"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan).  d_ff=0 in the assignment: the
feed-forward capacity lives in the blocks' own up-projections.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import KeyGen, make_param


# ---------------------------------------------------------------------------
# mLSTM: per-head matrix memory C [hd, hd], exponential gating; computed in
# chunkwise-parallel form (intra-chunk attention-like + inter-chunk recurrence)
# ---------------------------------------------------------------------------

def init_mlstm(kg: KeyGen, d_model: int, n_heads: int, dtype,
               proj_factor: float = 2.0) -> Dict[str, Any]:
    d_in = int(proj_factor * d_model)
    assert d_in % n_heads == 0
    return {
        "up_proj": make_param(kg(), (d_model, 2 * d_in), dtype),
        "wq": make_param(kg(), (d_in, d_in), dtype),
        "wk": make_param(kg(), (d_in, d_in), dtype),
        "wv": make_param(kg(), (d_in, d_in), dtype),
        "w_i": make_param(kg(), (d_in, n_heads), dtype),   # input gate
        "w_f": make_param(kg(), (d_in, n_heads), dtype),   # forget gate
        "b_i": jnp.zeros((n_heads,), jnp.float32),
        "b_f": jnp.full((n_heads,), 3.0, jnp.float32),     # open at init
        "out_norm": jnp.zeros((d_in,), jnp.float32),
        "down_proj": make_param(kg(), (d_in, d_model), dtype),
    }


def _mlstm_sequential(q, k, v, log_i, log_f, C0, n0, m0, hint=None):
    """Step recurrence (exact reference + the decode path)."""
    def step(carry, xs):
        C, n, m = carry
        if hint is not None:  # keep per-step residuals batch-sharded
            m = jax.lax.with_sharding_constraint(m, hint)
        qt, kt, vt, li, lf = xs  # [B,H,hd] x3, [B,H] x2
        m_new = jnp.maximum(lf + m, li)
        f_ = jnp.exp(lf + m - m_new)
        i_ = jnp.exp(li - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f_[..., None] * n + i_[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), log_i.transpose(2, 0, 1),
          log_f.transpose(2, 0, 1))
    (C, n, m), hs = lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), (C, n, m)  # [B,H,S,hd]


def _mlstm_chunkwise(q, k, v, log_i, log_f, C0, n0, m0, chunk: int):
    """Chunkwise-parallel mLSTM (the xLSTM paper's training form).

    The matrix memory C recurs only across chunk BOUNDARIES (S/chunk scan
    steps), so the backward pass stores S/chunk matrix states instead of S
    — the difference between ~2.4 TB and ~40 GB at S=4096.  Within a chunk
    everything is a batched (attention-like) matmul.  Exact same math as
    the sequential recurrence (tests assert allclose).
    """
    B, H, S, hd = q.shape
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    n_chunks = S // L

    def to_chunks(x):  # [B,H,S,...] -> [n, B,H,L,...]
        return x.reshape(B, H, n_chunks, L, *x.shape[3:]) \
                .transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)

    def chunk_step(carry, xs):
        C, n, m = carry                     # [B,H,hd,hd], [B,H,hd], [B,H]
        qt, kt, vt, li, lf = xs             # [B,H,L,hd] x3, [B,H,L] x2
        b = jnp.cumsum(lf, axis=-1)         # inclusive forget-cumlog
        # per-step stabilizer: max(inter, best intra source)
        a = li - b                          # source weight exponent (+b_j)
        a_run = lax.cummax(a, axis=a.ndim - 1)
        m_j = jnp.maximum(m[..., None] + b, b + a_run)   # [B,H,L]
        # inter-chunk: q_j . C_prev, decayed by exp(b_j + m - m_j)
        w_inter = jnp.exp(b + m[..., None] - m_j)
        num = jnp.einsum("bhld,bhde->bhle", qt, C) * w_inter[..., None]
        den = jnp.einsum("bhld,bhd->bhl", qt, n) * w_inter
        # intra-chunk: D_jk = exp(b_j - b_k + i_k - m_j) for k <= j
        expo = b[..., :, None] - b[..., None, :] + li[..., None, :] \
            - m_j[..., :, None]
        mask = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(mask, jnp.exp(expo), 0.0)          # [B,H,L,L]
        s = jnp.einsum("bhld,bhkd->bhlk", qt, kt) * D
        num = num + jnp.einsum("bhlk,bhke->bhle", s, vt)
        den = den + s.sum(axis=-1)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
        # carry to next chunk (stabilized at m_last)
        bL = b[..., -1:]                                  # [B,H,1]
        m_new = jnp.maximum(m + bL[..., 0],
                            (bL - b + li).max(axis=-1))
        w_old = jnp.exp(m + bL[..., 0] - m_new)
        w_src = jnp.exp(bL - b + li - m_new[..., None])   # [B,H,L]
        C = C * w_old[..., None, None] + jnp.einsum(
            "bhl,bhld,bhle->bhde", w_src, kt, vt)
        n = n * w_old[..., None] + jnp.einsum("bhl,bhld->bhd", w_src, kt)
        return (C, n, m_new), h

    (C, n, m), hs = lax.scan(chunk_step, (C0, n0, m0),
                             (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    return h, (C, n, m)


def apply_mlstm(p, x, *, n_heads: int, chunk: int = 64,
                state: Optional[Dict[str, Any]] = None, hint=None
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """mLSTM block: chunkwise-parallel for S>1, sequential for decode."""
    B, S, D = x.shape
    d_in = p["wq"].shape[0]
    hd = d_in // n_heads

    up = x @ p["up_proj"]
    u, z = up[..., :d_in], up[..., d_in:]
    q = (u @ p["wq"]).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    k = (u @ p["wk"]).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    v = (u @ p["wv"]).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    q = q.astype(jnp.float32) / jnp.sqrt(hd).astype(jnp.float32)
    k = k.astype(jnp.float32) / jnp.sqrt(hd).astype(jnp.float32)
    v = v.astype(jnp.float32)
    # gates: [B, H, S]
    log_i = (u @ p["w_i"]).astype(jnp.float32).transpose(0, 2, 1) + p["b_i"][:, None]
    log_f = jax.nn.log_sigmoid(
        (u @ p["w_f"]).astype(jnp.float32).transpose(0, 2, 1)
        + p["b_f"][:, None])

    C0 = (state["C"] if state is not None
          else jnp.zeros((B, n_heads, hd, hd), jnp.float32))
    n0 = (state["n"] if state is not None
          else jnp.zeros((B, n_heads, hd), jnp.float32))
    m0 = (state["m"] if state is not None
          else jnp.full((B, n_heads), -30.0, jnp.float32))

    if S == 1:
        hbh, (C, n, m) = _mlstm_sequential(q, k, v, log_i, log_f,
                                           C0, n0, m0, hint)
    elif S % min(chunk, S) == 0:
        hbh, (C, n, m) = _mlstm_chunkwise(q, k, v, log_i, log_f,
                                          C0, n0, m0, chunk)
    else:
        hbh, (C, n, m) = _mlstm_sequential(q, k, v, log_i, log_f,
                                           C0, n0, m0, hint)
    h = hbh.transpose(0, 2, 1, 3).reshape(B, S, d_in)

    # group-norm-ish output normalization per head, then gate + down-project
    hn = h.reshape(B, S, n_heads, hd)
    hn = hn * jax.lax.rsqrt(jnp.mean(hn * hn, axis=-1, keepdims=True) + 1e-6)
    h = hn.reshape(B, S, d_in) * (1.0 + p["out_norm"])
    h = (h * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = h @ p["down_proj"]
    new_state = {"C": C, "n": n, "m": m} if state is not None else None
    return out, new_state


def init_mlstm_state(batch: int, d_model: int, n_heads: int,
                     proj_factor: float = 2.0) -> Dict[str, Any]:
    d_in = int(proj_factor * d_model)
    hd = d_in // n_heads
    return {"C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
            "m": jnp.full((batch, n_heads), -30.0, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with exponential gating, sequential by construction
# ---------------------------------------------------------------------------

def init_slstm(kg: KeyGen, d_model: int, n_heads: int, dtype,
               proj_factor: float = 2.0) -> Dict[str, Any]:
    d_in = int(proj_factor * d_model)
    return {
        "up_proj": make_param(kg(), (d_model, d_in), dtype),
        "w_z": make_param(kg(), (d_in, d_in), dtype),
        "w_i": make_param(kg(), (d_in, d_in), dtype),
        "w_f": make_param(kg(), (d_in, d_in), dtype),
        "w_o": make_param(kg(), (d_in, d_in), dtype),
        "r_z": make_param(kg(), (d_in, d_in), dtype, scale=0.5),
        "r_i": make_param(kg(), (d_in, d_in), dtype, scale=0.5),
        "r_f": make_param(kg(), (d_in, d_in), dtype, scale=0.5),
        "r_o": make_param(kg(), (d_in, d_in), dtype, scale=0.5),
        "b_z": jnp.zeros((d_in,), jnp.float32),
        "b_i": jnp.zeros((d_in,), jnp.float32),
        "b_f": jnp.full((d_in,), 3.0, jnp.float32),
        "b_o": jnp.zeros((d_in,), jnp.float32),
        "down_proj": make_param(kg(), (d_in, d_model), dtype),
    }


def apply_slstm(p, x, *, state: Optional[Dict[str, Any]] = None, hint=None
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    B, S, D = x.shape
    d_in = p["w_z"].shape[0]
    u = (x @ p["up_proj"]).astype(jnp.float32)
    # precompute input contributions for all steps
    zi = u @ p["w_z"].astype(jnp.float32)
    ii = u @ p["w_i"].astype(jnp.float32)
    fi = u @ p["w_f"].astype(jnp.float32)
    oi = u @ p["w_o"].astype(jnp.float32)

    if state is not None:
        c0, n0, m0, h0 = state["c"], state["n"], state["m"], state["h"]
    else:
        c0 = jnp.zeros((B, d_in), jnp.float32)
        n0 = jnp.ones((B, d_in), jnp.float32)
        m0 = jnp.zeros((B, d_in), jnp.float32)
        h0 = jnp.zeros((B, d_in), jnp.float32)

    rz = p["r_z"].astype(jnp.float32)
    ri = p["r_i"].astype(jnp.float32)
    rf = p["r_f"].astype(jnp.float32)
    ro = p["r_o"].astype(jnp.float32)

    def step(carry, xs):
        c, n, m, h = carry
        if hint is not None:  # keep per-step residuals batch-sharded
            h = jax.lax.with_sharding_constraint(h, hint)
        zt, it, ft, ot = xs
        z = jnp.tanh(zt + h @ rz + p["b_z"])
        li = it + h @ ri + p["b_i"]
        lf = jax.nn.log_sigmoid(ft + h @ rf + p["b_f"])
        o = jax.nn.sigmoid(ot + h @ ro + p["b_o"])
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_ * c + i_ * z
        n = jnp.maximum(f_ * n + i_, 1e-6)
        h = o * (c / n)
        return (c, n, m_new, h), h

    xs = (zi.transpose(1, 0, 2), ii.transpose(1, 0, 2),
          fi.transpose(1, 0, 2), oi.transpose(1, 0, 2))
    (c, n, m, h_last), hs = lax.scan(step, (c0, n0, m0, h0), xs)
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    out = h @ p["down_proj"]
    new_state = ({"c": c, "n": n, "m": m, "h": h_last}
                 if state is not None else None)
    return out, new_state


def init_slstm_state(batch: int, d_model: int,
                     proj_factor: float = 2.0) -> Dict[str, Any]:
    d_in = int(proj_factor * d_model)
    z = jnp.zeros((batch, d_in), jnp.float32)
    return {"c": z, "n": jnp.ones((batch, d_in), jnp.float32), "m": z, "h": z}

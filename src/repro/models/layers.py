"""Shared neural-net building blocks (pure JAX, params as pytrees)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16, "int8": jnp.int8}[name]


# ---------------------------------------------------------------------------
# Initializers.  All param-producing code goes through `make_param` so that
# abstract (shape-only) initialization works with jax.eval_shape for dry runs.
# ---------------------------------------------------------------------------

def make_param(key, shape, dtype, scale: float = 1.0, mode: str = "normal"):
    if mode == "zeros":
        return jnp.zeros(shape, dtype)
    if mode == "ones":
        return jnp.ones(shape, dtype)
    fan_in = shape[0] if len(shape) > 1 else max(1, shape[0])
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


class KeyGen:
    """Splittable key source so init code stays linear to read."""

    def __init__(self, key):
        self.key = key

    def __call__(self):
        self.key, sub = jax.random.split(self.key)
        return sub


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping."""
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings (partial rotation supported for glm4)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_fraction: float, theta: float):
    rot_dim = int(head_dim * rotary_fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2) / rot_dim))
    return rot_dim, jnp.asarray(inv, jnp.float32)


def apply_rope(x, positions, rotary_fraction: float = 1.0,
               theta: float = 10_000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    rot_dim, inv = rope_freqs(head_dim, rotary_fraction, theta)
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # [..,S,1,rd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (llama-family) -- also used per-expert by MoE
# ---------------------------------------------------------------------------

def init_mlp(kg: KeyGen, d_model: int, d_ff: int, dtype) -> Dict[str, Any]:
    return {
        "wi_gate": make_param(kg(), (d_model, d_ff), dtype),
        "wi_up": make_param(kg(), (d_model, d_ff), dtype),
        "wo": make_param(kg(), (d_ff, d_model), dtype),
    }


def apply_mlp(p, x, act: str = "silu"):
    h = act_fn(act)(x @ p["wi_gate"]) * (x @ p["wi_up"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(kg: KeyGen, vocab: int, d_model: int, dtype,
               tie: bool) -> Dict[str, Any]:
    p = {"embedding": make_param(kg(), (vocab, d_model), dtype, scale=1.0)}
    if not tie:
        p["lm_head"] = make_param(kg(), (d_model, vocab), dtype)
    return p


def embed_tokens(p, tokens, scale_embed: bool, d_model: int, dtype):
    x = p["embedding"][tokens].astype(dtype)
    if scale_embed:
        x = x * jnp.asarray(np.sqrt(d_model), dtype)
    return x


def unembed(p, x, logit_cap: float = 0.0, n_valid: int = 0):
    if "lm_head" in p:
        logits = x @ p["lm_head"]
    else:
        logits = x @ p["embedding"].astype(x.dtype).T
    logits = softcap(logits.astype(jnp.float32), logit_cap)
    V = logits.shape[-1]
    if n_valid and n_valid < V:
        # vocab-padding columns must never win a softmax/argmax
        mask = jnp.where(jnp.arange(V) < n_valid, 0.0, -1e9)
        logits = logits + mask
    return logits


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Stable CE over (possibly vocab-sharded) logits.  [B,S,V] x [B,S]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return loss.mean()

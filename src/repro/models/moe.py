"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Dispatch uses scatter/gather with a per-expert capacity bound (tokens over
capacity are dropped, residual passes through) — the standard TPU-friendly
formulation: dense einsums over a [E, C, D] buffer, expert dim shardable
over the "model"/"expert" mesh axis (EP).  XLA SPMD inserts the all-to-all
style collectives from the sharding constraints; the explicit schedule is a
hill-climb lever (EXPERIMENTS.md §Perf).

The expert-capacity *reservation* itself is an instance of the paper's
multi-word atomic reservation problem — see repro.kernels.pmwcas_apply for
the batched variant used by the serving layer.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import KeyGen, act_fn, make_param


def init_moe(kg: KeyGen, d_model: int, n_experts: int, d_ff: int,
             dtype) -> Dict[str, Any]:
    return {
        "router": make_param(kg(), (d_model, n_experts), jnp.float32),
        "wi_gate": make_param(kg(), (n_experts, d_model, d_ff), dtype),
        "wi_up": make_param(kg(), (n_experts, d_model, d_ff), dtype),
        "wo": make_param(kg(), (n_experts, d_ff, d_model), dtype),
    }


def _capacity(n_tokens: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8 for layout


def apply_moe(p, x, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu", ecd_hint=None, gather_hint=None,
              groups: int = 1, group_hint=None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    groups > 1 enforces capacity PER GROUP (= per data shard on the
    production mesh, Switch-style) and — crucially — makes every dispatch
    gather/scatter local to its group, so GSPMD never re-replicates the
    buffers (the hill-climb measurement behind this is in EXPERIMENTS.md
    §Perf, granite cell)."""
    B, S, D = x.shape
    N_all = B * S
    if groups > 1 and N_all % groups == 0:
        xg = x.reshape(groups, N_all // groups, 1, D)
        if group_hint is not None:
            xg = jax.lax.with_sharding_constraint(xg, group_hint)
        yg, aux = jax.vmap(
            lambda xi: apply_moe(p, xi, top_k=top_k,
                                 capacity_factor=capacity_factor, act=act,
                                 groups=1))(xg)
        if group_hint is not None:
            yg = jax.lax.with_sharding_constraint(yg, group_hint)
        return yg.reshape(B, S, D), aux.mean()
    E = p["router"].shape[1]
    N = B * S
    C = _capacity(N, E, top_k, capacity_factor)
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # [N, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert, in token order.
    # sort-based ranking: O(NK log NK) and O(NK) memory — a [NK, E] one-hot
    # cumsum would lower to reduce-window (quadratic cost) and 4 GB buffers.
    flat_e = expert_ids.reshape(N * top_k)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=E)                  # tokens/expert
    starts = jnp.cumsum(counts) - counts                     # [E]
    ranks_sorted = jnp.arange(N * top_k) - starts[flat_e[order]]
    pos = jnp.zeros(N * top_k, jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32)).reshape(N, top_k)
    keep = pos < C

    # gather-based dispatch: a scatter-add into the [E*C, D] buffer forces
    # GSPMD to replicate the operand (measured: 242 GiB/device on granite);
    # the equivalent gather from the expert-sorted token stream stays
    # sharded.  idx[e, c] = position of expert e's c-th assignment in the
    # sorted stream; its token id indexes xf directly.
    slot = jnp.where(keep, expert_ids * C + pos, E * C)       # for combine
    tok_of = (order // top_k).astype(jnp.int32)               # [N*K]
    grid = starts[:, None] + jnp.arange(C)[None, :]           # [E, C]
    in_cap = jnp.arange(C)[None, :] < counts[:, None]
    src = jnp.where(in_cap,
                    tok_of[jnp.clip(grid, 0, N * top_k - 1)], N)
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), x.dtype)])
    xe = xf_pad[src]                                          # [E, C, D]
    if ecd_hint is not None:
        xe = jax.lax.with_sharding_constraint(xe, ecd_hint)

    # expert FFNs as batched einsums (E shardable)
    h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, p["wi_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["wi_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # [E, C, D]
    if ecd_hint is not None:
        ye = jax.lax.with_sharding_constraint(ye, ecd_hint)

    # gather back and combine with gates
    gathered = ye.reshape(E * C, D)[jnp.minimum(slot, E * C - 1).reshape(-1)]
    gathered = gathered.reshape(N, top_k, D)
    if gather_hint is not None:
        gathered = jax.lax.with_sharding_constraint(gathered, gather_hint)
    w = (gate_vals * keep).astype(x.dtype)                    # dropped -> 0
    y = jnp.einsum("nkd,nk->nd", gathered, w)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                   # [E]
    ce = counts.astype(jnp.float32) / (N * top_k)             # dispatch frac
    aux = E * jnp.sum(me * ce)

    return y.reshape(B, S, D), aux


def apply_moe_dense(p, x, *, top_k: int, act: str = "silu"):
    """Dropless MoE for decode (S==1, N small): compute every expert and
    combine with the normalized top-k gates.  Exactly the capacity path's
    math with zero drops; decode is weight-read-bound anyway, so computing
    all experts costs no extra memory traffic per expert touched."""
    B, S, D = x.shape
    E = p["router"].shape[1]
    xf = x.reshape(B * S, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    w = (jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)
         * gate_vals[..., None]).sum(axis=1)                   # [N, E]

    h = act_fn(act)(jnp.einsum("nd,edf->nef", xf, p["wi_gate"])) * \
        jnp.einsum("nd,edf->nef", xf, p["wi_up"])
    ye = jnp.einsum("nef,efd->ned", h, p["wo"])                # [N, E, D]
    y = jnp.einsum("ned,ne->nd", ye, w.astype(ye.dtype))
    return y.reshape(B, S, D), jnp.zeros((), jnp.float32)

"""Mamba (selective state-space) block — used by jamba and available to any
hybrid stack.  Chunked associative-scan training path + O(1)-state decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import KeyGen, make_param


def init_mamba(kg: KeyGen, d_model: int, dtype, d_state: int = 16,
               d_conv: int = 4, expand: int = 2,
               dt_rank: int = 0) -> Dict[str, Any]:
    d_in = expand * d_model
    dt_rank = dt_rank or -(-d_model // 16)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_proj": make_param(kg(), (d_model, 2 * d_in), dtype),
        "conv_w": make_param(kg(), (d_conv, d_in), dtype, scale=1.0),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": make_param(kg(), (d_in, dt_rank + 2 * d_state), dtype),
        "dt_proj_w": make_param(kg(), (dt_rank, d_in), dtype),
        "dt_proj_b": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": make_param(kg(), (d_in, d_model), dtype),
    }


def _ssm_scan_chunk(dA, dBx, h0):
    """Associative scan within a chunk.  dA, dBx: [B, L, d_in, N]."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    A, Bx = lax.associative_scan(combine, (dA, dBx), axis=1)
    # fold in the carried-in state
    Bx = Bx + A * h0[:, None]
    return Bx  # h_t for every t in chunk


def _selective_ssm(p, x, h0, chunk: int, unroll: bool = False):
    """x: [B, L, d_in] post-conv.  Returns (y, h_final)."""
    B, L, d_in = x.shape
    d_state = p["a_log"].shape[1]
    dt_rank = p["x_proj"].shape[1] - 2 * d_state
    proj = x @ p["x_proj"]
    dt = jax.nn.softplus(
        proj[..., :dt_rank] @ p["dt_proj_w"]
        + p["dt_proj_b"]).astype(jnp.float32)                 # [B,L,d_in]
    Bm = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + d_state:].astype(jnp.float32)
    A = -jnp.exp(p["a_log"])                                  # [d_in, N]

    dA = jnp.exp(dt[..., None] * A)                           # [B,L,d_in,N]
    dBx = (dt * x.astype(jnp.float32))[..., None] * Bm[..., None, :]

    n_chunks = -(-L // chunk)
    pad = n_chunks * chunk - L
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dA = dA.reshape(B, n_chunks, chunk, d_in, d_state).transpose(1, 0, 2, 3, 4)
    dBx = dBx.reshape(B, n_chunks, chunk, d_in, d_state).transpose(1, 0, 2, 3, 4)

    def body(h, xs):
        dAc, dBxc = xs
        hs = _ssm_scan_chunk(dAc, dBxc, h)
        return hs[:, -1], hs

    h_final, hs = lax.scan(body, h0, (dA, dBx),
                           unroll=n_chunks if unroll else 1)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk,
                                             d_in, d_state)[:, :L]
    y = jnp.einsum("blds,bls->bld", hs, Cm)
    y = y + x.astype(jnp.float32) * p["d_skip"]
    return y, h_final


def apply_mamba(p, x, *, chunk: int = 256, unroll: bool = False,
                state: Optional[Dict[str, Any]] = None,
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """x: [B, S, D].  state (decode): {"conv": [B,d_conv-1,d_in],
    "ssm": [B,d_in,N]}.  Returns (y [B,S,D], new_state or None)."""
    B, S, D = x.shape
    d_in = p["in_proj"].shape[1] // 2
    d_conv = p["conv_w"].shape[0]
    d_state = p["a_log"].shape[1]

    xz = x @ p["in_proj"]
    xs, z = xz[..., :d_in], xz[..., d_in:]

    # causal depthwise conv over the sequence
    if state is not None:
        hist = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
    else:
        hist = jnp.pad(xs, ((0, 0), (d_conv - 1, 0), (0, 0)))
    windows = jnp.stack([hist[:, i:i + S] for i in range(d_conv)], axis=2)
    xc = jnp.einsum("bswd,wd->bsd", windows, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, d_in, d_state), jnp.float32))
    y, h_final = _selective_ssm(p, xc, h0, chunk, unroll)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]

    new_state = None
    if state is not None:
        new_state = {"conv": hist[:, -(d_conv - 1):].astype(jnp.float32),
                     "ssm": h_final}
    return out, new_state


def init_mamba_state(batch: int, d_model: int, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2) -> Dict[str, Any]:
    d_in = expand * d_model
    return {"conv": jnp.zeros((batch, d_conv - 1, d_in), jnp.float32),
            "ssm": jnp.zeros((batch, d_in, d_state), jnp.float32)}

"""Model assembly: embeddings -> scanned unit stack -> logits.

One ``Model`` class covers all ten assigned architectures:
- decoder-only dense / MoE / SSM / xLSTM / hybrid stacks (repeating units)
- encoder-decoder (seamless-m4t) with cross-attention
- modality frontends as stubs: precomputed patch/frame embeddings are inputs
  (per the assignment, the backbone is what we model)

Layer stacks lower through a single ``lax.scan`` over stacked unit params
(remat-wrapped), so 64-layer configs compile one unit body.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, MambaConfig, ModelConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (KeyGen, cross_entropy, dtype_of, embed_tokens,
                     init_embed, init_mlp, apply_mlp, make_param, rms_norm,
                     unembed)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)
        self.param_dtype = dtype_of(cfg.param_dtype)
        # optional NamedSharding hints ("act", "logits", "moe_ecd") set by
        # the launcher; they anchor XLA's sharding propagation
        self.hints = {}

    def _hint(self, x, name):
        h = self.hints.get(name)
        if h is None:
            return x
        return jax.lax.with_sharding_constraint(x, h)

    # ------------------------------------------------------------------ init
    def _init_layer(self, kg: KeyGen, spec: LayerSpec,
                    cross: bool = False) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.param_dtype
        p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
        if spec.kind == "attn":
            p["attn"] = attn_mod.init_attention(
                kg, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, dt, cfg.qkv_bias)
        elif spec.kind == "mamba":
            m = cfg.mamba or MambaConfig()
            p["mamba"] = ssm_mod.init_mamba(
                kg, cfg.d_model, dt, m.d_state, m.d_conv, m.expand, m.dt_rank)
        elif spec.kind == "mlstm":
            x = cfg.xlstm
            p["mlstm"] = xlstm_mod.init_mlstm(kg, cfg.d_model, cfg.n_heads,
                                              dt, x.proj_factor)
        elif spec.kind == "slstm":
            x = cfg.xlstm
            p["slstm"] = xlstm_mod.init_slstm(kg, cfg.d_model, cfg.n_heads,
                                              dt, x.proj_factor)
        if cross:
            p["ln_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["cross"] = attn_mod.init_attention(
                kg, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, dt, False, cross=True)
        if spec.ffn != "none":
            p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if spec.ffn == "dense":
            p["mlp"] = init_mlp(kg, cfg.d_model, cfg.d_ff, dt)
        elif spec.ffn == "moe":
            p["moe"] = moe_mod.init_moe(kg, cfg.d_model, cfg.moe.n_experts,
                                        cfg.moe.d_ff, dt)
        return p

    def _init_unit(self, kg: KeyGen, cross: bool = False) -> Dict[str, Any]:
        return {f"layer{i}": self._init_layer(kg, spec, cross)
                for i, spec in enumerate(self.cfg.unit)}

    def init_params(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        kg = KeyGen(key)
        params: Dict[str, Any] = {}
        params.update(init_embed(kg, cfg.padded_vocab, cfg.d_model,
                                 self.param_dtype, cfg.tie_embeddings))
        cross = cfg.enc_dec
        units = [self._init_unit(kg, cross) for _ in range(cfg.n_units)]
        params["units"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *units)
        params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.enc_dec:
            enc_spec = LayerSpec(kind="attn", attn_type="global", ffn="dense")
            enc_units = [
                {"layer0": self._init_layer(kg, enc_spec)}
                for _ in range(cfg.n_enc_layers)]
            params["encoder"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *enc_units)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.frontend != "none":
            params["frontend_proj"] = make_param(
                kg(), (cfg.frontend_dim, cfg.d_model), self.param_dtype)
        return params

    def abstract_params(self):
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return jax.eval_shape(self.init_params, key)

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int):
        """Decode cache: one entry per unit position, stacked over units."""
        cfg = self.cfg
        per_pos = {}
        for i, spec in enumerate(cfg.unit):
            if spec.kind == "attn":
                c = attn_mod.init_kv_cache(
                    batch, cfg.n_kv_heads, max_len, cfg.resolved_head_dim,
                    cfg.kv_dtype, cfg.n_units)
                c.pop("index")
                per_pos[f"layer{i}"] = c
            elif spec.kind == "mamba":
                m = cfg.mamba
                s = ssm_mod.init_mamba_state(batch, cfg.d_model, m.d_state,
                                             m.d_conv, m.expand)
                per_pos[f"layer{i}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.n_units,) + x.shape), s)
            elif spec.kind == "mlstm":
                s = xlstm_mod.init_mlstm_state(batch, cfg.d_model,
                                               cfg.n_heads,
                                               cfg.xlstm.proj_factor)
                per_pos[f"layer{i}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.n_units,) + x.shape), s)
            elif spec.kind == "slstm":
                s = xlstm_mod.init_slstm_state(batch, cfg.d_model,
                                               cfg.xlstm.proj_factor)
                per_pos[f"layer{i}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.n_units,) + x.shape), s)
        cache = {"layers": per_pos, "index": jnp.zeros((), jnp.int32)}
        if cfg.enc_dec:
            cache["cross_k"] = jnp.zeros(
                (cfg.n_units, batch, cfg.n_kv_heads, cfg.frontend_len,
                 cfg.resolved_head_dim), self.dtype)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        return cache

    # -------------------------------------------------------------- sublayer
    def _apply_layer(self, spec: LayerSpec, p, x, *, positions,
                     layer_cache=None, cache_index=None, cross_kv=None,
                     causal=True):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        new_cache = layer_cache
        if spec.kind == "attn":
            window = cfg.sliding_window if spec.attn_type == "local" else 0
            chunk = (cfg.decode_chunk if h.shape[1] == 1 else cfg.attn_chunk)
            y, upd = attn_mod.attention(
                p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                causal=causal, window=window,
                rotary_fraction=cfg.rotary_fraction,
                rope_theta=cfg.rope_theta, attn_cap=cfg.attn_softcap,
                impl=cfg.attn_impl, chunk=chunk, unroll=cfg.unroll_scans,
                layer_cache=layer_cache,
                cache_index=cache_index)
            if upd is not None:
                new_cache = upd
        elif spec.kind == "mamba":
            y, upd = ssm_mod.apply_mamba(p["mamba"], h,
                                         chunk=cfg.mamba_chunk,
                                         unroll=cfg.unroll_scans,
                                         state=layer_cache)
            if upd is not None:
                new_cache = upd
        elif spec.kind == "mlstm":
            y, upd = xlstm_mod.apply_mlstm(p["mlstm"], h,
                                           n_heads=cfg.n_heads,
                                           chunk=cfg.xlstm.chunk,
                                           state=layer_cache,
                                           hint=self.hints.get("state_b"))
            if upd is not None:
                new_cache = upd
        elif spec.kind == "slstm":
            y, upd = xlstm_mod.apply_slstm(p["slstm"], h, state=layer_cache,
                                           hint=self.hints.get("state_b"))
            if upd is not None:
                new_cache = upd
        else:
            raise ValueError(spec.kind)
        x = x + y

        if cross_kv is not None and "cross" in p:
            h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            y, _ = attn_mod.attention(
                p["cross"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                causal=False, use_rope=False, impl=cfg.attn_impl,
                kv=cross_kv)
            x = x + y

        if spec.ffn == "dense":
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + apply_mlp(p["mlp"], h, cfg.act)
        elif spec.ffn == "moe":
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            if h.shape[1] == 1:  # decode: dropless all-experts path
                y, a = moe_mod.apply_moe_dense(p["moe"], h,
                                               top_k=cfg.moe.top_k,
                                               act=cfg.act)
            else:
                y, a = moe_mod.apply_moe(
                    p["moe"], h, top_k=cfg.moe.top_k,
                    capacity_factor=cfg.moe.capacity_factor, act=cfg.act,
                    ecd_hint=self.hints.get("moe_ecd"),
                    gather_hint=self.hints.get("moe_gather"),
                    groups=self.hints.get("moe_groups", 1),
                    group_hint=self.hints.get("moe_grp"))
            x = x + y
            aux = aux + a
        return x, new_cache, aux

    # ---------------------------------------------------------------- stacks
    def _run_units(self, params, x, *, positions, cache=None,
                   cache_index=None, causal=True, remat=True):
        cfg = self.cfg

        def unit_body(carry, xs):
            x, aux = carry
            x = self._hint(x, "act")
            if cache is None:
                unit_p = xs
                unit_c = {}
                cross_kv = None
            elif cfg.enc_dec:
                unit_p, unit_c, ck, cv = xs
                cross_kv = (ck, cv)
            else:
                unit_p, unit_c = xs
                cross_kv = None
            new_c = {}
            for i, spec in enumerate(cfg.unit):
                name = f"layer{i}"
                x, nc, a = self._apply_layer(
                    spec, unit_p[name], x, positions=positions,
                    layer_cache=unit_c.get(name), cache_index=cache_index,
                    cross_kv=cross_kv, causal=causal)
                if nc is not None:
                    new_c[name] = nc
                aux = aux + a
            return (x, aux), new_c

        body = unit_body
        if remat:
            body = jax.checkpoint(unit_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)

        if cache is None:
            xs = params["units"]
        elif cfg.enc_dec:
            xs = (params["units"], cache["layers"], cache["cross_k"],
                  cache["cross_v"])
        else:
            xs = (params["units"], cache["layers"])

        (x, aux), new_layers = lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs,
            unroll=cfg.n_units if cfg.unroll_scans else 1)
        return x, aux, new_layers

    def _run_encoder(self, params, x):
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])
        enc_spec = LayerSpec(kind="attn", attn_type="global", ffn="dense")

        def body(x, layer_p):
            x = self._hint(x, "act")
            x, _, _ = self._apply_layer(enc_spec, layer_p["layer0"], x,
                                        positions=positions, causal=False)
            return x, None

        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = lax.scan(body, x, params["encoder"],
                        unroll=cfg.n_enc_layers if cfg.unroll_scans else 1)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ------------------------------------------------------------- embedding
    def _embed_inputs(self, params, tokens, frontend_embeds=None):
        cfg = self.cfg
        x = self._hint(embed_tokens(params, tokens, cfg.scale_embed,
                                    cfg.d_model, self.dtype), "act")
        if cfg.frontend != "none" and not cfg.enc_dec:
            assert frontend_embeds is not None, \
                f"{cfg.name} requires frontend embeddings"
            prefix = (frontend_embeds.astype(self.dtype)
                      @ params["frontend_proj"].astype(self.dtype))
            x = jnp.concatenate([prefix, x], axis=1)
        return x

    # ----------------------------------------------------------- entrypoints
    def train_loss(self, params, batch, remat: bool = True):
        """batch: {tokens [B,S], labels [B,S], frontend_embeds?}."""
        cfg = self.cfg
        params = jax.tree_util.tree_map(
            lambda x: x.astype(self.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim > 1 else x,
            params)
        tokens, labels = batch["tokens"], batch["labels"]
        fe = batch.get("frontend_embeds")

        if cfg.enc_dec:
            mem = (fe.astype(self.dtype) @ params["frontend_proj"]
                   .astype(self.dtype))
            memory = self._run_encoder(params, mem)
            x = self._embed_inputs(params, tokens)
            # precompute per-unit cross kv via vmap over stacked params
            ck, cv = jax.vmap(
                lambda up: attn_mod.precompute_cross_kv(
                    up["layer0"]["cross"], memory, cfg.n_kv_heads,
                    cfg.resolved_head_dim))(params["units"])
            cache = {"layers": _empty_layers(cfg), "cross_k": ck,
                     "cross_v": cv}
            positions = jnp.arange(x.shape[1])
            x, aux, _ = self._run_units(params, x, positions=positions,
                                        cache=cache, cache_index=None,
                                        causal=True, remat=remat)
        else:
            x = self._embed_inputs(params, tokens, fe)
            positions = jnp.arange(x.shape[1])
            x, aux, _ = self._run_units(params, x, positions=positions,
                                        remat=remat)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.frontend != "none" and not cfg.enc_dec:
            x = x[:, -tokens.shape[1]:]        # loss over text positions only
        logits = self._hint(unembed(params, x, cfg.logit_softcap,
                                    cfg.vocab), "logits")
        loss = cross_entropy(logits, labels)
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_weight * aux / cfg.n_layers
        return loss

    def prefill(self, params, tokens, cache, frontend_embeds=None):
        """Process a full prompt, filling the cache.  Returns (logits_last,
        cache)."""
        cfg = self.cfg
        params = _cast_params(params, self.dtype)
        if cfg.enc_dec:
            mem = (frontend_embeds.astype(self.dtype)
                   @ params["frontend_proj"].astype(self.dtype))
            memory = self._run_encoder(params, mem)
            ck, cv = jax.vmap(
                lambda up: attn_mod.precompute_cross_kv(
                    up["layer0"]["cross"], memory, cfg.n_kv_heads,
                    cfg.resolved_head_dim))(params["units"])
            cache = dict(cache)
            cache["cross_k"], cache["cross_v"] = ck, cv
            x = self._embed_inputs(params, tokens)
        else:
            x = self._embed_inputs(params, tokens, frontend_embeds)
        S = x.shape[1]
        positions = jnp.arange(S)
        x, _, new_layers = self._run_units(
            params, x, positions=positions, cache=cache,
            cache_index=jnp.zeros((), jnp.int32), causal=True, remat=False)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["index"] = jnp.asarray(S, jnp.int32)
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = unembed(params, x, cfg.logit_softcap, cfg.vocab)
        return logits[:, 0], new_cache

    def decode_step(self, params, token, cache):
        """token: [B, 1] -> (logits [B, V], updated cache)."""
        cfg = self.cfg
        params = _cast_params(params, self.dtype)
        idx = cache["index"]
        x = embed_tokens(params, token, cfg.scale_embed, cfg.d_model,
                         self.dtype)
        positions = idx + jnp.arange(1)
        x, _, new_layers = self._run_units(
            params, x, positions=positions, cache=cache, cache_index=idx,
            causal=True, remat=False)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["index"] = idx + 1
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params, x, cfg.logit_softcap, cfg.vocab)
        return logits[:, 0], new_cache


def _cast_params(params, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim > 1 else x,
        params)


def _empty_layers(cfg):
    return {}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

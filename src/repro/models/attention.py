"""Grouped-query attention with the features the assigned archs need.

Covered: GQA/MQA (kv groups), RoPE (partial rotation for glm4), QKV bias
(qwen1.5), attention-logit softcapping + alternating local/global layers
(gemma2), sliding windows, encoder-decoder cross attention (seamless),
KV caches in bf16 or int8 (per-token-per-head scales), and three
implementations of the core softmax(QK^T)V:

- ``ref``      materialized [B,KV,G,S,S] scores -- the oracle
- ``chunked``  online-softmax scan over KV chunks (flash-style, pure jnp;
               the default: never materializes the full score matrix)
- ``pallas``   the TPU kernel in repro.kernels.flash_attention

All three are numerically interchangeable (tests assert allclose).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import KeyGen, apply_rope, make_param, softcap

NEG_INF = -2.0 ** 20  # large-but-finite to keep softcap/tanh well-behaved


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(kg: KeyGen, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False,
                   cross: bool = False) -> Dict[str, Any]:
    p = {
        "wq": make_param(kg(), (d_model, n_heads * head_dim), dtype),
        "wk": make_param(kg(), (d_model, n_kv_heads * head_dim), dtype),
        "wv": make_param(kg(), (d_model, n_kv_heads * head_dim), dtype),
        "wo": make_param(kg(), (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


# ---------------------------------------------------------------------------
# Score-level mask
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """Additive bias [.., S_q, S_k] in f32."""
    ok = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], jnp.bool_)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core softmax(QK^T)V implementations.  Layouts:
#   q: [B, KV, G, S_q, hd]   k/v: [B, KV, S_k, hd]
# ---------------------------------------------------------------------------

def _sdpa_ref(q, k, v, q_pos, k_pos, *, causal, window, attn_cap, scale):
    s = jnp.einsum("bkgqd,bkcd->bkgqc", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, attn_cap)
    s = s + _mask_bias(q_pos, k_pos, causal, window)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqc,bkcd->bkgqd", w.astype(v.dtype), v)


def _chunk_kv(k, v, k_pos, chunk):
    B, KV, Sk, hd = k.shape
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        # pad sentinel: beyond the validity limit so every mask drops it
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2.0 ** 30)
    kc = k.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, KV, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)
    return kc, vc, pc, n_chunks, pad


def _fmask_bias(q_pos, k_pos, causal: bool, window: int):
    """Additive bias from float positions (custom_vjp-friendly)."""
    ok = jnp.broadcast_to(k_pos[None, :] < 2.0 ** 29,   # drop pad sentinels
                          q_pos.shape[-1:] + k_pos.shape[-1:])
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int, attn_cap: float, scale: float,
                chunk: int, unroll: bool):
    """Flash attention in jnp with a recompute-based custom VJP.

    Without this, differentiating through the online-softmax scan stores
    per-chunk residuals (O(S^2 / chunk) memory) — the exact failure mode
    flash attention exists to avoid.  Forward saves only (q, k, v, out, L);
    backward recomputes scores chunk by chunk.
    """

    def fwd_pass(q, k, v, q_pos, k_pos):
        B, KV, G, Sq, hd = q.shape
        c = min(chunk, k.shape[2])
        kc, vc, pc, n_chunks, _ = _chunk_kv(k, v, k_pos, c)

        def body(carry, xs):
            m, l, acc = carry
            kb, vb, pb = xs
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q, kb,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, attn_cap)
            s = s + _fmask_bias(q_pos, pb, causal, window)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vb.dtype),
                vb).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, pc),
                                  unroll=n_chunks if unroll else 1)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return out, lse

    @jax.custom_vjp
    def flash(q, k, v, q_pos, k_pos):
        return fwd_pass(q, k, v, q_pos, k_pos)[0]

    def flash_fwd(q, k, v, q_pos, k_pos):
        out, lse = fwd_pass(q, k, v, q_pos, k_pos)
        return out, (q, k, v, q_pos, k_pos, out, lse)

    def flash_bwd(res, do):
        q, k, v, q_pos, k_pos, out, lse = res
        B, KV, G, Sq, hd = q.shape
        Sk = k.shape[2]
        c = min(chunk, Sk)
        kc, vc, pc, n_chunks, pad = _chunk_kv(k, v, k_pos, c)
        do_f = do.astype(jnp.float32)
        delta = jnp.sum(do_f * out.astype(jnp.float32), axis=-1)  # [B,KV,G,S]

        def body(dq, xs):
            kb, vb, pb = xs
            sraw = jnp.einsum("bkgqd,bkcd->bkgqc", q, kb,
                              preferred_element_type=jnp.float32) * scale
            s = softcap(sraw, attn_cap)
            s = s + _fmask_bias(q_pos, pb, causal, window)
            p = jnp.exp(s - lse[..., None])                       # true probs
            dv = jnp.einsum("bkgqc,bkgqd->bkcd", p, do_f)
            dp = jnp.einsum("bkgqd,bkcd->bkgqc", do_f,
                            vb.astype(jnp.float32))
            ds = p * (dp - delta[..., None])
            if attn_cap > 0.0:
                th = jnp.tanh(sraw * (1.0 / attn_cap))
                ds = ds * (1.0 - th * th)
            dq = dq + jnp.einsum("bkgqc,bkcd->bkgqd", ds,
                                 kb.astype(jnp.float32)) * scale
            dk = jnp.einsum("bkgqc,bkgqd->bkcd", ds,
                            q.astype(jnp.float32)) * scale
            return dq, (dk, dv)

        dq0 = jnp.zeros(q.shape, jnp.float32)
        dq, (dk_c, dv_c) = lax.scan(body, dq0, (kc, vc, pc),
                                    unroll=n_chunks if unroll else 1)
        dk = dk_c.transpose(1, 2, 0, 3, 4).reshape(B, KV, n_chunks * c, hd)
        dv = dv_c.transpose(1, 2, 0, 3, 4).reshape(B, KV, n_chunks * c, hd)
        if pad:
            dk, dv = dk[:, :, :Sk], dv[:, :, :Sk]
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                jnp.zeros_like(q_pos), jnp.zeros_like(k_pos))

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, causal, window, attn_cap, scale,
                  chunk: int = 1024, unroll: bool = False):
    """Flash-style attention: online softmax fwd + recompute bwd."""
    fn = _make_flash(bool(causal), int(window), float(attn_cap),
                     float(scale), int(chunk), bool(unroll))
    return fn(q, k, v, q_pos.astype(jnp.float32), k_pos.astype(jnp.float32))


def _sdpa_chunked_quant(q, k8, ks, v8, vs, q_pos, k_pos, *, causal, window,
                        attn_cap, scale, chunk: int = 16384):
    """Online-softmax attention DIRECTLY over an int8 KV cache: dequantize
    chunk-by-chunk inside the scan so the bf16 copy of the full cache never
    materializes (a whole-cache dequant costs B*KV*L*hd*2 bytes of temp —
    21 GiB/device for qwen1.5-32B decode_32k).  Forward-only (decode)."""
    B, KV, G, Sq, hd = q.shape
    Sk = k8.shape[2]
    c = min(chunk, Sk)
    n_chunks = -(-Sk // c)
    pad = n_chunks * c - Sk
    if pad:
        k8 = jnp.pad(k8, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v8 = jnp.pad(v8, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2.0 ** 30)
    kc = k8.reshape(B, KV, n_chunks, c, hd).transpose(2, 0, 1, 3, 4)
    vc = v8.reshape(B, KV, n_chunks, c, hd).transpose(2, 0, 1, 3, 4)
    ksc = ks.reshape(B, KV, n_chunks, c).transpose(2, 0, 1, 3)
    vsc = vs.reshape(B, KV, n_chunks, c).transpose(2, 0, 1, 3)
    pc = k_pos.reshape(n_chunks, c).astype(jnp.float32)
    q_posf = q_pos.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kb8, vb8, ksb, vsb, pb = xs
        kb = kb8.astype(jnp.float32) * ksb[..., None]
        vb = vb8.astype(jnp.float32) * vsb[..., None]
        s = jnp.einsum("bkgqd,bkcd->bkgqc", q.astype(jnp.float32), kb) * scale
        s = softcap(s, attn_cap)
        s = s + _fmask_bias(q_posf, pb, causal, window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgqc,bkcd->bkgqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, ksc, vsc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def _sdpa_pallas(q, k, v, q_pos, k_pos, **kw):
    from repro.kernels.flash_attention import ops as fa_ops
    return fa_ops.flash_attention(q, k, v, q_pos, k_pos, **kw)


_IMPLS = {"ref": _sdpa_ref, "chunked": _sdpa_chunked, "pallas": _sdpa_pallas}


# ---------------------------------------------------------------------------
# KV cache (bf16 or int8 with per-token-per-head scales)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, n_kv_heads: int, max_len: int, head_dim: int,
                  kv_dtype: str, n_layers: int) -> Dict[str, Any]:
    """Stacked-over-layers cache (leading dim matches the layer scan)."""
    if kv_dtype == "int8":
        z8 = jnp.zeros((n_layers, batch, n_kv_heads, max_len, head_dim),
                       jnp.int8)
        sc = jnp.zeros((n_layers, batch, n_kv_heads, max_len), jnp.float32)
        return {"k": z8, "v": z8, "k_scale": sc, "v_scale": sc,
                "index": jnp.zeros((), jnp.int32)}
    zb = jnp.zeros((n_layers, batch, n_kv_heads, max_len, head_dim),
                   jnp.bfloat16)
    return {"k": zb, "v": zb, "index": jnp.zeros((), jnp.int32)}


def _quant(x):
    scale = jnp.max(jnp.abs(x), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x / scale[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_update(layer_cache, k_new, v_new, index):
    """Write [B,KV,S,hd] at position `index`; returns updated layer cache."""
    out = dict(layer_cache)
    if layer_cache["k"].dtype == jnp.int8:
        kq, ks = _quant(k_new)
        vq, vs = _quant(v_new)
        out["k"] = lax.dynamic_update_slice_in_dim(layer_cache["k"], kq,
                                                   index, axis=2)
        out["v"] = lax.dynamic_update_slice_in_dim(layer_cache["v"], vq,
                                                   index, axis=2)
        out["k_scale"] = lax.dynamic_update_slice_in_dim(
            layer_cache["k_scale"], ks, index, axis=2)
        out["v_scale"] = lax.dynamic_update_slice_in_dim(
            layer_cache["v_scale"], vs, index, axis=2)
    else:
        out["k"] = lax.dynamic_update_slice_in_dim(
            layer_cache["k"], k_new.astype(layer_cache["k"].dtype), index,
            axis=2)
        out["v"] = lax.dynamic_update_slice_in_dim(
            layer_cache["v"], v_new.astype(layer_cache["v"].dtype), index,
            axis=2)
    return out


def cache_kv(layer_cache, dtype):
    if layer_cache["k"].dtype == jnp.int8:
        k = _dequant(layer_cache["k"], layer_cache["k_scale"], dtype)
        v = _dequant(layer_cache["v"], layer_cache["v_scale"], dtype)
        return k, v
    return layer_cache["k"].astype(dtype), layer_cache["v"].astype(dtype)


# ---------------------------------------------------------------------------
# Full attention layer
# ---------------------------------------------------------------------------

def attention(p, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
              positions, causal: bool = True, window: int = 0,
              rotary_fraction: float = 1.0, rope_theta: float = 10_000.0,
              use_rope: bool = True, attn_cap: float = 0.0,
              impl: str = "chunked", chunk: int = 1024,
              unroll: bool = False,
              kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              k_positions=None,
              layer_cache: Optional[Dict[str, Any]] = None,
              cache_index=None):
    """One attention sublayer.

    - self-attention (train/prefill): kv=None, layer_cache=None
    - cross-attention: kv=(k_mem, v_mem) precomputed from the encoder
    - cached decode/prefill: layer_cache set; writes at cache_index
    Returns (output [B,S,D], updated layer_cache or None).
    """
    B, S, _ = x.shape
    G = n_heads // n_kv_heads
    q = (x @ p["wq"] + (p["bq"] if "bq" in p else 0.0)).reshape(
        B, S, n_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rotary_fraction, rope_theta)

    if kv is not None:                       # cross-attention memory
        k, v = kv
        k_pos = (k_positions if k_positions is not None
                 else jnp.arange(k.shape[2]))
        new_cache = None
    else:
        k = (x @ p["wk"] + (p["bk"] if "bk" in p else 0.0)).reshape(
            B, S, n_kv_heads, head_dim)
        v = (x @ p["wv"] + (p["bv"] if "bv" in p else 0.0)).reshape(
            B, S, n_kv_heads, head_dim)
        if use_rope:
            k = apply_rope(k, positions, rotary_fraction, rope_theta)
        k = k.transpose(0, 2, 1, 3)          # [B,KV,S,hd]
        v = v.transpose(0, 2, 1, 3)
        if layer_cache is not None:
            new_cache = cache_update(layer_cache, k, v, cache_index)
            if new_cache["k"].dtype == jnp.int8:
                # fused per-chunk dequantization — never materialize the
                # bf16 copy of the whole cache
                k_pos = jnp.arange(new_cache["k"].shape[2])
                qg = q.reshape(B, S, n_kv_heads, n_heads // n_kv_heads,
                               head_dim).transpose(0, 2, 3, 1, 4)
                out = _sdpa_chunked_quant(
                    qg, new_cache["k"], new_cache["k_scale"],
                    new_cache["v"], new_cache["v_scale"], positions, k_pos,
                    causal=causal, window=window, attn_cap=attn_cap,
                    scale=1.0 / np.sqrt(head_dim))
                out = out.transpose(0, 3, 1, 2, 4).reshape(
                    B, S, n_heads * head_dim)
                return out @ p["wo"], new_cache
            k, v = cache_kv(new_cache, x.dtype)
            k_pos = jnp.arange(k.shape[2])
        else:
            new_cache = None
            k_pos = positions

    qg = q.reshape(B, S, n_kv_heads, G, head_dim).transpose(0, 2, 3, 1, 4)
    scale = 1.0 / np.sqrt(head_dim)
    kw = dict(causal=causal, window=window, attn_cap=attn_cap, scale=scale)
    if impl == "chunked":
        kw.update(chunk=chunk, unroll=unroll)
    out = _IMPLS[impl](qg, k, v, positions, k_pos, **kw)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, n_heads * head_dim)
    return out @ p["wo"], new_cache


def precompute_cross_kv(p, memory, n_kv_heads: int, head_dim: int):
    """Encoder memory -> (k, v) in [B,KV,S,hd] for decoder cross-attention."""
    B, S, _ = memory.shape
    k = (memory @ p["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (memory @ p["wv"]).reshape(B, S, n_kv_heads, head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

"""A minimal statechart substrate for workload and fault machines.

The chaos harness needs adversarial schedules that *evolve* — skew that
drifts, storms that migrate between shards, sessions that stall and
crash — and those are naturally statecharts: a machine is a named state,
a list of guarded transitions, an event queue, and a seeded PRNG.
Nothing here knows about KV ops or services; :mod:`repro.chaos.machines`
builds the concrete client/fault machines on top.

Determinism is the design constraint (the regression tests assert
byte-identical traces across runs): transitions fire in declaration
order, events process in FIFO order, and all randomness flows through
the machine's own ``numpy`` generator seeded at construction.  Every
processed event — including ones no transition consumed — appends one
tuple to ``machine.trace``, so two runs of a scenario can be compared
event-for-event.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Event:
    """One queued occurrence: a name plus an immutable payload dict."""
    name: str
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __getitem__(self, key: str) -> Any:
        return self.payload[key]

    def get(self, key: str, default: Any = None) -> Any:
        return self.payload.get(key, default)


@dataclasses.dataclass(frozen=True)
class Transition:
    """``source --event[guard]/action--> target``.

    ``guard(machine, event) -> bool`` gates the transition (None = always
    enabled); ``action(machine, event)`` runs side effects on the machine
    when it fires.  ``source`` may be ``"*"`` to match any state."""
    source: str
    event: str
    target: str
    guard: Optional[Callable[["Machine", Event], bool]] = None
    action: Optional[Callable[["Machine", Event], None]] = None


class Machine:
    """One statechart instance: state + transitions + event queue + PRNG.

    Subclasses (or factories) supply the transition table; the driver
    posts events and calls :meth:`process` once per wave.  The first
    declared transition whose source/event/guard all match fires; an
    event no transition consumes is recorded as dropped (``target is
    None`` in the trace) — dropping is normal (e.g. a ``tick`` while
    awaiting a verdict), not an error.
    """

    def __init__(self, name: str, initial: str,
                 transitions: Sequence[Transition], seed: int):
        self.name = name
        self.state = initial
        self.transitions = list(transitions)
        self.rng = np.random.default_rng(seed)
        self.queue: deque = deque()
        # (state_before, event_name, state_after_or_None) per processed event
        self.trace: List[Tuple[str, str, Optional[str]]] = []

    def post(self, event: str, **payload: Any) -> None:
        self.queue.append(Event(event, payload))

    def _match(self, ev: Event) -> Optional[Transition]:
        for t in self.transitions:
            if t.event != ev.name:
                continue
            if t.source != "*" and t.source != self.state:
                continue
            if t.guard is not None and not t.guard(self, ev):
                continue
            return t
        return None

    def process(self) -> int:
        """Drain the event queue; returns the number of fired transitions."""
        fired = 0
        while self.queue:
            ev = self.queue.popleft()
            t = self._match(ev)
            if t is None:
                self.trace.append((self.state, ev.name, None))
                continue
            before = self.state
            if t.action is not None:
                t.action(self, ev)
            self.state = t.target
            self.trace.append((before, ev.name, self.state))
            fired += 1
        return fired

    def trace_lines(self) -> List[str]:
        """The trace in a canonical text form (for byte-level diffing)."""
        return [f"{self.name}:{b}--{e}-->{a if a is not None else '.'}"
                for b, e, a in self.trace]

"""ScenarioDriver: statechart machines x KVService x fault injection.

One scenario run is a synchronous wave loop.  Each wave the driver

1. ticks every client machine (ops land in their outboxes),
2. ticks the fault machines and applies their directives — crash traps
   arm a shard pool's ``crash_after_persists`` budget (the exact idiom
   the structure crash sweeps use), stalls and storms post events back
   to the client machines,
3. submits the outbox ops (recording invocations in the history),
4. runs ONE ``KVService.step()`` wave inside a ``SimulatedCrash``
   handler: on a normal wave newly-completed futures are recorded and
   their owners get ``done`` events; on a crash the service recovers
   in place (``KVService.crash()``: every shard replays its WAL), the
   recovered state is re-adopted into the history, and every in-flight
   client gets a ``crashed`` event (its verdict is lost, not wrong).

After the scheduled waves the driver disarms all traps, drains the
in-flight tail, and hands the history to the linearizability checker.
Every source of nondeterminism is a seeded machine PRNG, so the same
scenario seed reproduces the run event-for-event — the determinism
regression asserts byte-identical traces and final state.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import SimulatedCrash
from repro.obs import SloEngine, SloSpec, instant, span
from repro.service import KVService
from repro.structures import KVOp, SCAN

from .history import CheckStats, HistoryRecorder, check_history
from .machines import (ARM_CRASH, ARM_MIG_CRASH, CALM, ClientMachine,
                       ClientSpec, FaultMachine, FaultSpec, MIGRATE,
                       STALL, STORM)


# the degradation objectives every scenario is judged against WHILE its
# faults fire (one observation per wave; multi-window burn semantics in
# repro.obs.slo).  Bounds are deliberately loose — chaos runs measure
# degradation, not steady-state speed — and the per-family verdict lands
# in ``ChaosReport.slo`` / ``BENCH_chaos.json``.
CHAOS_SLOS = (
    SloSpec("p99_latency_ceiling", "p99_latency_us", 5_000_000.0,
            "ceiling", error_budget=0.2,
            description="client p99 completion latency stays under 5s "
                        "through crashes and storms"),
    SloSpec("throughput_floor", "ops_per_s", 1.0, "floor",
            error_budget=0.34,
            description="completed ops per wall second stays above 1"),
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One reproducible chaos scenario (see :mod:`repro.chaos.scenarios`
    for the named families)."""
    name: str
    family: str
    client: ClientSpec
    faults: Tuple[FaultSpec, ...] = ()
    n_clients: int = 6
    waves: int = 60
    n_shards: int = 2
    n_buckets: int = 32
    backend: str = "durable"
    structure: str = "hashmap"
    load_keys: int = 12            # deterministic pre-populated keys
    round_cap: int = 8
    # prune cadence in waves; the step counter survives crashes (the
    # recovered service carries its ServiceStats), so the cadence fires
    # on schedule regardless of crash spacing
    wal_prune_every: int = 6
    # epoch durability knobs (KVService pass-through): rounds per shared
    # fence and epochs per WAL checkpoint (1/0 = classic per-round mode)
    epoch_rounds: int = 1
    checkpoint_every: int = 0
    seed: int = 0


@dataclasses.dataclass
class ChaosReport:
    """Outcome of one scenario run."""
    scenario: Scenario
    waves_run: int = 0
    ops_invoked: int = 0
    ops_completed: int = 0
    crashes: int = 0
    faults_fired: int = 0
    migrations: int = 0            # key-range migrations decided
    wal_records: int = 0           # descriptor records left across shards
    wal_pruned: int = 0
    elapsed_s: float = 0.0
    p99_latency_us: float = 0.0    # final client p99 (stats survive crashes)
    # per-family degradation verdict: the SLO report evaluated DURING
    # the fault schedule (None only if the run never reached the loop)
    slo: Optional[Dict] = None
    check: Optional[CheckStats] = None
    trace_lines: List[str] = dataclasses.field(default_factory=list)
    final_items: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def ops_per_s(self) -> float:
        return self.ops_completed / self.elapsed_s if self.elapsed_s else 0.0

    def summary(self) -> str:
        c = self.check
        verdict = ("LINEARIZABLE" if c is not None and c.ok else "UNCHECKED")
        return (f"{self.scenario.name}: {verdict} — "
                f"{self.ops_completed}/{self.ops_invoked} ops in "
                f"{self.waves_run} waves, {self.crashes} crashes, "
                f"{self.faults_fired} faults fired"
                + (f"; checked {c.immediates} immediates + {c.mutations} "
                   f"mutations, {c.indeterminate} indeterminate"
                   if c is not None else ""))


class ScenarioDriver:
    """Run one :class:`Scenario` to completion (see module docstring)."""

    # drain budget after the scheduled waves: in-flight ops retry under
    # the service's own EXHAUSTED bound, so this only guards a stuck loop
    DRAIN_CAP = 512

    def __init__(self, scenario: Scenario,
                 durable_root=None):
        self.scenario = scenario
        self._tmpdir = None
        if durable_root is None and scenario.backend == "durable":
            # durable scenarios need a root the DRIVER owns: the
            # migration decision log derives from it, and a crash must
            # find the same pools again (auto-cleaned on GC)
            self._tmpdir = tempfile.TemporaryDirectory(prefix="chaos_run_")
            durable_root = self._tmpdir.name
        self.durable_root = durable_root
        sc = scenario
        self.clients = [
            ClientMachine(f"c{i}", sc.client, seed=sc.seed * 1000 + i)
            for i in range(sc.n_clients)]
        self.faults = [
            FaultMachine(fs, seed=sc.seed * 1000 + 500 + j)
            for j, fs in enumerate(sc.faults)]
        self.recorder = HistoryRecorder()
        self.report = ChaosReport(scenario=sc)
        self.svc: Optional[KVService] = None
        # outstanding futures: (future, owning client, driver-global seq)
        # — the driver numbers ops itself because KVService.crash()
        # rebuilds the service and restarts its internal sequence
        self._outstanding: List[Tuple[object, ClientMachine, int]] = []
        self._seq = 0
        # service-step -> driver-wave map: with epoch durability, an ack
        # can be withheld for waves after its verdict was decided; the
        # history records the DECIDED wave (fut.done_step), where the
        # op's effect became visible to later reads
        self._wave_of_step: Dict[int, int] = {}

    # -- service plumbing ------------------------------------------------------
    def _build_service(self) -> KVService:
        sc = self.scenario
        return KVService(sc.n_shards, structure=sc.structure,
                         backend=sc.backend, n_buckets=sc.n_buckets,
                         round_cap=sc.round_cap,
                         durable_root=self.durable_root,
                         wal_prune_every=sc.wal_prune_every,
                         epoch_rounds=sc.epoch_rounds,
                         checkpoint_every=sc.checkpoint_every)

    def _load_phase(self) -> None:
        """Deterministic pre-population, recorded as the checker's base."""
        sc = self.scenario
        rng = np.random.default_rng(sc.seed + 0xC0A5)
        keys = rng.permutation(sc.client.n_keys)[:sc.load_keys]
        ops = [KVOp("insert", int(k) + 1, int(rng.integers(1, 1 << 20)))
               for k in keys]
        self.svc.apply(ops)
        self.recorder.base(self.svc.check_integrity())

    def _arm_crash(self, shard: int, persists_ahead: int) -> None:
        pool = getattr(self.svc.backends[shard], "pool", None)
        if pool is not None:                   # durable shards only
            pool.crash_after = pool.persist_count + persists_ahead

    def _disarm_all(self) -> None:
        for b in self.svc.backends:
            pool = getattr(b, "pool", None)
            if pool is not None:
                pool.crash_after = None
        if self.svc.mig_pool is not None:
            self.svc.mig_pool.crash_after = None

    def _wal_record_count(self) -> int:
        total = 0
        for b in self.svc.backends:
            pool = getattr(b, "pool", None)
            if pool is not None:
                total += len(pool.listdir("wal"))
        return total

    # -- wave mechanics --------------------------------------------------------
    def _apply_directives(self) -> None:
        for fm in self.faults:
            for d in fm.drain_directives():
                # every injected fault is an instant event: the chaos
                # trace shows faults inline with the service waves
                if d[0] == ARM_CRASH:
                    instant("chaos.fault", kind="crash_trap", shard=d[1],
                            persists_ahead=d[2])
                    self._arm_crash(d[1], d[2])
                elif d[0] == STALL:
                    instant("chaos.fault", kind="stall", client=d[1],
                            waves=d[2])
                    self.clients[d[1]].post("stall", waves=d[2])
                elif d[0] == STORM:
                    instant("chaos.fault", kind="storm", shard=d[1])
                    for c in self.clients:
                        c.post("storm", shard=d[1])
                elif d[0] == CALM:
                    instant("chaos.fault", kind="calm")
                    for c in self.clients:
                        c.post("calm")
                elif d[0] == MIGRATE:
                    instant("chaos.fault", kind="migrate", lo=d[1],
                            hi=d[2], dst=d[3])
                    try:
                        # the decide persist runs here; an armed trap may
                        # spring on it (caller handles SimulatedCrash)
                        self.svc.start_migration(d[1], d[2], d[3])
                        self.report.migrations += 1
                    except RuntimeError:
                        pass       # overlaps an in-flight migration: skip
                elif d[0] == ARM_MIG_CRASH:
                    instant("chaos.fault", kind="mig_crash_trap",
                            persists_ahead=d[1])
                    pool = self.svc.mig_pool
                    if pool is not None:
                        pool.crash_after = pool.persist_count + d[1]

    def _submit_outboxes(self, wave: int) -> int:
        scans = 0
        for c in self.clients:
            if c.outbox is None:
                continue
            op, c.outbox = c.outbox, None
            fut = self.svc.submit(op, client=c.name)
            self._seq += 1
            self.recorder.invoke(wave, c.name, self._seq, op.kind,
                                 op.key, op.value)
            self.report.ops_invoked += 1
            self._outstanding.append((fut, c, self._seq))
            if op.kind == SCAN:
                scans += 1
        return scans

    def _collect_completions(self, wave: int) -> int:
        done = 0
        still = []
        for fut, c, seq in self._outstanding:
            if fut.done:
                decided = self._wave_of_step.get(
                    getattr(fut, "done_step", None), wave)
                self.recorder.complete(decided, seq, fut.result.status,
                                       fut.result.value)
                c.post("done", status=fut.result.status)
                c.process()
                self.report.ops_completed += 1
                done += 1
            else:
                still.append((fut, c, seq))
        self._outstanding = still
        return done

    def _handle_crash(self, wave: int) -> None:
        self.report.crashes += 1
        instant("chaos.fault", kind="crash", wave=wave)
        self.recorder.crash(wave)
        # the recovered service carries its stats (monotone counters),
        # so the prune count is read once, at end of run
        with span("chaos.crash_recover", wave=wave):
            self.svc = self.svc.crash()        # per-shard WAL replay
        self._disarm_all()                     # fresh pools carry no trap
        self.recorder.adopt(wave, self.svc.check_integrity())
        for _fut, c, _seq in self._outstanding:  # verdicts lost, not wrong
            c.post("crashed")
            c.process()
        self._outstanding = []
        for fm in self.faults:
            fm.post("crash", wave=wave)
            fm.process()

    def _step_wave(self, wave: int, scans_pending: int) -> None:
        for fm in self.faults:
            fm.post("tick", wave=wave, scans_pending=scans_pending)
            fm.process()
        try:
            # directive application can itself persist (a MIGRATE's
            # decide record) and spring a previously-armed trap
            self._apply_directives()
            self.svc.step()
        except SimulatedCrash:
            self._handle_crash(wave)
            return
        self._wave_of_step.setdefault(self.svc.stats.steps, wave)
        self._collect_completions(wave)

    # -- entry point -----------------------------------------------------------
    def run(self) -> ChaosReport:
        sc = self.scenario
        t0 = time.monotonic()
        # SLOs are judged DURING the fault schedule, one observation per
        # wave — degradation inside the windows is the measurement
        slo_engine = SloEngine(CHAOS_SLOS, short_window=8, long_window=32)
        with span("chaos.scenario", scenario=sc.name,
                  family=sc.family) as sp:
            self.svc = self._build_service()
            self._load_phase()
            wave = 0
            for wave in range(1, sc.waves + 1):
                for c in self.clients:
                    c.post("tick", wave=wave)
                    c.process()
                scans = self._submit_outboxes(wave)
                self._step_wave(wave, scans)
                elapsed = time.monotonic() - t0
                slo_engine.observe({
                    "p99_latency_us": self.svc.stats.p99_latency_us,
                    "ops_per_s": (self.report.ops_completed / elapsed
                                  if elapsed > 0 else 0.0)})
            # drain the in-flight tail with faults disarmed (clients
            # issue nothing new; the EXHAUSTED bound caps retries)
            self._disarm_all()
            for extra in range(self.DRAIN_CAP):
                if not self._outstanding and not self.svc._migrations:
                    break
                wave += 1
                try:
                    self.svc.step()
                except SimulatedCrash:         # a pre-armed trap's tail
                    self._handle_crash(wave)
                    continue
                self._wave_of_step.setdefault(self.svc.stats.steps, wave)
                self._collect_completions(wave)
            if self._outstanding:
                raise RuntimeError(
                    f"{sc.name}: {len(self._outstanding)} ops still in "
                    f"flight after {self.DRAIN_CAP} drain waves")
            self.report.waves_run = wave
            self.report.final_items = self.svc.check_integrity()
            self.recorder.final(self.report.final_items)
            self.report.faults_fired = sum(fm.fired for fm in self.faults)
            self.report.wal_records = self._wal_record_count()
            self.report.wal_pruned += self.svc.stats.wal_pruned
            self.report.p99_latency_us = self.svc.stats.p99_latency_us
            self.report.slo = slo_engine.report(
                section=f"chaos.{sc.family}")
            sp.set(waves=wave, crashes=self.report.crashes,
                   slo_ok=self.report.slo["ok"])
        self.report.elapsed_s = time.monotonic() - t0
        self.report.trace_lines = self.trace_lines()
        self.report.check = check_history(self.recorder.events)
        return self.report

    def trace_lines(self) -> List[str]:
        """Canonical text trace: every machine's statechart trace plus
        the history events, byte-comparable across runs."""
        lines: List[str] = []
        for m in self.clients + self.faults:
            lines.extend(m.trace_lines())
        lines.extend(self.recorder.canonical_lines())
        return lines

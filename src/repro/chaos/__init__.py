"""repro.chaos — statechart-driven workload & fault harness with
linearizability checking.

The layer above :mod:`repro.service`: adversarial *scenarios* instead of
static workloads.  Seeded statechart machines drive client sessions
(drifting Zipf skew, storm targeting, think/await pacing) and fault
processes (crash-at-persist traps, crash-mid-scan, stragglers, shard
storms); a :class:`ScenarioDriver` runs them against a live
:class:`repro.service.KVService` wave by wave, injecting crashes and
recovering in place; every completed verdict lands in a history the
linearizability checker validates against a sequential oracle
(DESIGN.md Sec. 10 explains why wave order makes that check linear-time).

Public surface::

    from repro.chaos import chaos_sweep
    for report in chaos_sweep(seed=1):
        print(report.summary())

Everything is deterministic per scenario seed — byte-identical traces
and final state across runs, including across crash/recover cycles.
"""
from .statechart import Event, Machine, Transition
from .machines import (ARM_CRASH, ARM_MIG_CRASH, CALM, CRASH_AT_PERSIST,
                       CRASH_MID_MIGRATION, CRASH_MID_SCAN, ClientMachine,
                       ClientSpec, EPOCH_BOUNDARY, FAULT_KINDS,
                       FaultMachine, FaultSpec, MIGRATE, SHARD_STORM,
                       STALL, STORM, STRAGGLER)
from .history import (CheckStats, HistoryRecorder, LinearizabilityError,
                      check_history)
from .driver import ChaosReport, Scenario, ScenarioDriver
from .scenarios import (FAMILIES, chaos_sweep, crash_mid_migration,
                        crash_mid_scan, default_scenarios, drifting_skew,
                        epoch_boundary, hot_key_storm, run_scenario,
                        sim_native, straggler)

__all__ = [
    "Event", "Machine", "Transition",
    "ClientMachine", "ClientSpec", "FaultMachine", "FaultSpec",
    "FAULT_KINDS", "CRASH_AT_PERSIST", "CRASH_MID_SCAN", "STRAGGLER",
    "SHARD_STORM", "CRASH_MID_MIGRATION", "EPOCH_BOUNDARY",
    "ARM_CRASH", "STALL", "STORM", "CALM", "MIGRATE", "ARM_MIG_CRASH",
    "HistoryRecorder", "check_history", "CheckStats",
    "LinearizabilityError",
    "Scenario", "ScenarioDriver", "ChaosReport",
    "FAMILIES", "default_scenarios", "run_scenario", "chaos_sweep",
    "hot_key_storm", "crash_mid_scan", "straggler", "drifting_skew",
    "crash_mid_migration", "epoch_boundary", "sim_native",
]

"""Completed-operation history recording + linearizability checking.

The recorder logs one flat, json-able event stream per scenario run:

- ``("base", items)`` — the state after the load phase (checked inserts)
- ``("invoke", wave, client, seq, kind, key, value)`` — op submitted
- ``("complete", wave, seq, status, value)`` — verdict observed
- ``("crash", wave)`` — the wave's execution died in ``SimulatedCrash``
- ``("adopt", wave, items)`` — recovered state re-adopted as the model
- ``("final", items)`` — the drained service's live items

Why checking is cheap (DESIGN.md Sec. 10): the service executes in
synchronous waves, and a wave gives the commit order away — reads,
scans and other immediate verdicts are compiled against the wave-start
snapshot *before* any CAS executes, every committed mutation completes
in the wave its round won, and the conflict-defer rule admits at most
one committed mutation per key per wave.  So the sequential oracle is a
dict replayed wave by wave: check the wave's immediate verdicts against
the model, then apply its committed mutations (each with its
precondition) — per-key order verification, no interleaving search.

Crashes make verdicts indeterminate, not wrong: ops invoked but never
completed may or may not have committed.  On ``adopt`` the checker
accepts any recovered per-key value reachable from the model through
some subset/order of the in-flight mutations for that key (a fixpoint
closure — a deliberate over-approximation across keys, since round
atomicity only ties keys together in ways that shrink the real set),
then *adopts* the recovered state and keeps checking — the in-place
recovery continuation the chaos driver performs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

DELETE, INSERT, READ, SCAN, UPDATE = ("delete", "insert", "read", "scan",
                                      "update")
OK, EXISTS, NOT_FOUND = "ok", "exists", "not_found"
MUTATIONS = (INSERT, UPDATE, DELETE)


class LinearizabilityError(AssertionError):
    """A completed verdict no sequential execution can explain."""


def _items_list(items: Dict[int, int]) -> List[List[int]]:
    return [[int(k), int(v)] for k, v in sorted(items.items())]


class HistoryRecorder:
    """Append-only event log for one scenario run (see module docstring)."""

    def __init__(self):
        self.events: List[Tuple] = []

    def base(self, items: Dict[int, int]) -> None:
        self.events.append(("base", _items_list(items)))

    def invoke(self, wave: int, client: str, seq: int, kind: str,
               key: int, value: int) -> None:
        self.events.append(("invoke", wave, client, seq, kind, key, value))

    def complete(self, wave: int, seq: int, status: str,
                 value: Optional[int]) -> None:
        self.events.append(("complete", wave, seq, status, value))

    def crash(self, wave: int) -> None:
        self.events.append(("crash", wave))

    def adopt(self, wave: int, items: Dict[int, int]) -> None:
        self.events.append(("adopt", wave, _items_list(items)))

    def final(self, items: Dict[int, int]) -> None:
        self.events.append(("final", _items_list(items)))

    def canonical_lines(self) -> List[str]:
        """One canonical text line per event (byte-comparable across
        runs — the determinism regression diffs these)."""
        return [json.dumps(list(ev), separators=(",", ":"))
                for ev in self.events]


@dataclasses.dataclass
class CheckStats:
    """What one checker pass covered."""
    immediates: int = 0          # read/scan/exists/not-found verdicts checked
    mutations: int = 0           # committed mutations applied with precondition
    unchecked: int = 0           # FULL / EXHAUSTED verdicts (capacity-defined)
    crashes: int = 0
    indeterminate: int = 0       # in-flight ops dropped by a crash
    ok: bool = True


def _reachable(base: Optional[int], muts: Sequence[Tuple[str, int]]):
    """Per-key closure: every value reachable from ``base`` through some
    subset/order of the in-flight mutations (None = key absent)."""
    seen = {base}
    frontier = [base]
    while frontier:
        v = frontier.pop()
        for kind, val in muts:
            if kind == INSERT and v is None:
                nxt = val
            elif kind == UPDATE and v is not None:
                nxt = val
            elif kind == DELETE and v is not None:
                nxt = None
            else:
                continue
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    return seen


def check_history(events: Sequence[Tuple]) -> CheckStats:
    """Validate one recorded history against the sequential oracle.

    Raises :class:`LinearizabilityError` on the first verdict (or
    recovered state) no sequential per-key execution can explain;
    returns coverage stats otherwise."""
    stats = CheckStats()
    model: Dict[int, int] = {}
    pending: Dict[int, Tuple[str, str, int, int]] = {}   # seq -> invocation
    buffered: List[Tuple] = []                           # one wave's completes
    buf_wave: Optional[int] = None

    def fail(msg: str) -> None:
        stats.ok = False
        raise LinearizabilityError(msg)

    def check_immediate(wave, seq, kind, key, value, status, val) -> None:
        if status in ("full", "exhausted"):
            stats.unchecked += 1
            return
        stats.immediates += 1
        if kind == READ:
            if status == OK and model.get(key) != val:
                fail(f"wave {wave} seq {seq}: read({key}) returned {val}, "
                     f"model holds {model.get(key)}")
            if status == NOT_FOUND and key in model:
                fail(f"wave {wave} seq {seq}: read({key}) missed but model "
                     f"holds {model[key]}")
        elif kind == SCAN:
            want = sum(1 for k in model if k >= key)
            if status != OK or val != want:
                fail(f"wave {wave} seq {seq}: scan(>={key}) counted {val}, "
                     f"model counts {want}")
        elif kind == INSERT and status == EXISTS:
            if model.get(key) != val:
                fail(f"wave {wave} seq {seq}: insert({key}) saw EXISTS with "
                     f"{val}, model holds {model.get(key)}")
        elif kind in (UPDATE, DELETE) and status == NOT_FOUND:
            if key in model:
                fail(f"wave {wave} seq {seq}: {kind}({key}) missed but "
                     f"model holds {model[key]}")
        else:
            fail(f"wave {wave} seq {seq}: inexplicable verdict "
                 f"{kind}/{status}")

    def flush() -> None:
        nonlocal buffered, buf_wave
        if not buffered:
            return
        wave = buf_wave
        immediates, mutations = [], []
        for (_, w, seq, status, val) in buffered:
            if seq not in pending:
                fail(f"wave {w} seq {seq}: completion without invocation")
            inv = pending.pop(seq)
            _client, kind, key, value = inv
            if kind in MUTATIONS and status == OK:
                mutations.append((w, seq, kind, key, value))
            else:
                immediates.append((w, seq, kind, key, value, status, val))
        # immediate verdicts saw the wave-start snapshot: check first
        for im in immediates:
            check_immediate(*im)
        # then the wave's committed mutations (conflict-defer admits at
        # most one per key per wave, so intra-wave order is irrelevant)
        touched = set()
        for (w, seq, kind, key, value) in mutations:
            if key in touched:
                fail(f"wave {w}: two mutations committed on key {key} "
                     "in one wave (conflict-defer violated)")
            touched.add(key)
            stats.mutations += 1
            if kind == INSERT:
                if key in model:
                    fail(f"wave {w} seq {seq}: insert({key}) committed "
                         f"over live value {model[key]}")
                model[key] = value
            elif kind == UPDATE:
                if key not in model:
                    fail(f"wave {w} seq {seq}: update({key}) committed "
                         "on an absent key")
                model[key] = value
            else:
                if key not in model:
                    fail(f"wave {w} seq {seq}: delete({key}) committed "
                         "on an absent key")
                del model[key]
        buffered, buf_wave = [], None

    for ev in events:
        tag = ev[0]
        if tag == "base":
            model = {k: v for k, v in ev[1]}
        elif tag == "invoke":
            flush()
            _, wave, client, seq, kind, key, value = ev
            pending[seq] = (client, kind, key, value)
        elif tag == "complete":
            if buf_wave is not None and ev[1] != buf_wave:
                flush()
            buf_wave = ev[1]
            buffered.append(ev)
        elif tag == "crash":
            flush()
            stats.crashes += 1
        elif tag == "adopt":
            flush()
            _, wave, items = ev
            adopted = {k: v for k, v in items}
            per_key: Dict[int, List[Tuple[str, int]]] = {}
            for (_client, kind, key, value) in pending.values():
                if kind in MUTATIONS:
                    per_key.setdefault(key, []).append((kind, value))
            for key in set(model) | set(adopted) | set(per_key):
                okvals = _reachable(model.get(key), per_key.get(key, []))
                if adopted.get(key) not in okvals:
                    fail(f"wave {wave}: recovered value {adopted.get(key)} "
                         f"for key {key} unreachable from {model.get(key)} "
                         f"under in-flight ops {per_key.get(key, [])}")
            stats.indeterminate += len(pending)
            pending.clear()          # in-flight verdicts died with the crash
            model = adopted
        elif tag == "final":
            flush()
            if pending:
                fail(f"history ended with {len(pending)} ops never "
                     "completed (and no crash to explain them)")
            final = {k: v for k, v in ev[1]}
            if final != model:
                fail(f"final items {final} != model {model}")
        else:
            fail(f"unknown history event {tag!r}")
    flush()
    return stats

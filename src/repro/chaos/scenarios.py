"""The named scenario families and the chaos sweep entry point.

Each family is a factory returning a :class:`repro.chaos.Scenario`; the
durable-backed families all carry a crash schedule (the acceptance bar:
crash/recover cycles under every workload shape), plus their own twist:

- :func:`hot_key_storm` — a shard-targeted storm migrates between
  shards while crashes land mid-storm (contention + recovery).
- :func:`crash_mid_scan` — scan-heavy clients; the trap only springs on
  waves with a scan in flight, so lost verdicts include range reads.
- :func:`straggler` — a fault machine keeps stalling one client, so
  in-flight windows span many waves when the crash lands.
- :func:`drifting_skew` — the Zipf-hot keys rotate through the keyspace
  on a cadence (the skew the paper's static Eq. 1 workloads never move).
- :func:`crash_mid_migration` — online key-range shard migrations under
  live traffic, with crashes scheduled into the copy and the swing; the
  decision log must leave every migration invisible or completed.
- :func:`epoch_boundary` — epoch durability on (rounds share one fence,
  acks held behind open epochs), with crashes aimed at the epoch-close
  and checkpoint persists; acked ops must survive every landing.
- :func:`sim_native` — the same client machines on SIM-backed shards:
  full KV ops on the cycle-accurate micro-op machines (native desired
  values), no crash faults (the simulator models cores, not pools).

``chaos_sweep`` runs a list of scenarios (default: all seven) and
returns their reports; every history must check out linearizable.
"""
from __future__ import annotations

import tempfile
from typing import List, Optional, Sequence

from .driver import ChaosReport, Scenario, ScenarioDriver
from .machines import (CRASH_AT_PERSIST, CRASH_MID_MIGRATION,
                       CRASH_MID_SCAN, ClientSpec, EPOCH_BOUNDARY,
                       FaultSpec, SHARD_STORM, STRAGGLER)


def _crash(n_shards: int, *, first_wave: int = 8, gap_lo: int = 10,
           gap_hi: int = 18, persists_hi: int = 14) -> FaultSpec:
    return FaultSpec(kind=CRASH_AT_PERSIST, n_shards=n_shards,
                     first_wave=first_wave, gap_lo=gap_lo, gap_hi=gap_hi,
                     persists_hi=persists_hi)


def hot_key_storm(seed: int = 0, waves: int = 60) -> Scenario:
    n_shards = 2
    client = ClientSpec(n_keys=32, alpha=1.1, read=0.35, update=0.3,
                        insert=0.2, delete=0.1, scan=0.05,
                        storm_bias=0.9, n_shards=n_shards)
    return Scenario(
        name=f"hot_key_storm/s{seed}", family="hot_key_storm",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        faults=(FaultSpec(kind=SHARD_STORM, n_shards=n_shards,
                          first_wave=5, storm_len=10, gap_lo=6, gap_hi=10),
                _crash(n_shards, first_wave=12)))


def crash_mid_scan(seed: int = 0, waves: int = 60) -> Scenario:
    n_shards = 2
    client = ClientSpec(n_keys=32, alpha=0.6, read=0.25, update=0.2,
                        insert=0.15, delete=0.1, scan=0.3,
                        n_shards=n_shards)
    return Scenario(
        name=f"crash_mid_scan/s{seed}", family="crash_mid_scan",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        faults=(FaultSpec(kind=CRASH_MID_SCAN, n_shards=n_shards,
                          first_wave=6, gap_lo=10, gap_hi=16),))


def straggler(seed: int = 0, waves: int = 60) -> Scenario:
    n_shards = 2
    client = ClientSpec(n_keys=32, alpha=0.9, read=0.4, update=0.25,
                        insert=0.2, delete=0.1, scan=0.05,
                        think_hi=3, n_shards=n_shards)
    return Scenario(
        name=f"straggler/s{seed}", family="straggler",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        faults=(FaultSpec(kind=STRAGGLER, n_shards=n_shards,
                          n_clients=6, first_wave=4, gap_lo=4, gap_hi=8,
                          stall_waves=8),
                _crash(n_shards, first_wave=14)))


def drifting_skew(seed: int = 0, waves: int = 60) -> Scenario:
    n_shards = 2
    client = ClientSpec(n_keys=32, alpha=1.2, read=0.4, update=0.25,
                        insert=0.2, delete=0.1, scan=0.05,
                        drift_every=6, drift_step=5, n_shards=n_shards)
    return Scenario(
        name=f"drifting_skew/s{seed}", family="drifting_skew",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        faults=(_crash(n_shards, first_wave=10),))


def crash_mid_migration(seed: int = 0, waves: int = 60) -> Scenario:
    """Online key-range shard migrations under client traffic, with
    crashes scheduled INTO the migration: half trap the decision log's
    own persists (decide / swing), half a shard WAL pool (mid-copy).
    Recovery must leave each migration invisible or completed — the
    history stays linearizable either way (a migration moves keys, it
    never changes a value)."""
    n_shards = 3
    client = ClientSpec(n_keys=32, alpha=0.9, read=0.4, update=0.25,
                        insert=0.2, delete=0.1, scan=0.05,
                        n_shards=n_shards)
    return Scenario(
        name=f"crash_mid_migration/s{seed}", family="crash_mid_migration",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        faults=(FaultSpec(kind=CRASH_MID_MIGRATION, n_shards=n_shards,
                          n_keys=32, first_wave=6, gap_lo=8, gap_hi=14,
                          persists_lo=2, persists_hi=10, storm_len=10),))


def epoch_boundary(seed: int = 0, waves: int = 60) -> Scenario:
    """Crashes aimed at epoch-close/checkpoint fences, with epoch
    durability ON (``epoch_rounds=4``, ``checkpoint_every=2``).  Under
    the epoch protocol nearly every persist a shard issues IS an epoch
    boundary, so a small ``persists_ahead`` budget (1..3) lands the
    crash exactly on one.  The service withholds acks behind open
    epochs, so every acked op must survive — the checker sees lost
    in-flight verdicts as indeterminate, never a revoked ack — and the
    epoch checkpoints must keep the WAL bounded despite the crashes."""
    n_shards = 2
    client = ClientSpec(n_keys=32, alpha=0.9, read=0.4, update=0.25,
                        insert=0.2, delete=0.1, scan=0.05,
                        n_shards=n_shards)
    return Scenario(
        name=f"epoch_boundary/s{seed}", family="epoch_boundary",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        epoch_rounds=4, checkpoint_every=2, wal_prune_every=0,
        faults=(FaultSpec(kind=EPOCH_BOUNDARY, n_shards=n_shards,
                          first_wave=8, gap_lo=10, gap_hi=16,
                          persists_lo=1, persists_hi=3),))


def sim_native(seed: int = 0, waves: int = 40) -> Scenario:
    """KV chaos on SIM-backed shards: the native-desired-value path —
    real inserts/updates/deletes (keys, values, TOMBSTONEs) running on
    the cycle-accurate state machines, no shadow words."""
    n_shards = 2
    client = ClientSpec(n_keys=24, alpha=0.9, read=0.4, update=0.25,
                        insert=0.2, delete=0.1, scan=0.05,
                        drift_every=8, drift_step=3, n_shards=n_shards)
    return Scenario(
        name=f"sim_native/s{seed}", family="sim_native",
        client=client, waves=waves, n_shards=n_shards, seed=seed,
        backend="sim", n_buckets=24, wal_prune_every=0)


FAMILIES = {
    "hot_key_storm": hot_key_storm,
    "crash_mid_scan": crash_mid_scan,
    "straggler": straggler,
    "drifting_skew": drifting_skew,
    "crash_mid_migration": crash_mid_migration,
    "epoch_boundary": epoch_boundary,
    "sim_native": sim_native,
}


def default_scenarios(seed: int = 0, waves: int = 60) -> List[Scenario]:
    out = [make(seed=seed, waves=waves) for name, make in FAMILIES.items()
           if name != "sim_native"]
    out.append(sim_native(seed=seed, waves=max(20, waves // 2)))
    return out


def run_scenario(scenario: Scenario, durable_root=None) -> ChaosReport:
    """Run one scenario; durable scenarios get a temp root when none is
    given (auto-cleaned per-shard pools)."""
    return ScenarioDriver(scenario, durable_root=durable_root).run()


def chaos_sweep(scenarios: Optional[Sequence[Scenario]] = None, *,
                seed: int = 0, waves: int = 60,
                durable_root=None) -> List[ChaosReport]:
    """Run every scenario (default: every family) and check every
    history.  Raises :class:`repro.chaos.LinearizabilityError` on the
    first violation — a passing sweep IS the correctness claim."""
    scenarios = (default_scenarios(seed=seed, waves=waves)
                 if scenarios is None else list(scenarios))
    reports = []
    with tempfile.TemporaryDirectory(prefix="chaos_") as tmp:
        for i, sc in enumerate(scenarios):
            root = (None if durable_root is None and sc.backend != "durable"
                    else f"{durable_root or tmp}/run{i}")
            reports.append(run_scenario(sc, durable_root=root))
    return reports

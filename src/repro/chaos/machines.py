"""Concrete statechart machines: workload clients and fault injectors.

:class:`ClientMachine` is one logical client session: it thinks for a
few waves, issues one KV op, awaits the verdict, and repeats.  Its
key-distribution state is itself part of the statechart — a Zipf rank
permutation whose hot end *drifts* on a cadence, and an optional
shard-targeted storm mode where draws concentrate on keys routing to one
victim shard (the router's own hash decides which keys those are).

:class:`FaultMachine` produces adversarial *directives* the driver
applies to the service: arm a crash a few persists ahead on some shard
(the ``crash_after_persists`` trap the structure crash sweeps use),
crash specifically while a scan is in flight, stall a straggler client,
or start/stop a shard-targeted storm.  Directives accumulate in
``machine.directives`` and are drained by the driver each wave — the
machine never touches the service itself, which keeps fault scheduling
replayable from the trace.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.pmwcas import zipf_probs
from repro.structures import KVOp, key_shard

from .statechart import Machine, Transition

DELETE, INSERT, READ, SCAN, UPDATE = ("delete", "insert", "read", "scan",
                                      "update")


@dataclasses.dataclass(frozen=True)
class ClientSpec:
    """Mix + skew + pacing of one client session (fractions sum to 1)."""
    n_keys: int = 32
    read: float = 0.4
    update: float = 0.25
    insert: float = 0.2
    delete: float = 0.1
    scan: float = 0.05
    alpha: float = 0.9             # Zipf skew of key popularity
    think_lo: int = 0              # waves between verdict and next issue
    think_hi: int = 2
    drift_every: int = 0           # rotate the hot ranks every N waves
    drift_step: int = 0
    storm_bias: float = 0.85       # P(draw a victim-shard key) in a storm
    n_shards: int = 1              # router fan-out (for storm targeting)

    def __post_init__(self):
        total = self.read + self.update + self.insert + self.delete \
            + self.scan
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"mix fractions sum to {total}, need 1.0")


class ClientMachine(Machine):
    """think --issue--> await --done/crashed--> think, forever.

    The op the machine wants executed this wave sits in ``outbox`` after
    a ``tick`` fires the issue transition; the driver submits it and
    posts ``done`` (with the verdict) or ``crashed`` (verdict lost) when
    the service answers.  Key draws follow a seeded Zipf over a private
    rank permutation; ``drift_every``/``drift_step`` rotate which keys
    are hot, and a fault-machine storm re-biases draws onto one shard.
    """

    KINDS = (READ, UPDATE, INSERT, DELETE, SCAN)

    def __init__(self, name: str, spec: ClientSpec, seed: int):
        self.spec = spec
        transitions = [
            Transition("think", "tick", "await",
                       guard=lambda m, e: m.think_left <= 0,
                       action=ClientMachine._issue),
            Transition("think", "tick", "think",
                       action=ClientMachine._idle_tick),
            Transition("await", "tick", "await",
                       action=ClientMachine._idle_tick),
            Transition("await", "done", "think",
                       action=ClientMachine._done),
            Transition("await", "crashed", "think",
                       action=ClientMachine._crashed),
            Transition("think", "storm", "think",
                       action=ClientMachine._storm),
            Transition("await", "storm", "await",
                       action=ClientMachine._storm),
            Transition("think", "calm", "think",
                       action=ClientMachine._calm),
            Transition("await", "calm", "await",
                       action=ClientMachine._calm),
            Transition("think", "stall", "think",
                       action=ClientMachine._stall),
            Transition("await", "stall", "await",
                       action=ClientMachine._stall),
        ]
        super().__init__(name, "think", transitions, seed)
        self._probs = zipf_probs(spec.n_keys, spec.alpha)
        self._perm = self.rng.permutation(spec.n_keys)
        self._mix = [spec.read, spec.update, spec.insert, spec.delete,
                     spec.scan]
        # keys (1-based) owned by each shard, for storm targeting
        self._shard_keys: List[List[int]] = [[] for _ in range(spec.n_shards)]
        for key in range(1, spec.n_keys + 1):
            self._shard_keys[key_shard(key, spec.n_shards)].append(key)
        self.hot_offset = 0
        self.storm_shard: Optional[int] = None
        self.stall_bonus = 0
        self.think_left = int(self.rng.integers(spec.think_lo,
                                                spec.think_hi + 1))
        self.outbox: Optional[KVOp] = None
        self.issued = 0
        self.lost_to_crash = 0

    # -- draws -----------------------------------------------------------------
    def _draw_key(self) -> int:
        if self.storm_shard is not None and \
                self._shard_keys[self.storm_shard] and \
                self.rng.random() < self.spec.storm_bias:
            victims = self._shard_keys[self.storm_shard]
            return victims[int(self.rng.integers(len(victims)))]
        rank = int(self.rng.choice(self.spec.n_keys, p=self._probs))
        return int((self._perm[rank] + self.hot_offset)
                   % self.spec.n_keys) + 1

    def _draw_op(self) -> KVOp:
        kind = self.KINDS[int(self.rng.choice(5, p=self._mix))]
        key = self._draw_key()
        value = int(self.rng.integers(1, 1 << 20))
        return KVOp(kind, key, value if kind in (INSERT, UPDATE) else 0)

    def _drift(self, ev) -> None:
        sp = self.spec
        if sp.drift_every and ev["wave"] % sp.drift_every == 0:
            self.hot_offset = (self.hot_offset + sp.drift_step) % sp.n_keys

    # -- transition actions ----------------------------------------------------
    def _issue(self, ev) -> None:
        self._drift(ev)
        self.outbox = self._draw_op()
        self.issued += 1

    def _idle_tick(self, ev) -> None:
        self._drift(ev)
        if self.state == "think":
            self.think_left -= 1

    def _rethink(self) -> None:
        sp = self.spec
        self.think_left = int(self.rng.integers(
            sp.think_lo, sp.think_hi + 1)) + self.stall_bonus
        self.stall_bonus = 0

    def _done(self, ev) -> None:
        self._rethink()

    def _crashed(self, ev) -> None:
        self.lost_to_crash += 1
        self._rethink()

    def _storm(self, ev) -> None:
        self.storm_shard = int(ev["shard"])

    def _calm(self, ev) -> None:
        self.storm_shard = None

    def _stall(self, ev) -> None:
        self.stall_bonus += int(ev["waves"])


# ---------------------------------------------------------------------------
# Fault machines
# ---------------------------------------------------------------------------

# directive vocabulary the driver consumes (first tuple element)
ARM_CRASH = "arm_crash"        # (ARM_CRASH, shard, persists_ahead)
STALL = "stall"                # (STALL, client_index, waves)
STORM = "storm"                # (STORM, shard)
CALM = "calm"                  # (CALM,)
MIGRATE = "migrate"            # (MIGRATE, lo, hi, dst_shard)
ARM_MIG_CRASH = "arm_mig_crash"  # (ARM_MIG_CRASH, persists_ahead)

CRASH_AT_PERSIST = "crash_at_persist"
CRASH_MID_SCAN = "crash_mid_scan"
STRAGGLER = "straggler"
SHARD_STORM = "shard_storm"
CRASH_MID_MIGRATION = "crash_mid_migration"
# same trap mechanics as CRASH_AT_PERSIST, but meant for scenarios with
# epoch durability on: with rounds buffered under one coalesced fence,
# nearly every persist a shard issues IS an epoch-close or checkpoint
# fence, so a small persists_ahead budget lands the crash exactly on an
# epoch boundary — the bounded-loss window the protocol must contain
EPOCH_BOUNDARY = "epoch_boundary"
FAULT_KINDS = (CRASH_AT_PERSIST, CRASH_MID_SCAN, STRAGGLER, SHARD_STORM,
               CRASH_MID_MIGRATION, EPOCH_BOUNDARY)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Scheduling knobs shared by the fault kinds."""
    kind: str = CRASH_AT_PERSIST
    n_shards: int = 1
    n_clients: int = 1
    first_wave: int = 6            # earliest wave the fault may trigger
    gap_lo: int = 8                # waves between triggers
    gap_hi: int = 16
    persists_lo: int = 1           # crash trap: persists ahead of now
    persists_hi: int = 12
    stall_waves: int = 6           # straggler: added think time
    storm_len: int = 8             # storm duration in waves
    n_keys: int = 32               # keyspace (migration range drawing)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultMachine(Machine):
    """Statechart fault injector; emits driver directives (see module
    docstring).  One machine = one fault process; a scenario may run
    several concurrently (e.g. a shard storm plus a crash schedule)."""

    def __init__(self, spec: FaultSpec, seed: int):
        self.spec = spec
        self.directives: List[Tuple] = []
        self.fired = 0
        if spec.kind in (CRASH_AT_PERSIST, CRASH_MID_SCAN, EPOCH_BOUNDARY):
            guard = (self._may_crash_scan if spec.kind == CRASH_MID_SCAN
                     else self._may_crash)
            transitions = [
                Transition("idle", "tick", "armed", guard=guard,
                           action=FaultMachine._arm),
                Transition("idle", "tick", "idle"),
                Transition("armed", "tick", "armed"),
                Transition("armed", "crash", "idle",
                           action=FaultMachine._sprung),
            ]
        elif spec.kind == CRASH_MID_MIGRATION:
            transitions = [
                Transition("idle", "tick", "armed", guard=self._due,
                           action=FaultMachine._arm_migration),
                Transition("idle", "tick", "idle"),
                Transition("armed", "tick", "idle",
                           guard=lambda m, e: e["wave"] >= m.until,
                           action=FaultMachine._reschedule),
                Transition("armed", "tick", "armed"),
                Transition("armed", "crash", "idle",
                           action=FaultMachine._sprung),
            ]
        elif spec.kind == STRAGGLER:
            transitions = [
                Transition("idle", "tick", "stalling", guard=self._due,
                           action=FaultMachine._pick_victim),
                Transition("idle", "tick", "idle"),
                Transition("stalling", "tick", "idle",
                           action=FaultMachine._reschedule),
            ]
        else:                                           # SHARD_STORM
            transitions = [
                Transition("calm", "tick", "storming", guard=self._due,
                           action=FaultMachine._start_storm),
                Transition("calm", "tick", "calm"),
                Transition("storming", "tick", "calm",
                           guard=lambda m, e: e["wave"] >= m.until,
                           action=FaultMachine._end_storm),
                Transition("storming", "tick", "storming"),
            ]
        initial = "calm" if spec.kind == SHARD_STORM else "idle"
        super().__init__(f"fault:{spec.kind}", initial, transitions, seed)
        self.next_wave = spec.first_wave
        self.until = 0

    # -- guards ----------------------------------------------------------------
    def _due(self, m, ev) -> bool:
        return ev["wave"] >= self.next_wave

    def _may_crash(self, m, ev) -> bool:
        return self._due(m, ev)

    def _may_crash_scan(self, m, ev) -> bool:
        # crash-mid-scan: only spring the trap on a wave with a scan in
        # flight, so the lost verdict is a range read
        return self._due(m, ev) and ev.get("scans_pending", 0) > 0

    # -- actions ---------------------------------------------------------------
    def _reschedule(self, ev) -> None:
        self.next_wave = ev["wave"] + int(
            self.rng.integers(self.spec.gap_lo, self.spec.gap_hi + 1))

    def _arm(self, ev) -> None:
        sp = self.spec
        shard = int(self.rng.integers(sp.n_shards))
        ahead = int(self.rng.integers(sp.persists_lo, sp.persists_hi + 1))
        if sp.kind == CRASH_MID_SCAN:
            ahead = int(self.rng.integers(0, 4))   # spring it this wave
        self.directives.append((ARM_CRASH, shard, ahead))

    def _sprung(self, ev) -> None:
        self.fired += 1
        self._reschedule(ev)

    def _arm_migration(self, ev) -> None:
        """Start a key-range migration and schedule a crash into it:
        half the draws trap the migration decision log (the swing's own
        persists), half trap a shard WAL pool (mid-copy)."""
        sp = self.spec
        lo = 1 + int(self.rng.integers(sp.n_keys))
        hi = lo + 1 + int(self.rng.integers(max(2, sp.n_keys // 3)))
        dst = int(self.rng.integers(sp.n_shards))
        self.directives.append((MIGRATE, lo, hi, dst))
        if self.rng.random() < 0.5:
            self.directives.append(
                (ARM_MIG_CRASH, 1 + int(self.rng.integers(3))))
        else:
            shard = int(self.rng.integers(sp.n_shards))
            ahead = int(self.rng.integers(sp.persists_lo,
                                          sp.persists_hi + 1))
            self.directives.append((ARM_CRASH, shard, ahead))
        self.until = ev["wave"] + sp.storm_len
        self.fired += 1

    def _pick_victim(self, ev) -> None:
        victim = int(self.rng.integers(self.spec.n_clients))
        self.directives.append((STALL, victim, self.spec.stall_waves))
        self.fired += 1

    def _start_storm(self, ev) -> None:
        shard = int(self.rng.integers(self.spec.n_shards))
        self.until = ev["wave"] + self.spec.storm_len
        self.directives.append((STORM, shard))
        self.fired += 1

    def _end_storm(self, ev) -> None:
        self.directives.append((CALM,))
        self._reschedule(ev)

    def drain_directives(self) -> List[Tuple]:
        out, self.directives = self.directives, []
        return out

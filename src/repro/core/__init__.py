"""The paper's primary contribution: practical persistent multi-word CAS.

- ``model``     state + configuration for the many-core simulator
- ``engine``    the four algorithms as micro-op state machines
- ``sim``       deterministic jit'd simulation driver + instrumentation
- ``recovery``  crash recovery from persisted descriptors (the WAL insight)
"""
from .model import (ALG_ORIGINAL, ALG_OURS, ALG_OURS_DF, ALG_PCAS, ALGORITHMS,
                    CostModel, SimConfig, generate_ops, generate_schedule,
                    init_state)
from .recovery import (RecoveryError, check_crash_consistency,
                       committed_histogram, recover)
from .sim import SimResult, run_sim, run_until

__all__ = [
    "ALG_ORIGINAL", "ALG_OURS", "ALG_OURS_DF", "ALG_PCAS", "ALGORITHMS",
    "CostModel", "SimConfig", "SimResult", "generate_ops",
    "generate_schedule", "init_state", "run_sim", "run_until", "recover",
    "committed_histogram", "check_crash_consistency", "RecoveryError",
]

"""Crash recovery (paper Sec. 3/4 consistency arguments, Figs. 6/7).

A crash discards all CPU caches and every thread register; what survives is
``pmem`` plus the *persisted* descriptor table (``d_*_p`` fields).  Recovery
rolls every descriptor-referencing word forward (state Succeeded) or back
(Failed / Undecided) using only that persisted information, and clears dirty
flags — exactly the procedure the paper's state machines justify.

``committed_histogram`` computes, from the pre-crash simulator state, the set
of operations whose effects MUST survive (their Succeeded state reached
pmem — the durability linearization point, Fig. 4 line 15).  The central
crash-consistency property tested is::

    recovered_value(w) == initial(w) + #committed ops covering w
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

from .model import (ALG_PCAS, PC, ST_SUCCEEDED, SimConfig, TAG_DESC,
                    TAG_DESC_DIRTY, TAG_DIRTY, TAG_MASK, TAG_RDCSS, TAG_SHIFT)

_REF_TAGS = (int(TAG_DESC), int(TAG_DESC_DIRTY), int(TAG_RDCSS))


class RecoveryError(AssertionError):
    """A pmem state recovery cannot explain — must never happen."""


def recover(cfg: SimConfig, st: Dict[str, Any]) -> np.ndarray:
    """Return the recovered (consistent, payload-only) pmem word array."""
    pmem = np.asarray(st["pmem"]).copy()
    tags = pmem & int(TAG_MASK)
    vals = pmem >> TAG_SHIFT

    # 1. dirty payloads: the value is present; clear the flag (Tables 3/4).
    dirty = tags == int(TAG_DIRTY)
    pmem[dirty] = (vals[dirty] << TAG_SHIFT).astype(pmem.dtype)

    # 2. descriptor references: roll forward/back from the persisted WAL.
    d_state_p = np.asarray(st["d_state_p"])
    d_ver_p = np.asarray(st["d_ver_p"])
    d_addr_p = np.asarray(st["d_addr_p"])
    d_exp_p = np.asarray(st["d_exp_p"])
    d_des_p = np.asarray(st["d_des_p"])
    T = cfg.n_threads

    ref_addrs = np.nonzero(np.isin(tags, _REF_TAGS))[0]
    for addr in ref_addrs:
        ptr = int(vals[addr])
        t = ptr % T
        if d_ver_p[t] * T + t != ptr:
            raise RecoveryError(
                f"word {addr} references descriptor generation {ptr}, but "
                f"thread {t}'s persisted descriptor is generation "
                f"{d_ver_p[t] * T + t} — stale reference escaped to pmem")
        (slots,) = np.nonzero(d_addr_p[t] == addr)
        if len(slots) != 1:
            raise RecoveryError(
                f"word {addr} not among thread {t}'s persisted targets")
        j = int(slots[0])
        if d_state_p[t] == ST_SUCCEEDED:
            pmem[addr] = d_des_p[t, j]   # roll forward
        else:
            pmem[addr] = d_exp_p[t, j]   # roll back (Failed/Undecided)

    # Recovery is idempotent by construction: the result is payload-only.
    assert (pmem & int(TAG_MASK) == 0).all()
    return pmem


def committed_histogram(cfg: SimConfig, st: Dict[str, Any]) -> np.ndarray:
    """Per-word increment counts that MUST survive the crash.

    committed(t) = all fully completed ops (op_idx of them; ops retry until
    success) + the in-flight op iff its Succeeded state was persisted for the
    *current* descriptor generation (for PCAS: iff the dirty value was
    flushed, i.e. the thread passed P_PERSIST).
    """
    ops = np.asarray(st["ops"])
    op_idx = np.asarray(st["op_idx"])
    hist = np.zeros(cfg.n_words, dtype=np.int64)
    for t in range(cfg.n_threads):
        n = int(op_idx[t])
        full, part = divmod(n, cfg.max_ops)
        if full:
            np.add.at(hist, ops[t].reshape(-1), full)
        if part:
            np.add.at(hist, ops[t, :part].reshape(-1), 1)
        # in-flight op of thread t
        if cfg.algorithm == ALG_PCAS:
            # committed once the dirty value is flushed (past P_PERSIST);
            # the op is not yet in op_idx until OP_DONE executes
            inflight_committed = int(np.asarray(st["pc"])[t]) in (
                PC.P_CLEAR, PC.OP_DONE)
        else:
            inflight_committed = (
                int(np.asarray(st["d_state_p"])[t]) == ST_SUCCEEDED
                and int(np.asarray(st["d_ver_p"])[t])
                == int(np.asarray(st["d_ver"])[t]))
        if inflight_committed:
            cur = ops[t, n % cfg.max_ops]
            np.add.at(hist, cur, 1)
    return hist


def check_crash_consistency(cfg: SimConfig, st: Dict[str, Any]
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Recover and verify the central crash invariant.  Returns (rec, hist)."""
    rec = recover(cfg, st)
    hist = committed_histogram(cfg, st)
    got = (rec >> TAG_SHIFT).astype(np.int64)
    if not np.array_equal(got, hist):
        bad = np.nonzero(got != hist)[0][:10]
        raise RecoveryError(
            f"crash invariant violated at words {bad}: "
            f"recovered={got[bad]} expected={hist[bad]}")
    return rec, hist

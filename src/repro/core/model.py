"""State model for the PMwCAS concurrency simulator.

The simulator models a many-core CPU with a coherent cache hierarchy in
front of persistent memory, at the granularity the paper reasons about:

- ``cache``  -- the CPU-cache-visible value of every word (what loads/CAS see)
- ``pmem``   -- the persisted value of every word (what survives a crash)
- ``line_owner`` -- which thread's cache currently owns each 64-byte line
  (modified state); writes by another thread count an *invalidation*,
  the contention signal the paper attributes the original algorithm's
  collapse to.

Words are uint32 with the paper's low tag bits (Table 2).  The payload
width is semantics-neutral: the numpy oracle (``core/oracle.py``) runs the
same algorithms with 64-bit words and must agree event-for-event.

Geometry is faithful to the paper's benchmark (Fig. 8): each word
logically occupies the head of a ``block_bytes``-sized memory block, so
``words_per_line = max(1, 64 // block_bytes)`` words share a cache line
(the Fig. 14 false-sharing study).  Descriptors live on their own lines
after the word array.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Algorithms (paper Section 5's four competitors)
# ---------------------------------------------------------------------------
ALG_OURS = "ours"            # Section 4: no dirty flags (descriptor-as-WAL)
ALG_OURS_DF = "ours_df"      # Section 3: with dirty flags
ALG_ORIGINAL = "original"    # Wang et al. (ICDE'18): RDCSS install + helping
ALG_PCAS = "pcas"            # Wang et al.'s persistent single-word CAS
ALGORITHMS = (ALG_OURS, ALG_OURS_DF, ALG_ORIGINAL, ALG_PCAS)

# ---------------------------------------------------------------------------
# Word tagging.
#
# Ours (Table 2, 2 low bits):   00 payload | 10 descriptor | 01 dirty payload
# Original (3 low bits):        adds an RDCSS-intermediate tag (bit 2) and may
#                               combine descriptor/dirty bits.
# A payload value v is stored as (v << TAG_SHIFT) | tag.
# ---------------------------------------------------------------------------
TAG_SHIFT = 3  # one shared shift so both schemes coexist in one word array
TAG_MASK = np.uint32((1 << TAG_SHIFT) - 1)

TAG_PAYLOAD = np.uint32(0b000)
TAG_DIRTY = np.uint32(0b001)    # payload with dirty flag
TAG_DESC = np.uint32(0b010)     # PMwCAS descriptor pointer
TAG_DESC_DIRTY = np.uint32(0b011)
TAG_RDCSS = np.uint32(0b100)    # original algorithm's intermediate descriptor

# Descriptor states (paper Table 1).  The original (Wang et al.) algorithm
# additionally distinguishes an Undecided status during its install phase.
ST_COMPLETED = 0
ST_FAILED = 1
ST_SUCCEEDED = 2
ST_UNDECIDED = 3

# Sentinel in the per-op desired-value table (``ops_des``): "desired =
# expected + 1", the paper's benchmark shape.  Payloads are < 2**(32 -
# TAG_SHIFT) (they are stored shifted), so the all-ones word can never be
# a real desired value.
DES_INCREMENT = np.uint32(0xFFFFFFFF)
# The original algorithm's status word carries its own dirty bit; we track it
# as a separate field on the descriptor ("d_state_dirty").


def encode(value, tag=TAG_PAYLOAD):
    value = jnp.asarray(value, jnp.uint32)
    return (value << TAG_SHIFT) | jnp.asarray(tag, jnp.uint32)


def decode(word):
    word = jnp.asarray(word, jnp.uint32)
    return word >> TAG_SHIFT, word & jnp.uint32(TAG_MASK)


def np_encode(value: int, tag: int = 0) -> int:
    return (int(value) << TAG_SHIFT) | int(tag)


def np_decode(word: int) -> Tuple[int, int]:
    return int(word) >> TAG_SHIFT, int(word) & int(TAG_MASK)


# ---------------------------------------------------------------------------
# Cycle-cost model.  Instruction/invalidation COUNTS are exact; these
# constants only convert counts into modeled wall-cycles for the throughput
# figures.  Calibrated once (see benchmarks/calibration.md) and then frozen.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CostModel:
    local: int = 1          # register/ALU micro-op
    load_hit: int = 2       # load from an owned/shared line
    load_miss: int = 24     # load needing a coherence transfer
    cas_owned: int = 6      # CAS on a line already in M state locally
    cas_remote: int = 40    # CAS stealing the line (invalidation)
    store_owned: int = 2
    store_remote: int = 30
    flush: int = 250        # clflushopt to Optane (~100ns-class)
    flush_clean: int = 60   # flushing a line that is not locally modified
    wait: int = 4           # one back-off step

    def as_array(self) -> jnp.ndarray:
        return jnp.array(
            [self.local, self.load_hit, self.load_miss, self.cas_owned,
             self.cas_remote, self.store_owned, self.store_remote,
             self.flush, self.flush_clean, self.wait],
            dtype=jnp.int64 if jax.config.jax_enable_x64 else jnp.int32,
        )


# Cost indices (into CostModel.as_array()).
C_LOCAL, C_LOAD_HIT, C_LOAD_MISS, C_CAS_OWNED, C_CAS_REMOTE = 0, 1, 2, 3, 4
C_STORE_OWNED, C_STORE_REMOTE, C_FLUSH, C_FLUSH_CLEAN, C_WAIT = 5, 6, 7, 8, 9


# ---------------------------------------------------------------------------
# Simulator configuration.  Frozen + hashable so jit specializes per config.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SimConfig:
    algorithm: str = ALG_OURS
    n_threads: int = 8
    n_words: int = 1 << 16          # paper: 1e6; tests use smaller
    k: int = 3                      # words per PMwCAS
    block_bytes: int = 256          # Fig. 8 memory-block size
    alpha: float = 0.0              # Zipf skew (Eq. 1)
    max_ops: int = 256              # distinct pre-generated ops per thread
    n_steps: int = 20_000           # scheduler micro-steps
    seed: int = 0
    backoff_init: int = 4           # back-off (paper Sec. 3 impl notes)
    backoff_cap: int = 256
    cost: CostModel = dataclasses.field(default_factory=CostModel)

    # Derived geometry -----------------------------------------------------
    @property
    def words_per_line(self) -> int:
        # 64-byte cache lines; each word heads a block_bytes-sized block.
        return max(1, 64 // self.block_bytes)

    @property
    def n_word_lines(self) -> int:
        wpl = self.words_per_line
        return (self.n_words + wpl - 1) // wpl

    @property
    def desc_lines(self) -> int:
        # state+count header (16B) + k * 24B of target info, 64B lines
        return (16 + self.k * 24 + 63) // 64

    @property
    def n_lines(self) -> int:
        return self.n_word_lines + self.n_threads * self.desc_lines

    def desc_line(self, tid):
        """First cache line of thread `tid`'s descriptor."""
        return self.n_word_lines + tid * self.desc_lines

    def validate(self) -> "SimConfig":
        assert self.algorithm in ALGORITHMS, self.algorithm
        assert self.k >= 1
        if self.algorithm == ALG_PCAS:
            assert self.k == 1, "PCAS is single-word"
        assert self.n_words >= self.k
        return self


# ---------------------------------------------------------------------------
# Program counters (micro-op state machines).  One memory event per step.
# ---------------------------------------------------------------------------
class PC:
    # shared front-end: benchmark reads current values (read procedure Fig. 5)
    READ_TGT = 0
    READ_WAIT = 1
    INIT_DESC = 2          # Fig.4 line 1 (state <- Failed; fill targets)
    PERSIST_DESC = 3       # Fig.4 line 2
    RESERVE_TEST = 4       # TTAS load (impl. details, Sec. 3)
    RESERVE_WAIT = 5       # back-off while another PMwCAS is in flight
    RESERVE_CAS = 6        # Fig.4 line 6
    PERSIST_TGT = 7        # Fig.4 line 13
    SET_SUCC = 8           # Fig.4 line 14
    PERSIST_STATE = 9      # Fig.4 line 15 (durability linearization point)
    FIN_STORE_DIRTY = 10   # Fig.4 line 21 (ours_df only)
    FIN_PERSIST_DIRTY = 11  # Fig.4 line 22
    FIN_STORE = 12         # Fig.4 line 23
    FIN_PERSIST = 13       # Fig.4 line 24
    OP_DONE = 14           # Fig.4 line 25 (state <- Completed; next op)

    # original (Wang et al.) extras: RDCSS two-phase install + dirty handling
    O_RDCSS_CAS = 15       # CAS #1: install RDCSS intermediate
    O_PROMOTE_CAS = 16     # CAS #2: promote to MwCAS descriptor (|dirty)
    O_PERSIST_TGT = 17     # flush the installed (dirty) descriptor word
    O_CLEAR_TGT = 18       # store: clear the dirty bit on the descriptor word
    O_STATUS_CAS = 19      # CAS #3-class: Undecided -> Succeeded/Failed |dirty
    O_STATUS_PERSIST = 20
    O_STATUS_CLEAR = 21
    O_FIN_CAS = 22         # CAS #4: descriptor -> final value |dirty
    O_FIN_PERSIST = 23
    O_FIN_CLEAR = 24       # store: clear dirty on final value

    # helping (original only): a reader/installer that hits a foreign
    # descriptor completes that operation before retrying its own.
    H_TEST = 25
    H_CAS = 26
    H_STATUS_CAS = 27
    H_FIN_CAS = 28
    H_FIN_PERSIST = 29
    H_FIN_CLEAR = 30

    # PCAS
    P_READ = 31
    P_CAS = 32             # CAS(v -> (v+1)|dirty)
    P_PERSIST = 33
    P_CLEAR = 34           # store clean value

    COUNT = 35


# Counter slots (per thread).
CNT_CAS = 0          # CAS-class events (incl. atomic finalize stores)
CNT_FLUSH = 1
CNT_LOAD = 2
CNT_STORE = 3
CNT_INVAL = 4        # cache-line invalidations this thread caused
CNT_OPS = 5          # completed (successful) PMwCAS operations
CNT_FAILS = 6        # failed PMwCAS attempts (op retried)
CNT_CYCLES = 7       # modeled cycles consumed by this thread
CNT_HELPS = 8        # helping episodes entered (original only)
N_COUNTERS = 9


def zipf_probs(n: int, alpha: float) -> np.ndarray:
    """Eq. (1): f(k; alpha, |W|) over word ranks 1..n."""
    if alpha == 0.0:
        return np.full(n, 1.0 / n)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


def generate_ops(cfg: SimConfig) -> np.ndarray:
    """Pre-generate [n_threads, max_ops, k] distinct, address-sorted targets.

    The paper's benchmark selects k words per operation by Zipf rank and
    embeds descriptors in a canonical (sorted) address order so that
    concurrent PMwCAS operations cannot deadlock (Sec. 2.1).
    """
    rng = np.random.default_rng(cfg.seed)
    p = zipf_probs(cfg.n_words, cfg.alpha)
    # Popularity rank r maps to word id perm[r] (stable shuffle).
    perm = rng.permutation(cfg.n_words)
    shape = (cfg.n_threads, cfg.max_ops, cfg.k)
    ranks = rng.choice(cfg.n_words, size=shape, p=p)
    # Reject duplicate words within an op (sample-until-distinct).
    for _ in range(64):
        ids = perm[ranks]
        dup = np.zeros(shape, dtype=bool)
        srt = np.sort(ids, axis=-1)
        d = srt[..., 1:] == srt[..., :-1]
        if not d.any():
            break
        # resample every position of ops that contain any duplicate
        bad_ops = d.any(axis=-1)
        n_bad = int(bad_ops.sum())
        ranks[bad_ops] = rng.choice(cfg.n_words, size=(n_bad, cfg.k), p=p)
    ids = perm[ranks]
    ids = np.sort(ids, axis=-1)  # canonical embedding order
    return ids.astype(np.int32)


def generate_schedule(cfg: SimConfig) -> np.ndarray:
    """A uniformly random but deterministic thread interleaving."""
    rng = np.random.default_rng(cfg.seed + 0x5EED)
    return rng.integers(0, cfg.n_threads, size=cfg.n_steps, dtype=np.int32)


def init_state(cfg: SimConfig, ops: Optional[np.ndarray] = None,
               ops_des: Optional[np.ndarray] = None) -> Dict[str, Any]:
    """Build the initial simulator state pytree.

    ``ops_des`` optionally supplies explicit desired payload values per
    target (``[n_threads, max_ops, k]`` uint32, same shape as ``ops``);
    entries equal to :data:`DES_INCREMENT` (the default everywhere) fall
    back to the benchmark's ``expected + 1``.  This is how structure
    rounds — whose desired values are real keys/values, not increments —
    run natively on the cycle-accurate machines."""
    cfg.validate()
    T, k = cfg.n_threads, cfg.k
    if ops is None:
        ops = generate_ops(cfg)
    ops = np.asarray(ops)
    if ops_des is None:
        ops_des = np.full(ops.shape, DES_INCREMENT, np.uint32)
    ops_des = np.asarray(ops_des, np.uint32)
    if ops_des.shape != ops.shape:
        raise ValueError(f"ops_des shape {ops_des.shape} != ops shape "
                         f"{ops.shape}")
    start_pc = PC.P_READ if cfg.algorithm == ALG_PCAS else PC.READ_TGT
    state = {
        # memory ------------------------------------------------------------
        "cache": jnp.zeros(cfg.n_words, jnp.uint32),
        "pmem": jnp.zeros(cfg.n_words, jnp.uint32),
        "line_owner": jnp.full(cfg.n_lines, -1, jnp.int32),
        # descriptor table (cache + pmem copies) -----------------------------
        "d_state": jnp.full(T, ST_COMPLETED, jnp.int32),
        "d_state_p": jnp.full(T, ST_COMPLETED, jnp.int32),
        "d_state_dirty": jnp.zeros(T, jnp.int32),   # original's status dirty bit
        "d_addr": jnp.full((T, k), -1, jnp.int32),
        "d_exp": jnp.zeros((T, k), jnp.uint32),     # tagged expected words
        "d_des": jnp.zeros((T, k), jnp.uint32),     # tagged desired words
        "d_addr_p": jnp.full((T, k), -1, jnp.int32),
        "d_exp_p": jnp.zeros((T, k), jnp.uint32),
        "d_des_p": jnp.zeros((T, k), jnp.uint32),
        # descriptor generation counters.  The descriptor *pointer* stored in
        # a word is ver*T + tid, so helpers can detect a recycled descriptor
        # (the ABA hazard Wang et al. solve with epoch-based GC; the paper's
        # own algorithms never dereference foreign descriptors, so they need
        # no GC -- one of its contributions).
        "d_ver": jnp.zeros(T, jnp.int32),
        "d_ver_p": jnp.zeros(T, jnp.int32),
        # per-thread registers ------------------------------------------------
        "pc": jnp.full(T, start_pc, jnp.int32),
        "op_idx": jnp.zeros(T, jnp.int32),
        "tgt_idx": jnp.zeros(T, jnp.int32),
        "success": jnp.ones(T, jnp.bool_),
        "backoff": jnp.zeros(T, jnp.int32),
        "backoff_exp": jnp.full(T, cfg.backoff_init, jnp.int32),
        "exp": jnp.zeros((T, k), jnp.uint32),       # untagged payload values
        "help_desc": jnp.full(T, -1, jnp.int32),
        "help_tgt": jnp.zeros(T, jnp.int32),
        "help_ok": jnp.ones(T, jnp.bool_),
        "ret_pc": jnp.full(T, start_pc, jnp.int32),
        # outstanding descriptor references per owner thread (cache / pmem);
        # see engine._ref_update for why these exist
        "ref_cache": jnp.zeros(T, jnp.int32),
        "ref_pmem": jnp.zeros(T, jnp.int32),
        # instrumentation -----------------------------------------------------
        "counters": jnp.zeros((T, N_COUNTERS), jnp.int64
                              if jax.config.jax_enable_x64 else jnp.int32),
        # static data ---------------------------------------------------------
        "ops": jnp.asarray(ops),
        "ops_des": jnp.asarray(ops_des, jnp.uint32),
    }
    return state

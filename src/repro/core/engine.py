"""Micro-op state machines for the four PMwCAS algorithms.

Every simulator step executes exactly ONE memory event (load / CAS / store /
persist) of ONE thread, so interleavings are modeled at the same atomicity
granularity the algorithms reason about.  Branches are selected with
``lax.switch`` on the thread's program counter; the whole step function is
jit-compatible and driven by ``core.sim.run_sim`` inside a ``lax.scan``.

Fidelity notes (see DESIGN.md Sec. 2.1):
- CAS always acquires line ownership (x86 ``lock cmpxchg`` issues an RFO even
  when the comparison fails) -- this is what makes failed-CAS storms expensive
  and is the contention mechanism behind the paper's Fig. 2.
- ``persist`` models ``clflushopt`` (their Cascade Lake Xeon): the line is
  written back AND evicted (ownership cleared).
- Helper threads in the original algorithm pay their install-persist and
  dirty-clear as a fused step (one scheduler slot, both events counted);
  everything else is one event per step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from .model import (ALG_ORIGINAL, ALG_OURS, ALG_OURS_DF, ALG_PCAS,
                    CNT_CAS, CNT_CYCLES, DES_INCREMENT,
                    CNT_FAILS, CNT_FLUSH, CNT_HELPS, CNT_INVAL, CNT_LOAD,
                    CNT_OPS, CNT_STORE, PC, ST_COMPLETED, ST_FAILED,
                    ST_SUCCEEDED, ST_UNDECIDED, SimConfig, TAG_DESC,
                    TAG_DESC_DIRTY, TAG_DIRTY, TAG_MASK, TAG_PAYLOAD,
                    TAG_RDCSS, TAG_SHIFT)

U32 = jnp.uint32


def _u32(x):
    return jnp.asarray(x, U32)


# ---------------------------------------------------------------------------
# Small state utilities
# ---------------------------------------------------------------------------

def _bump(st, tid, cnt, n=1):
    st = dict(st)
    st["counters"] = st["counters"].at[tid, cnt].add(n)
    return st


def _cost(st, tid, cycles):
    st = dict(st)
    st["counters"] = st["counters"].at[tid, CNT_CYCLES].add(cycles)
    return st


def _set(st, field, tid, value):
    st = dict(st)
    st[field] = st[field].at[tid].set(value)
    return st


def _cur_op_addrs(cfg: SimConfig, st, tid):
    """Addresses of the thread's current operation (ops wrap around)."""
    idx = lax.rem(st["op_idx"][tid], jnp.int32(cfg.max_ops))
    return lax.dynamic_index_in_dim(st["ops"][tid], idx, axis=0, keepdims=False)


def _cur_op_des(cfg: SimConfig, st, tid):
    """Explicit desired payloads of the current op (DES_INCREMENT rows
    mean "expected + 1" — the benchmark default)."""
    idx = lax.rem(st["op_idx"][tid], jnp.int32(cfg.max_ops))
    return lax.dynamic_index_in_dim(st["ops_des"][tid], idx, axis=0,
                                    keepdims=False)


def _desc_ptr(cfg: SimConfig, st, tid):
    """The tagged-word payload that identifies this thread's live descriptor."""
    return _u32(st["d_ver"][tid]) * _u32(cfg.n_threads) + _u32(tid)


def _desc_tid(cfg: SimConfig, val):
    return jnp.asarray(val, jnp.int32) % jnp.int32(cfg.n_threads)


# ---------------------------------------------------------------------------
# Memory events.  Each returns an updated state with counters/cycles applied.
# ---------------------------------------------------------------------------

def _line_of(cfg: SimConfig, addr):
    return addr // jnp.int32(cfg.words_per_line)


def _ev_load(cfg, st, tid, line):
    owned = st["line_owner"][line] == tid
    cm = cfg.cost
    st = _bump(st, tid, CNT_LOAD)
    return _cost(st, tid, jnp.where(owned, cm.load_hit, cm.load_miss))


def _take_line(cfg, st, tid, line):
    """Write-side ownership transfer.

    Returns (st, owned_before): cost is priced on whether the line was
    already exclusively ours; an *invalidation* is only counted when the
    line is stolen from another thread's cache.
    """
    owner = st["line_owner"][line]
    owned = owner == tid
    stolen = (owner != tid) & (owner >= 0)
    st = _bump(st, tid, CNT_INVAL, jnp.where(stolen, 1, 0).astype(st["counters"].dtype))
    st = dict(st)
    st["line_owner"] = st["line_owner"].at[line].set(tid)
    return st, owned


def _is_ref(word):
    """Does this word reference a descriptor (desc / dirty-desc / RDCSS)?"""
    tag = word & TAG_MASK
    return (tag == TAG_DESC) | (tag == TAG_DESC_DIRTY) | (tag == TAG_RDCSS)


def _ref_update(cfg, st, field, old_word, new_word):
    """Maintain per-thread outstanding-descriptor-reference counts.

    Wang et al.'s algorithm needs epoch-based GC because helpers hold live
    references to descriptors; the paper's algorithms do not (a stated
    contribution).  We track exact reference counts per owner thread in both
    cache and pmem so that (a) the ORIGINAL simulation can model the reuse
    barrier GC provides, and (b) tests can ASSERT the paper's algorithms hit
    zero references at every operation boundary without any barrier.
    """
    t_old = jnp.asarray(old_word >> TAG_SHIFT, jnp.int32) % jnp.int32(cfg.n_threads)
    t_new = jnp.asarray(new_word >> TAG_SHIFT, jnp.int32) % jnp.int32(cfg.n_threads)
    dec = jnp.where(_is_ref(old_word), -1, 0)
    inc = jnp.where(_is_ref(new_word), 1, 0)
    st = dict(st)
    st[field] = st[field].at[t_old].add(dec)
    st[field] = st[field].at[t_new].add(inc)
    return st


def _ev_cas_word(cfg, st, tid, addr, expected, desired):
    """CAS on a data word.  Returns (st, success).  Always acquires the line."""
    line = _line_of(cfg, addr)
    cur = st["cache"][addr]
    ok = cur == expected
    new = jnp.where(ok, desired, cur)
    st = _ref_update(cfg, st, "ref_cache", cur, new)
    st = dict(st)
    st["cache"] = st["cache"].at[addr].set(new)
    st, owned = _take_line(cfg, st, tid, line)
    cm = cfg.cost
    st = _bump(st, tid, CNT_CAS)
    st = _cost(st, tid, jnp.where(owned, cm.cas_owned, cm.cas_remote))
    return st, ok


def _ev_store_word(cfg, st, tid, addr, value, cas_class=False):
    """Plain store to a data word (atomic 8-byte store on x86)."""
    line = _line_of(cfg, addr)
    st = _ref_update(cfg, st, "ref_cache", st["cache"][addr], value)
    st = dict(st)
    st["cache"] = st["cache"].at[addr].set(value)
    st, owned = _take_line(cfg, st, tid, line)
    cm = cfg.cost
    st = _bump(st, tid, CNT_CAS if cas_class else CNT_STORE)
    st = _cost(st, tid, jnp.where(owned, cm.store_owned, cm.store_remote))
    return st


def _ev_persist_word(cfg, st, tid, addr):
    """clflushopt: write back cache->pmem and evict the line."""
    line = _line_of(cfg, addr)
    st = _ref_update(cfg, st, "ref_pmem", st["pmem"][addr], st["cache"][addr])
    st = dict(st)
    st["pmem"] = st["pmem"].at[addr].set(st["cache"][addr])
    st["line_owner"] = st["line_owner"].at[line].set(-1)
    st = _bump(st, tid, CNT_FLUSH)
    return _cost(st, tid, cfg.cost.flush)


def _ev_persist_desc(cfg, st, tid, dt):
    """Persist thread dt's whole descriptor (state+ver+targets)."""
    st = dict(st)
    st["d_state_p"] = st["d_state_p"].at[dt].set(st["d_state"][dt])
    st["d_ver_p"] = st["d_ver_p"].at[dt].set(st["d_ver"][dt])
    st["d_addr_p"] = st["d_addr_p"].at[dt].set(st["d_addr"][dt])
    st["d_exp_p"] = st["d_exp_p"].at[dt].set(st["d_exp"][dt])
    st["d_des_p"] = st["d_des_p"].at[dt].set(st["d_des"][dt])
    line = jnp.int32(cfg.n_word_lines) + dt * jnp.int32(cfg.desc_lines)
    st["line_owner"] = st["line_owner"].at[line].set(-1)
    st = _bump(st, tid, CNT_FLUSH, cfg.desc_lines)
    return _cost(st, tid, cfg.cost.flush * cfg.desc_lines)


def _ev_persist_desc_state(cfg, st, tid, dt):
    """Persist only the state word of dt's descriptor (one line)."""
    st = dict(st)
    st["d_state_p"] = st["d_state_p"].at[dt].set(st["d_state"][dt])
    st["d_ver_p"] = st["d_ver_p"].at[dt].set(st["d_ver"][dt])
    line = jnp.int32(cfg.n_word_lines) + dt * jnp.int32(cfg.desc_lines)
    st["line_owner"] = st["line_owner"].at[line].set(-1)
    st = _bump(st, tid, CNT_FLUSH)
    return _cost(st, tid, cfg.cost.flush)


def _ev_desc_store(cfg, st, tid, dt, cas_class=False):
    """Cost/ownership accounting for a write to dt's descriptor line."""
    line = jnp.int32(cfg.n_word_lines) + dt * jnp.int32(cfg.desc_lines)
    st, owned = _take_line(cfg, st, tid, line)
    cm = cfg.cost
    st = _bump(st, tid, CNT_CAS if cas_class else CNT_STORE)
    return _cost(st, tid, jnp.where(owned,
                                    cm.cas_owned if cas_class
                                    else cm.store_owned,
                                    cm.cas_remote if cas_class
                                    else cm.store_remote))


def _ev_desc_load(cfg, st, tid, dt):
    line = jnp.int32(cfg.n_word_lines) + dt * jnp.int32(cfg.desc_lines)
    return _ev_load(cfg, st, tid, line)


def _ev_wait(cfg, st, tid):
    return _cost(st, tid, cfg.cost.wait)


def _ev_local(cfg, st, tid):
    return _cost(st, tid, cfg.cost.local)


# ---------------------------------------------------------------------------
# Shared helpers for branch bodies
# ---------------------------------------------------------------------------

def _enter_wait(cfg, st, tid, ret_pc):
    """Exponential back-off (paper Sec. 3 implementation details)."""
    be = st["backoff_exp"][tid]
    st = _set(st, "backoff", tid, be)
    st = _set(st, "backoff_exp", tid,
              jnp.minimum(be * 2, jnp.int32(cfg.backoff_cap)))
    st = _set(st, "ret_pc", tid, ret_pc)
    return _set(st, "pc", tid, jnp.int32(PC.READ_WAIT))


def _reset_backoff(cfg, st, tid):
    return _set(st, "backoff_exp", tid, jnp.int32(cfg.backoff_init))


def _is_busy_tag(tag):
    """Word currently unreadable: descriptor embedded or dirty (Fig. 5)."""
    return tag != TAG_PAYLOAD


def _goto(st, tid, pc):
    return _set(st, "pc", tid, jnp.int32(pc))


# ===========================================================================
# Branches shared by OURS / OURS_DF (paper Fig. 4) and partially by ORIGINAL
# ===========================================================================

def br_read_tgt(cfg, st, tid):
    """Benchmark front-end: read current value of target tgt_idx (Fig. 5)."""
    j = st["tgt_idx"][tid]
    addrs = _cur_op_addrs(cfg, st, tid)
    addr = addrs[j]
    st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
    word = st["cache"][addr]
    tag = word & TAG_MASK

    if cfg.algorithm == ALG_ORIGINAL:
        # The original algorithm HELPS instead of waiting.
        def busy(st):
            is_dirty = tag == TAG_DIRTY
            def flush_clear(st):
                # Wang et al.: readers flush dirty words, then clear the flag.
                st = _ev_persist_word(cfg, st, tid, addr)
                clean = word & ~_u32(TAG_MASK)
                return _ev_store_word(cfg, st, tid, addr, clean)
            def help_(st):
                st = _bump(st, tid, CNT_HELPS)
                st = _set(st, "help_desc", tid,
                          jnp.asarray(word >> TAG_SHIFT, jnp.int32))
                st = _set(st, "help_tgt", tid, jnp.int32(0))
                st = _set(st, "help_ok", tid, True)
                st = _set(st, "ret_pc", tid, jnp.int32(PC.READ_TGT))
                return _goto(st, tid, PC.H_TEST)
            return lax.cond(is_dirty, flush_clear, help_, st)
    else:
        def busy(st):
            return _enter_wait(cfg, st, tid, jnp.int32(PC.READ_TGT))

    def free(st):
        st = dict(st)
        st["exp"] = st["exp"].at[tid, j].set(word >> TAG_SHIFT)
        st = _set(st, "tgt_idx", tid, j + 1)
        st = _reset_backoff(cfg, st, tid)
        done = j + 1 >= cfg.k
        st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
        return _goto(st, tid, jnp.where(done, PC.INIT_DESC, PC.READ_TGT))

    return lax.cond(_is_busy_tag(tag), busy, free, st)


def br_read_wait(cfg, st, tid):
    b = st["backoff"][tid]
    st = _ev_wait(cfg, st, tid)
    st = _set(st, "backoff", tid, b - 1)
    return lax.cond(b - 1 <= 0,
                    lambda s: _goto(s, tid, s["ret_pc"][tid]),
                    lambda s: s, st)


def br_init_desc(cfg, st, tid):
    """Fig. 4 line 1 + filling target info (state Failed acts as the WAL)."""
    addrs = _cur_op_addrs(cfg, st, tid)
    init_state = (ST_UNDECIDED if cfg.algorithm == ALG_ORIGINAL else ST_FAILED)
    st = _set(st, "d_state", tid, jnp.int32(init_state))
    st = _set(st, "d_state_dirty", tid, jnp.int32(0))
    st = dict(st)
    exp = st["exp"][tid]
    des_tab = _cur_op_des(cfg, st, tid)
    des = jnp.where(des_tab == jnp.uint32(DES_INCREMENT),
                    exp + _u32(1), des_tab)
    st["d_addr"] = st["d_addr"].at[tid].set(addrs)
    st["d_exp"] = st["d_exp"].at[tid].set(exp << TAG_SHIFT)
    st["d_des"] = st["d_des"].at[tid].set(des << TAG_SHIFT)
    st = _set(st, "success", tid, True)
    st = _ev_desc_store(cfg, st, tid, tid)
    st = _set(st, "tgt_idx", tid, jnp.int32(0))
    return _goto(st, tid, PC.PERSIST_DESC)


def br_persist_desc(cfg, st, tid):
    """Fig. 4 line 2: the descriptor IS the write-ahead log."""
    st = _ev_persist_desc(cfg, st, tid, tid)
    first = (PC.O_RDCSS_CAS if cfg.algorithm == ALG_ORIGINAL
             else PC.RESERVE_TEST)
    return _goto(st, tid, first)


def br_reserve_test(cfg, st, tid):
    """TTAS pre-check before the reserve CAS (ours / ours_df)."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
    word = st["cache"][addr]
    tag = word & TAG_MASK

    def busy(st):  # another PMwCAS in flight (or dirty): wait + back off
        return _enter_wait(cfg, st, tid, jnp.int32(PC.RESERVE_TEST))

    def mismatch(st):  # Fig. 4 lines 8-10: operation failed, go abort
        st = _set(st, "success", tid, False)
        st = _set(st, "tgt_idx", tid, jnp.int32(0))
        first_fin = (PC.FIN_STORE_DIRTY if cfg.algorithm == ALG_OURS_DF
                     else PC.FIN_STORE)
        return _goto(st, tid, first_fin)

    def match(st):
        return _goto(st, tid, PC.RESERVE_CAS)

    return lax.cond(
        _is_busy_tag(tag), busy,
        lambda s: lax.cond(word == s["d_exp"][tid, j], match, mismatch, s),
        st)


def br_reserve_cas(cfg, st, tid):
    """Fig. 4 line 6: embed the descriptor address."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    desc_word = (_desc_ptr(cfg, st, tid) << TAG_SHIFT) | _u32(TAG_DESC)
    st, ok = _ev_cas_word(cfg, st, tid, addr, st["d_exp"][tid, j], desc_word)

    def on_ok(st):
        st = _reset_backoff(cfg, st, tid)
        done = j + 1 >= cfg.k
        st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
        return _goto(st, tid, jnp.where(done, PC.PERSIST_TGT, PC.RESERVE_TEST))

    def on_fail(st):  # re-test: the word may now hold a descriptor or a
        return _goto(st, tid, PC.RESERVE_TEST)  # different payload

    return lax.cond(ok, on_ok, on_fail, st)


def br_persist_tgt(cfg, st, tid):
    """Fig. 4 lines 12-13: persist every embedded descriptor address."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    st = _ev_persist_word(cfg, st, tid, addr)
    done = j + 1 >= cfg.k
    st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
    return _goto(st, tid, jnp.where(done, PC.SET_SUCC, PC.PERSIST_TGT))


def br_set_succ(cfg, st, tid):
    st = _set(st, "d_state", tid, jnp.int32(ST_SUCCEEDED))
    st = _ev_desc_store(cfg, st, tid, tid)
    return _goto(st, tid, PC.PERSIST_STATE)


def br_persist_state(cfg, st, tid):
    """Fig. 4 line 15 -- the durability linearization point."""
    st = _ev_persist_desc_state(cfg, st, tid, tid)
    st = _set(st, "tgt_idx", tid, jnp.int32(0))
    first_fin = (PC.FIN_STORE_DIRTY if cfg.algorithm == ALG_OURS_DF
                 else PC.FIN_STORE)
    return _goto(st, tid, first_fin)


def _final_word(cfg, st, tid, j):
    """Fig. 4 line 19: desired on success, expected on abort (tagged clean)."""
    return jnp.where(st["success"][tid], st["d_des"][tid, j],
                     st["d_exp"][tid, j])


def _holds_my_desc(cfg, st, tid, word):
    tag = word & TAG_MASK
    mine = (word >> TAG_SHIFT) == _desc_ptr(cfg, st, tid)
    return ((tag == TAG_DESC) | (tag == TAG_DESC_DIRTY)) & mine


def br_fin_store_dirty(cfg, st, tid):
    """Fig. 4 lines 17-21 (ours_df): store final value WITH the dirty flag."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    word = st["cache"][addr]

    def brk(st):  # line 18: first non-reserved target ends the abort sweep
        return _goto(st, tid, PC.OP_DONE)

    def go(st):
        dirty = _final_word(cfg, st, tid, j) | _u32(TAG_DIRTY)
        st = _ev_store_word(cfg, st, tid, addr, dirty, cas_class=True)
        return _goto(st, tid, PC.FIN_PERSIST_DIRTY)

    return lax.cond(_holds_my_desc(cfg, st, tid, word), go, brk, st)


def br_fin_persist_dirty(cfg, st, tid):
    j = st["tgt_idx"][tid]
    st = _ev_persist_word(cfg, st, tid, st["d_addr"][tid, j])
    return _goto(st, tid, PC.FIN_STORE)


def br_fin_store(cfg, st, tid):
    """Fig. 4 line 23: store the clean final value.

    In ours (no dirty flags) this is also where the per-target abort sweep
    checks for the first non-reserved address (line 17-18).
    """
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    word = st["cache"][addr]
    clean = _final_word(cfg, st, tid, j)

    if cfg.algorithm == ALG_OURS_DF:
        # arrived via the dirty path; the word holds our dirty value
        st = _ev_store_word(cfg, st, tid, addr, clean, cas_class=False)
        return _goto(st, tid, PC.FIN_PERSIST)

    def brk(st):
        return _goto(st, tid, PC.OP_DONE)

    def go(st):
        st2 = _ev_store_word(cfg, st, tid, addr, clean, cas_class=True)
        return _goto(st2, tid, PC.FIN_PERSIST)

    return lax.cond(_holds_my_desc(cfg, st, tid, word), go, brk, st)


def br_fin_persist(cfg, st, tid):
    """Fig. 4 line 24."""
    j = st["tgt_idx"][tid]
    st = _ev_persist_word(cfg, st, tid, st["d_addr"][tid, j])
    done = j + 1 >= cfg.k
    next_fin = (PC.FIN_STORE_DIRTY if cfg.algorithm == ALG_OURS_DF
                else PC.FIN_STORE)
    st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
    return _goto(st, tid, jnp.where(done, PC.OP_DONE, next_fin))


def br_op_done(cfg, st, tid):
    """Fig. 4 line 25 + benchmark bookkeeping (retry failed ops)."""
    if cfg.algorithm == ALG_ORIGINAL:
        # Epoch-GC stand-in: the original algorithm may not recycle a
        # descriptor while helpers/words still reference it.  The paper's
        # algorithms provably never wait here (asserted in tests).
        pending = (st["ref_cache"][tid] + st["ref_pmem"][tid]) > 0
        return lax.cond(pending,
                        lambda s: _ev_wait(cfg, s, tid),
                        functools.partial(_op_done_body, cfg, tid=tid), st)
    return _op_done_body(cfg, st, tid)


def _op_done_body(cfg, st, tid):
    if cfg.algorithm == ALG_ORIGINAL:
        # helpers may have decided the op differently from the owner's local
        # view; the descriptor status word is the authoritative outcome
        ok = st["d_state"][tid] == ST_SUCCEEDED
    else:
        ok = st["success"][tid]
    st = _set(st, "d_state", tid, jnp.int32(ST_COMPLETED))
    st = _ev_local(cfg, st, tid)
    cdt = st["counters"].dtype
    st = _bump(st, tid, CNT_OPS, jnp.where(ok, 1, 0).astype(cdt))
    st = _bump(st, tid, CNT_FAILS, jnp.where(ok, 0, 1).astype(cdt))
    st = _set(st, "op_idx", tid,
              st["op_idx"][tid] + jnp.where(ok, 1, 0).astype(jnp.int32))
    # a new descriptor generation begins; stale pointers become detectable
    st = _set(st, "d_ver", tid, st["d_ver"][tid] + 1)
    st = _set(st, "tgt_idx", tid, jnp.int32(0))
    start = PC.P_READ if cfg.algorithm == ALG_PCAS else PC.READ_TGT
    return _goto(st, tid, start)


# ===========================================================================
# ORIGINAL (Wang et al. ICDE'18): RDCSS install + dirty flags + helping
# ===========================================================================

def br_o_rdcss_cas(cfg, st, tid):
    """Install phase, CAS #1: place the RDCSS intermediate descriptor."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
    word = st["cache"][addr]
    tag = word & TAG_MASK
    mine = _holds_my_desc(cfg, st, tid, word)

    def skip(st):  # a helper already installed this target for us
        done = j + 1 >= cfg.k
        st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
        return _goto(st, tid, jnp.where(done, PC.O_STATUS_CAS, PC.O_RDCSS_CAS))

    def dirty(st):  # flush + clear, then retry
        st = _ev_persist_word(cfg, st, tid, addr)
        return _ev_store_word(cfg, st, tid, addr, word & ~_u32(TAG_MASK))

    def foreign(st):  # help the other operation to completion, then retry
        st = _bump(st, tid, CNT_HELPS)
        st = _set(st, "help_desc", tid,
                  jnp.asarray(word >> TAG_SHIFT, jnp.int32))
        st = _set(st, "help_tgt", tid, jnp.int32(0))
        st = _set(st, "help_ok", tid, True)
        st = _set(st, "ret_pc", tid, jnp.int32(PC.O_RDCSS_CAS))
        return _goto(st, tid, PC.H_TEST)

    def payload(st):
        def ok(st):
            rdcss = (_desc_ptr(cfg, st, tid) << TAG_SHIFT) | _u32(TAG_RDCSS)
            st2, success = _ev_cas_word(cfg, st, tid, addr,
                                        st["d_exp"][tid, j], rdcss)
            return lax.cond(success,
                            lambda s: _goto(s, tid, PC.O_PROMOTE_CAS),
                            lambda s: s, st2)  # retry the load

        def fail(st):  # unexpected value -> whole MwCAS fails
            st = _set(st, "success", tid, False)
            return _goto(st, tid, PC.O_STATUS_CAS)

        return lax.cond(word == st["d_exp"][tid, j], ok, fail, st)

    return lax.cond(
        mine, skip,
        lambda s: lax.cond(
            tag == TAG_DIRTY, dirty,
            lambda s2: lax.cond((tag == TAG_DESC) | (tag == TAG_DESC_DIRTY)
                                | (tag == TAG_RDCSS), foreign, payload, s2),
            s),
        st)


def br_o_promote_cas(cfg, st, tid):
    """Install phase, CAS #2: RDCSS -> MwCAS descriptor (dirty)."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    ptr = _desc_ptr(cfg, st, tid)
    rdcss = (ptr << TAG_SHIFT) | _u32(TAG_RDCSS)
    desc_dirty = (ptr << TAG_SHIFT) | _u32(TAG_DESC_DIRTY)
    st, ok = _ev_cas_word(cfg, st, tid, addr, rdcss, desc_dirty)
    # promotion can only fail if a helper already promoted it; either way the
    # word now holds our descriptor and must be persisted
    return _goto(st, tid, PC.O_PERSIST_TGT)


def br_o_persist_tgt(cfg, st, tid):
    j = st["tgt_idx"][tid]
    st = _ev_persist_word(cfg, st, tid, st["d_addr"][tid, j])
    return _goto(st, tid, PC.O_CLEAR_TGT)


def br_o_clear_tgt(cfg, st, tid):
    """Clear the dirty bit on the installed descriptor word.

    Wang et al.'s implementation flushes again after every dirty-bit
    clear (the "double flush" PerMA-bench identified; paper Sec. 4) —
    modeled as a fused store+persist step."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    word = st["cache"][addr]
    clean = (word & ~_u32(TAG_MASK)) | _u32(TAG_DESC)
    mine = _holds_my_desc(cfg, st, tid, word)

    def clear_flush(s):
        s = _ev_store_word(cfg, s, tid, addr, clean, cas_class=True)
        return _ev_persist_word(cfg, s, tid, addr)

    st = lax.cond(mine, clear_flush,
                  lambda s: _ev_local(cfg, s, tid), st)
    done = j + 1 >= cfg.k
    st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
    return _goto(st, tid, jnp.where(done, PC.O_STATUS_CAS, PC.O_RDCSS_CAS))


def br_o_status_cas(cfg, st, tid):
    """CAS the status word Undecided -> Succeeded/Failed, with dirty bit."""
    target = jnp.where(st["success"][tid], ST_SUCCEEDED, ST_FAILED)
    cur = st["d_state"][tid]
    st = dict(st)
    st["d_state"] = st["d_state"].at[tid].set(
        jnp.where(cur == ST_UNDECIDED, target, cur))
    st["d_state_dirty"] = st["d_state_dirty"].at[tid].set(1)
    st = _ev_desc_store(cfg, st, tid, tid, cas_class=True)
    return _goto(st, tid, PC.O_STATUS_PERSIST)


def br_o_status_persist(cfg, st, tid):
    st = _ev_persist_desc_state(cfg, st, tid, tid)
    return _goto(st, tid, PC.O_STATUS_CLEAR)


def br_o_status_clear(cfg, st, tid):
    st = _set(st, "d_state_dirty", tid, jnp.int32(0))
    st = _ev_desc_store(cfg, st, tid, tid)
    # Wang: the cleared status is flushed again (double flush)
    st = _ev_persist_desc_state(cfg, st, tid, tid)
    st = _set(st, "tgt_idx", tid, jnp.int32(0))
    return _goto(st, tid, PC.O_FIN_CAS)


def br_o_fin_cas(cfg, st, tid):
    """Finalize phase, CAS #4: descriptor -> final value (dirty)."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    word = st["cache"][addr]
    succeeded = st["d_state"][tid] == ST_SUCCEEDED
    final = jnp.where(succeeded, st["d_des"][tid, j], st["d_exp"][tid, j])

    def skip(st):  # already finalized (possibly by a helper) or never installed
        st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
        done = j + 1 >= cfg.k
        st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
        return _goto(st, tid, jnp.where(done, PC.OP_DONE, PC.O_FIN_CAS))

    def go(st):
        st, ok = _ev_cas_word(cfg, st, tid, addr, word,
                              final | _u32(TAG_DIRTY))
        return lax.cond(ok, lambda s: _goto(s, tid, PC.O_FIN_PERSIST),
                        skip, st)

    return lax.cond(_holds_my_desc(cfg, st, tid, word), go, skip, st)


def br_o_fin_persist(cfg, st, tid):
    j = st["tgt_idx"][tid]
    st = _ev_persist_word(cfg, st, tid, st["d_addr"][tid, j])
    return _goto(st, tid, PC.O_FIN_CLEAR)


def br_o_fin_clear(cfg, st, tid):
    """Clear + re-flush the finalized value (Wang's double flush)."""
    j = st["tgt_idx"][tid]
    addr = st["d_addr"][tid, j]
    word = st["cache"][addr]
    clean = word & ~_u32(TAG_MASK)
    is_dirty = (word & TAG_MASK) == TAG_DIRTY

    def clear_flush(s):
        s = _ev_store_word(cfg, s, tid, addr, clean)
        return _ev_persist_word(cfg, s, tid, addr)

    st = lax.cond(is_dirty, clear_flush,
                  lambda s: _ev_local(cfg, s, tid), st)
    done = j + 1 >= cfg.k
    st = _set(st, "tgt_idx", tid, jnp.where(done, 0, j + 1))
    return _goto(st, tid, jnp.where(done, PC.OP_DONE, PC.O_FIN_CAS))


# --------------------------- helping machinery -----------------------------

def _help_valid(cfg, st, tid):
    """ABA guard: is the helped descriptor still the generation we saw?"""
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)
    live = _u32(st["d_ver"][dt]) * _u32(cfg.n_threads) + _u32(dt)
    return live == _u32(h)


def br_h_test(cfg, st, tid):
    """Helper install loop over the helped descriptor's targets."""
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)
    st = _ev_desc_load(cfg, st, tid, dt)

    def abandon(st):
        st = _set(st, "help_desc", tid, jnp.int32(-1))
        return _goto(st, tid, st["ret_pc"][tid])

    def live(st):
        state = st["d_state"][dt]

        def decided(st):
            st = _set(st, "help_tgt", tid, jnp.int32(0))
            return _goto(st, tid, PC.H_FIN_CAS)

        def undecided(st):
            j = st["help_tgt"][tid]

            def all_done(st):
                return _goto(st, tid, PC.H_STATUS_CAS)

            def probe(st):
                addr = st["d_addr"][dt, j]
                st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
                word = st["cache"][addr]
                tag = word & TAG_MASK
                mine = (word >> TAG_SHIFT) == _u32(h)
                # ONLY a (possibly dirty) MwCAS descriptor counts as
                # installed; an RDCSS intermediate must still be PROMOTED
                # before the op may be declared Succeeded (otherwise a
                # crash can persist Succeeded with an unpersisted target —
                # caught by the exhaustive crash tests)
                installed_ = mine & ((tag == TAG_DESC)
                                     | (tag == TAG_DESC_DIRTY))
                rdcss_mine = mine & (tag == TAG_RDCSS)

                def installed(st):
                    st = _set(st, "help_tgt", tid, j + 1)
                    return st  # stay in H_TEST

                def caslike(st):
                    return _goto(st, tid, PC.H_CAS)

                def other(st):
                    # cannot install: value mismatch or a third descriptor;
                    # drive the helped op to Failed
                    st = _set(st, "help_ok", tid, False)
                    return _goto(st, tid, PC.H_STATUS_CAS)

                return lax.cond(
                    installed_, installed,
                    lambda s: lax.cond(
                        rdcss_mine | (word == s["d_exp"][dt, j]),
                        caslike, other, s),
                    st)

            return lax.cond(j >= cfg.k, all_done, probe, st)

        return lax.cond(state != ST_UNDECIDED, decided, undecided, st)

    return lax.cond(_help_valid(cfg, st, tid), live, abandon, st)


def br_h_cas(cfg, st, tid):
    """Helper CAS-install (+fused persist & dirty-clear; see module doc)."""
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)
    j = st["help_tgt"][tid]

    def abandon(st):
        st = _set(st, "help_desc", tid, jnp.int32(-1))
        return _goto(st, tid, st["ret_pc"][tid])

    def live(st):
        addr = st["d_addr"][dt, j]
        word = st["cache"][addr]
        rdcss = (_u32(h) << TAG_SHIFT) | _u32(TAG_RDCSS)
        # install from the expected value OR promote our own RDCSS
        eligible = (word == st["d_exp"][dt, j]) | (word == rdcss)
        expected = jnp.where(eligible, word, st["d_exp"][dt, j])
        desc_dirty = (_u32(h) << TAG_SHIFT) | _u32(TAG_DESC_DIRTY)
        st, ok = _ev_cas_word(cfg, st, tid, addr, expected, desc_dirty)
        ok = ok & eligible

        def persist_clear(st):
            st = _ev_persist_word(cfg, st, tid, addr)
            clean = (_u32(h) << TAG_SHIFT) | _u32(TAG_DESC)
            st = _ev_store_word(cfg, st, tid, addr, clean)
            st = _set(st, "help_tgt", tid, j + 1)
            return _goto(st, tid, PC.H_TEST)

        return lax.cond(ok, persist_clear,
                        lambda s: _goto(s, tid, PC.H_TEST), st)

    return lax.cond(_help_valid(cfg, st, tid), live, abandon, st)


def br_h_status_cas(cfg, st, tid):
    """Helper decides the helped op's status (racing the owner)."""
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)

    def abandon(st):
        st = _set(st, "help_desc", tid, jnp.int32(-1))
        return _goto(st, tid, st["ret_pc"][tid])

    def live(st):
        target = jnp.where(st["help_ok"][tid], ST_SUCCEEDED, ST_FAILED)
        cur = st["d_state"][dt]
        st = dict(st)
        st["d_state"] = st["d_state"].at[dt].set(
            jnp.where(cur == ST_UNDECIDED, target, cur))
        st = _ev_desc_store(cfg, st, tid, dt, cas_class=True)
        # helper persists the (possibly dirty) status before acting on it --
        # required for the recovery argument (DESIGN.md Sec. 2.1)
        st = _ev_persist_desc_state(cfg, st, tid, dt)
        st = _set(st, "help_tgt", tid, jnp.int32(0))
        return _goto(st, tid, PC.H_FIN_CAS)

    return lax.cond(_help_valid(cfg, st, tid), live, abandon, st)


def br_h_fin_cas(cfg, st, tid):
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)
    j = st["help_tgt"][tid]

    def abandon(st):
        st = _set(st, "help_desc", tid, jnp.int32(-1))
        return _goto(st, tid, st["ret_pc"][tid])

    def live(st):
        def done(st):
            st = _set(st, "help_desc", tid, jnp.int32(-1))
            return _goto(st, tid, st["ret_pc"][tid])

        def fin(st):
            addr = st["d_addr"][dt, j]
            word = st["cache"][addr]
            tag = word & TAG_MASK
            is_h = ((word >> TAG_SHIFT) == _u32(h)) & \
                   ((tag == TAG_DESC) | (tag == TAG_DESC_DIRTY))
            succeeded = st["d_state"][dt] == ST_SUCCEEDED
            final = jnp.where(succeeded, st["d_des"][dt, j],
                              st["d_exp"][dt, j])

            def go(st):
                st, ok = _ev_cas_word(cfg, st, tid, addr, word,
                                      final | _u32(TAG_DIRTY))
                return lax.cond(
                    ok, lambda s: _goto(s, tid, PC.H_FIN_PERSIST),
                    lambda s: _set(s, "help_tgt", tid, j + 1), st)

            def skip(st):
                st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
                return _set(st, "help_tgt", tid, j + 1)

            return lax.cond(is_h, go, skip, st)

        return lax.cond(j >= cfg.k, done, fin, st)

    return lax.cond(_help_valid(cfg, st, tid), live, abandon, st)


def br_h_fin_persist(cfg, st, tid):
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)
    j = st["help_tgt"][tid]
    st = _ev_persist_word(cfg, st, tid, st["d_addr"][dt, j])
    return _goto(st, tid, PC.H_FIN_CLEAR)


def br_h_fin_clear(cfg, st, tid):
    h = st["help_desc"][tid]
    dt = _desc_tid(cfg, h)
    j = st["help_tgt"][tid]
    addr = st["d_addr"][dt, j]
    word = st["cache"][addr]
    is_dirty = (word & TAG_MASK) == TAG_DIRTY
    st = lax.cond(is_dirty,
                  lambda s: _ev_store_word(cfg, s, tid, addr,
                                           word & ~_u32(TAG_MASK)),
                  lambda s: _ev_local(cfg, s, tid), st)
    st = _set(st, "help_tgt", tid, j + 1)
    return _goto(st, tid, PC.H_FIN_CAS)


# ===========================================================================
# PCAS (Wang et al.'s persistent single-word CAS, with TTAS + back-off)
# ===========================================================================

def br_p_read(cfg, st, tid):
    addrs = _cur_op_addrs(cfg, st, tid)
    addr = addrs[0]
    st = _ev_load(cfg, st, tid, _line_of(cfg, addr))
    word = st["cache"][addr]
    tag = word & TAG_MASK

    def busy(st):
        return _enter_wait(cfg, st, tid, jnp.int32(PC.P_READ))

    def free(st):
        st = dict(st)
        st["exp"] = st["exp"].at[tid, 0].set(word >> TAG_SHIFT)
        st = _reset_backoff(cfg, st, tid)
        return _goto(st, tid, PC.P_CAS)

    return lax.cond(_is_busy_tag(tag), busy, free, st)


def br_p_cas(cfg, st, tid):
    addrs = _cur_op_addrs(cfg, st, tid)
    addr = addrs[0]
    v = st["exp"][tid, 0]
    expected = v << TAG_SHIFT
    desired_dirty = ((v + _u32(1)) << TAG_SHIFT) | _u32(TAG_DIRTY)
    st, ok = _ev_cas_word(cfg, st, tid, addr, expected, desired_dirty)
    cdt = st["counters"].dtype
    st = _bump(st, tid, CNT_FAILS, jnp.where(ok, 0, 1).astype(cdt))
    return lax.cond(ok, lambda s: _goto(s, tid, PC.P_PERSIST),
                    lambda s: _goto(s, tid, PC.P_READ), st)


def br_p_persist(cfg, st, tid):
    addrs = _cur_op_addrs(cfg, st, tid)
    st = _ev_persist_word(cfg, st, tid, addrs[0])
    return _goto(st, tid, PC.P_CLEAR)


def br_p_clear(cfg, st, tid):
    addrs = _cur_op_addrs(cfg, st, tid)
    addr = addrs[0]
    clean = (st["exp"][tid, 0] + _u32(1)) << TAG_SHIFT
    st = _ev_store_word(cfg, st, tid, addr, clean, cas_class=True)
    st = _set(st, "success", tid, True)
    return _goto(st, tid, PC.OP_DONE)


# ===========================================================================
# Dispatcher
# ===========================================================================

def _noop(cfg, st, tid):
    return _ev_local(cfg, st, tid)


_BRANCHES = {
    PC.READ_TGT: br_read_tgt,
    PC.READ_WAIT: br_read_wait,
    PC.INIT_DESC: br_init_desc,
    PC.PERSIST_DESC: br_persist_desc,
    PC.RESERVE_TEST: br_reserve_test,
    PC.RESERVE_WAIT: br_read_wait,     # shared wait body
    PC.RESERVE_CAS: br_reserve_cas,
    PC.PERSIST_TGT: br_persist_tgt,
    PC.SET_SUCC: br_set_succ,
    PC.PERSIST_STATE: br_persist_state,
    PC.FIN_STORE_DIRTY: br_fin_store_dirty,
    PC.FIN_PERSIST_DIRTY: br_fin_persist_dirty,
    PC.FIN_STORE: br_fin_store,
    PC.FIN_PERSIST: br_fin_persist,
    PC.OP_DONE: br_op_done,
    PC.O_RDCSS_CAS: br_o_rdcss_cas,
    PC.O_PROMOTE_CAS: br_o_promote_cas,
    PC.O_PERSIST_TGT: br_o_persist_tgt,
    PC.O_CLEAR_TGT: br_o_clear_tgt,
    PC.O_STATUS_CAS: br_o_status_cas,
    PC.O_STATUS_PERSIST: br_o_status_persist,
    PC.O_STATUS_CLEAR: br_o_status_clear,
    PC.O_FIN_CAS: br_o_fin_cas,
    PC.O_FIN_PERSIST: br_o_fin_persist,
    PC.O_FIN_CLEAR: br_o_fin_clear,
    PC.H_TEST: br_h_test,
    PC.H_CAS: br_h_cas,
    PC.H_STATUS_CAS: br_h_status_cas,
    PC.H_FIN_CAS: br_h_fin_cas,
    PC.H_FIN_PERSIST: br_h_fin_persist,
    PC.H_FIN_CLEAR: br_h_fin_clear,
    PC.P_READ: br_p_read,
    PC.P_CAS: br_p_cas,
    PC.P_PERSIST: br_p_persist,
    PC.P_CLEAR: br_p_clear,
}

# Which PCs each algorithm can actually reach (keeps switch tables small).
_ALG_PCS = {
    ALG_OURS: [PC.READ_TGT, PC.READ_WAIT, PC.INIT_DESC, PC.PERSIST_DESC,
               PC.RESERVE_TEST, PC.RESERVE_WAIT, PC.RESERVE_CAS,
               PC.PERSIST_TGT, PC.SET_SUCC, PC.PERSIST_STATE, PC.FIN_STORE,
               PC.FIN_PERSIST, PC.OP_DONE],
    ALG_OURS_DF: [PC.READ_TGT, PC.READ_WAIT, PC.INIT_DESC, PC.PERSIST_DESC,
                  PC.RESERVE_TEST, PC.RESERVE_WAIT, PC.RESERVE_CAS,
                  PC.PERSIST_TGT, PC.SET_SUCC, PC.PERSIST_STATE,
                  PC.FIN_STORE_DIRTY, PC.FIN_PERSIST_DIRTY, PC.FIN_STORE,
                  PC.FIN_PERSIST, PC.OP_DONE],
    ALG_ORIGINAL: [PC.READ_TGT, PC.INIT_DESC, PC.PERSIST_DESC,
                   PC.O_RDCSS_CAS, PC.O_PROMOTE_CAS, PC.O_PERSIST_TGT,
                   PC.O_CLEAR_TGT, PC.O_STATUS_CAS, PC.O_STATUS_PERSIST,
                   PC.O_STATUS_CLEAR, PC.O_FIN_CAS, PC.O_FIN_PERSIST,
                   PC.O_FIN_CLEAR, PC.OP_DONE, PC.H_TEST, PC.H_CAS,
                   PC.H_STATUS_CAS, PC.H_FIN_CAS, PC.H_FIN_PERSIST,
                   PC.H_FIN_CLEAR],
    ALG_PCAS: [PC.P_READ, PC.READ_WAIT, PC.P_CAS, PC.P_PERSIST, PC.P_CLEAR,
               PC.OP_DONE],
}


@functools.lru_cache(maxsize=None)
def _pc_remap(algorithm: str):
    """Map global PC values -> dense branch indices for this algorithm."""
    pcs = _ALG_PCS[algorithm]
    table = [0] * PC.COUNT
    for i, pc in enumerate(pcs):
        table[pc] = i
    return tuple(pcs), tuple(table)


def step(cfg: SimConfig, st: Dict[str, Any], tid) -> Dict[str, Any]:
    """Execute one micro-op of thread ``tid``."""
    pcs, table = _pc_remap(cfg.algorithm)
    remap = jnp.asarray(table, jnp.int32)
    branches = [functools.partial(_BRANCHES[pc], cfg, tid=tid) for pc in pcs]
    idx = remap[st["pc"][tid]]
    return lax.switch(idx, branches, st)

"""Simulation driver: jit'd `lax.scan` over a deterministic interleaving.

`run_sim` executes `cfg.n_steps` scheduler slots (one micro-op each) and
optionally *drains* in-flight operations so the memory reaches quiescence
(every word payload-tagged, cache == pmem) — the precondition for the exact
sum-invariant checks in the tests.

Throughput is modeled as  total completed ops / max-over-threads cycles
(threads run concurrently on real hardware; the per-thread cycle accumulators
already include contention, back-off and flush costs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import engine
from .model import (ALG_PCAS, CNT_CAS, CNT_CYCLES, CNT_FAILS, CNT_FLUSH,
                    CNT_HELPS, CNT_INVAL, CNT_LOAD, CNT_OPS, CNT_STORE, PC,
                    SimConfig, TAG_MASK, TAG_SHIFT,
                    generate_schedule, init_state)


def _start_pc(cfg: SimConfig) -> int:
    return PC.P_READ if cfg.algorithm == ALG_PCAS else PC.READ_TGT


def _scan_steps(cfg: SimConfig, st: Dict[str, Any], schedule: jnp.ndarray):
    def body(st, tid):
        # negative schedule entries are no-ops (lets crash studies truncate a
        # schedule without recompiling)
        st = lax.cond(tid >= 0, lambda s: engine.step(cfg, s, tid),
                      lambda s: s, st)
        return st, None

    st, _ = lax.scan(body, st, schedule)
    return st


def _clean_mask(cfg: SimConfig, st) -> jnp.ndarray:
    """Threads with no in-flight memory side effects (op boundary)."""
    start = _start_pc(cfg)
    pc = st["pc"]
    at_start = pc == start
    waiting_clean = (pc == PC.READ_WAIT) & (st["ret_pc"] == start)
    return at_start | waiting_clean


def _drain(cfg: SimConfig, st: Dict[str, Any], max_rounds: int = 100_000):
    """Step every non-clean thread until all reach an operation boundary."""

    def cond(carry):
        st, rounds = carry
        return (~jnp.all(_clean_mask(cfg, st))) & (rounds < max_rounds)

    def body(carry):
        st, rounds = carry

        def per_thread(t, st):
            dirty = ~_clean_mask(cfg, st)[t]
            return lax.cond(dirty, lambda s: engine.step(cfg, s, t),
                            lambda s: s, st)

        st = lax.fori_loop(0, cfg.n_threads, per_thread, st)
        return st, rounds + 1

    st, rounds = lax.while_loop(cond, body, (st, jnp.int32(0)))
    return st, rounds


@dataclasses.dataclass
class SimResult:
    cfg: SimConfig
    state: Dict[str, Any]
    drained: bool
    drain_rounds: int

    # ----- instrumentation accessors --------------------------------------
    @property
    def counters(self) -> np.ndarray:
        return np.asarray(self.state["counters"])

    def total(self, cnt: int) -> int:
        return int(self.counters[:, cnt].sum())

    @property
    def ops_completed(self) -> int:
        return self.total(CNT_OPS)

    @property
    def wall_cycles(self) -> int:
        return int(self.counters[:, CNT_CYCLES].max())

    @property
    def throughput(self) -> float:
        """Completed operations per modeled cycle (scale-free)."""
        return self.ops_completed / max(1, self.wall_cycles)

    def mean_latency_cycles(self) -> float:
        """Average cycles per completed op, per thread, averaged."""
        ops = self.counters[:, CNT_OPS].astype(np.float64)
        cyc = self.counters[:, CNT_CYCLES].astype(np.float64)
        ok = ops > 0
        if not ok.any():
            return float("inf")
        return float((cyc[ok] / ops[ok]).mean())

    def percentile_latency_cycles(self, q: float) -> float:
        """Per-thread cycles/op distribution percentile (paper's p1/p99)."""
        ops = self.counters[:, CNT_OPS].astype(np.float64)
        cyc = self.counters[:, CNT_CYCLES].astype(np.float64)
        ok = ops > 0
        if not ok.any():
            return float("inf")
        return float(np.percentile(cyc[ok] / ops[ok], q))

    def per_op(self, cnt: int) -> float:
        """Average count per *successful* op (incl. retry overheads)."""
        return self.total(cnt) / max(1, self.ops_completed)

    def summary(self) -> Dict[str, float]:
        return {
            "algorithm": self.cfg.algorithm,
            "threads": self.cfg.n_threads,
            "k": self.cfg.k,
            "alpha": self.cfg.alpha,
            "ops": self.ops_completed,
            "fails": self.total(CNT_FAILS),
            "throughput_per_cycle": self.throughput,
            "cas_per_op": self.per_op(CNT_CAS),
            "flush_per_op": self.per_op(CNT_FLUSH),
            "load_per_op": self.per_op(CNT_LOAD),
            "store_per_op": self.per_op(CNT_STORE),
            "inval_per_op": self.per_op(CNT_INVAL),
            "helps": self.total(CNT_HELPS),
            "wall_cycles": self.wall_cycles,
        }

    # ----- invariants -------------------------------------------------------
    def payload_values(self, which: str = "pmem") -> np.ndarray:
        words = np.asarray(self.state[which])
        return words >> TAG_SHIFT

    def tags(self, which: str = "pmem") -> np.ndarray:
        return np.asarray(self.state[which]) & int(TAG_MASK)

    def expected_histogram(self) -> np.ndarray:
        """Per-word successful-increment counts implied by op_idx.

        Ops are retried until success, so thread t's completed set is exactly
        its first op_idx[t] pre-generated ops (with wrap-around reuse).
        """
        ops = np.asarray(self.state["ops"])  # [T, max_ops, k]
        op_idx = np.asarray(self.state["op_idx"])
        hist = np.zeros(self.cfg.n_words, dtype=np.int64)
        for t in range(self.cfg.n_threads):
            n = int(op_idx[t])
            full, part = divmod(n, self.cfg.max_ops)
            if full:
                np.add.at(hist, ops[t].reshape(-1), full)
            if part:
                np.add.at(hist, ops[t, :part].reshape(-1), 1)
        return hist


import functools


@functools.lru_cache(maxsize=64)
def _compiled_runner(cfg: SimConfig, drain: bool):
    @jax.jit
    def go(st, schedule):
        st = _scan_steps(cfg, st, schedule)
        if drain:
            st, rounds = _drain(cfg, st)
        else:
            rounds = jnp.int32(0)
        return st, rounds

    return go


def run_sim(cfg: SimConfig,
            ops: Optional[np.ndarray] = None,
            schedule: Optional[np.ndarray] = None,
            drain: bool = True) -> SimResult:
    """Run the simulation (jit-compiled; deterministic given cfg/ops/schedule)."""
    cfg.validate()
    st = init_state(cfg, ops)
    if schedule is None:
        schedule = generate_schedule(cfg)
    schedule = jnp.asarray(schedule, jnp.int32)

    go = _compiled_runner(cfg, drain)
    st, rounds = go(st, schedule)
    st = jax.tree_util.tree_map(lambda x: np.asarray(x), st)
    return SimResult(cfg=cfg, state=st, drained=drain,
                     drain_rounds=int(rounds))


def run_until(cfg: SimConfig, n_steps: int,
              ops: Optional[np.ndarray] = None,
              schedule: Optional[np.ndarray] = None) -> SimResult:
    """Run exactly n_steps micro-ops WITHOUT draining (for crash studies).

    The schedule keeps its full cfg.n_steps length with entries >= n_steps
    masked to -1 (no-op), so every crash point reuses one compiled scan.
    """
    if schedule is None:
        schedule = generate_schedule(cfg)
    schedule = np.asarray(schedule).copy()
    schedule[n_steps:] = -1
    return run_sim(cfg, ops=ops, schedule=schedule, drain=False)

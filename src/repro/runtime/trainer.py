"""Fault-tolerant training runtime.

Wraps the jitted train step with:
- atomic multi-group checkpointing (params / opt / data-iterator / rng
  committed together through the descriptor-WAL committer — the paper's
  technique guaranteeing no torn training state),
- automatic resume from the newest committed checkpoint,
- async (double-buffered) checkpoints overlapping training,
- straggler detection: per-step wall time is monitored and steps slower
  than ``straggler_factor`` x the running median are counted/logged — on
  a real cluster this feeds the reshard/evict decision,
- preemption hook: ``request_stop()`` finishes the current step, commits,
  and exits cleanly (SIGTERM-style elasticity).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointManager, CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.models import Model
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_async: bool = False
    ckpt_dir: str = "/tmp/repro_ckpt"
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 mesh=None, shardings=None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        mgr_cls = (AsyncCheckpointManager if tcfg.ckpt_async
                   else CheckpointManager)
        self.ckpt = mgr_cls(tcfg.ckpt_dir)
        self._stop = False
        self.step_times: list = []
        self.stragglers = 0
        self.metrics_log: list = []

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
            params, opt_state, info = adamw.update(opt_cfg, grads, opt_state,
                                                   params)
            return params, opt_state, {"loss": loss, **info}

        kw = {}
        if shardings is not None:
            kw = dict(in_shardings=shardings[0], out_shardings=shardings[1],
                      donate_argnums=(0, 1))
        self._step = jax.jit(train_step, **kw)

    def request_stop(self):
        self._stop = True

    # -- state ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init_params(jax.random.PRNGKey(seed))
        opt = adamw.init_state(self.opt_cfg, params)
        stream = SyntheticStream(self.data_cfg)
        return params, opt, stream, 0

    def restore_or_init(self, seed: int = 0):
        got = self.ckpt.restore()
        if got is None:
            return self.init_state(seed)
        step, state = got
        params = state["params"]
        opt = state["opt"]
        opt["step"] = jnp.asarray(np.asarray(opt["step"]).reshape(()))
        stream = SyntheticStream.from_state(self.data_cfg,
                                            state["data_state"])
        return params, opt, stream, int(np.asarray(state["meta_state"]
                                                   ["next_step"]))

    def _save(self, step, params, opt, stream):
        state = {
            "params": jax.tree_util.tree_map(np.asarray, params),
            "opt": jax.tree_util.tree_map(np.asarray, opt),
            "data_state": {k: np.asarray(v)
                           for k, v in stream.state().items()},
            "meta_state": {"next_step": np.asarray(step + 1)},
        }
        if self.tcfg.ckpt_async:
            self.ckpt.save_async(step + 1, state)
        else:
            self.ckpt.save(step + 1, state)

    # -- loop -------------------------------------------------------------------
    def run(self, seed: int = 0, crash_at_step: Optional[int] = None):
        params, opt, stream, start = self.restore_or_init(seed)
        t = self.tcfg
        losses = []
        for step in range(start, t.total_steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v)
                     for k, v in stream.next_batch().items()}
            params, opt, m = self._step(params, opt, batch)
            loss = float(m["loss"])
            losses.append(loss)
            dt = time.time() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-50:]))
            if len(self.step_times) > 5 and dt > t.straggler_factor * med:
                self.stragglers += 1
            if step % t.log_every == 0:
                self.metrics_log.append(
                    {"step": step, "loss": loss, "sec": dt})
            if crash_at_step is not None and step == crash_at_step:
                raise RuntimeError(f"injected crash at step {step}")
            if (step + 1) % t.ckpt_every == 0 or self._stop or \
                    step + 1 == t.total_steps:
                self._save(step, params, opt, stream)
            if self._stop:
                break
        if t.ckpt_async:
            self.ckpt.close()
        return params, opt, losses

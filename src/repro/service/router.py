"""Shard routing: partitioning the word/key space across backends.

The service owns S backend instances ("shards"); every submitted op must
land on exactly one of them — or be flagged cross-shard and serialized
(``repro.service.scheduler``).  Two address partitions are supported:

- ``range``:  shard ``addr // words_per_shard`` — contiguous blocks,
  the natural fit for structures occupying contiguous word ranges;
- ``hash``:   shard ``addr % n_shards``, local ``addr // n_shards`` —
  the interleaved (modular) member of the hash family.  Word addresses
  are already uniform integers, so the identity hash keeps the
  global<->local mapping a compact bijection; *key* routing (the KV
  service) uses a real multiplicative hash instead, because keys are
  anything but uniform.

Both are bijections ``global addr <-> (shard, local addr)``, so an op
can be translated into a shard's private address space and back —
each shard backend only ever sees local addresses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.pmwcas import MwCASOp, Target
from repro.structures import key_shard

# shard id returned for ops whose targets span shards (scheduler routes
# these to the serialized global round)
CROSS_SHARD = -1

_POLICIES = ("range", "hash")


@dataclasses.dataclass(frozen=True)
class RoutedOp:
    """One classified submission: the owning shard (or CROSS_SHARD) and
    the op translated into shard-local address space.  Cross-shard ops
    keep a per-shard breakdown instead of a single local op."""
    op: MwCASOp                          # original, global addresses
    shard: int                           # owning shard or CROSS_SHARD
    local: MwCASOp = None                # shard-local translation
    parts: Dict[int, Tuple[Target, ...]] = None   # cross: shard -> targets

    @property
    def is_cross(self) -> bool:
        return self.shard == CROSS_SHARD


class ShardRouter:
    def __init__(self, n_shards: int, *, words_per_shard: int = 0,
                 policy: str = "range"):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        if policy not in _POLICIES:
            raise ValueError(f"policy {policy!r} not in {_POLICIES}")
        if policy == "range" and words_per_shard < 1:
            raise ValueError("range partition needs words_per_shard >= 1")
        self.n_shards = n_shards
        self.words_per_shard = words_per_shard
        self.policy = policy
        # key-range routing overrides (online shard migration): ordered
        # (lo, hi, shard) rows consulted BEFORE the hash — the in-memory
        # image of the service's persistent route table
        self.ranges: List[Tuple[int, int, int]] = []

    # -- address partition -----------------------------------------------------
    def shard_of_addr(self, addr: int) -> int:
        if addr < 0:
            raise ValueError(f"negative address {addr}")
        if self.words_per_shard and \
                addr >= self.n_shards * self.words_per_shard:
            # array-shaped shards silently drop out-of-range scatters,
            # so an unbounded address would "succeed" writing nothing
            raise ValueError(f"address {addr} beyond shard space "
                             f"({self.n_shards} x "
                             f"{self.words_per_shard} words)")
        if self.policy == "range":
            return addr // self.words_per_shard
        return addr % self.n_shards

    def local(self, addr: int) -> int:
        """Global address -> the owning shard's local word index."""
        self.shard_of_addr(addr)                 # bounds check
        if self.policy == "range":
            return addr % self.words_per_shard
        return addr // self.n_shards

    def global_addr(self, shard: int, local: int) -> int:
        """Inverse of (shard_of_addr, local)."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range")
        if self.policy == "range":
            return shard * self.words_per_shard + local
        return local * self.n_shards + shard

    # -- key partition (KV service) --------------------------------------------
    def shard_of_key(self, key: int) -> int:
        """Multiplicative-hash key routing for the KV front (the same
        :func:`repro.structures.key_shard` that ``partition_ops``
        uses, so pre-partitioned workloads land where ops route).
        Range overrides installed by a completed shard migration win
        over the hash."""
        for lo, hi, shard in self.ranges:
            if lo <= key < hi:
                return shard
        return key_shard(key, self.n_shards)

    def hash_shard_of_key(self, key: int) -> int:
        """The pure hash route, ignoring overrides (what the key would
        do with no migrations — recovery uses this to tell a migrated
        copy from a key that natively hashes to its shard)."""
        return key_shard(key, self.n_shards)

    def set_range(self, lo: int, hi: int, shard: int) -> None:
        """Install a key-range override; the newest override wins over
        its whole range, so overlapping older rows are TRIMMED to their
        non-overlapping remainder (a later migration may re-migrate part
        of an earlier one's range).  Idempotent; the caller persists the
        route table (``MigrationLog.save_routes``) — this is only the
        in-memory image."""
        if not lo < hi:
            raise ValueError(f"empty key range [{lo}, {hi})")
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range")
        self.clear_range(lo, hi)
        self.ranges.append((lo, hi, shard))
        self.ranges.sort()

    def clear_range(self, lo: int, hi: int) -> None:
        """Remove [lo, hi) from every override, trimming partial
        overlaps to their remainder."""
        out: List[Tuple[int, int, int]] = []
        for a, b, s in self.ranges:
            if b <= lo or hi <= a:
                out.append((a, b, s))
                continue
            if a < lo:
                out.append((a, lo, s))
            if hi < b:
                out.append((hi, b, s))
        self.ranges = out

    # -- op classification -----------------------------------------------------
    def classify(self, op: MwCASOp) -> RoutedOp:
        """Route one op: single-shard ops get a local translation,
        spanning ops a per-shard breakdown under CROSS_SHARD."""
        by_shard: Dict[int, List[Target]] = {}
        for t in op.targets:
            if not isinstance(t.addr, int):
                raise TypeError(
                    f"service routing needs int word addresses, got "
                    f"{t.addr!r}")
            s = self.shard_of_addr(t.addr)
            by_shard.setdefault(s, []).append(
                Target(self.local(t.addr), t.expected, t.desired))
        if len(by_shard) == 1:
            ((shard, targets),) = by_shard.items()
            return RoutedOp(op=op, shard=shard, local=MwCASOp(targets))
        return RoutedOp(op=op, shard=CROSS_SHARD,
                        parts={s: tuple(ts) for s, ts in by_shard.items()})

"""Async batched MwCAS scheduling over sharded backends.

``BatchScheduler`` is the raw-op layer of the service: N logical clients
``submit`` :class:`MwCASOp`\\ s (global addresses) and get futures; the
scheduler routes each op to its shard, coalesces queued ops into
conflict-free per-shard rounds, executes all shard rounds in one wave
(kernel shards through the single stacked dispatch), and completes the
futures with per-op :class:`OpResult` verdicts.

Scheduling rules:

- **conflict-defer**: an op whose targets collide with an op already
  scheduled in this round is deferred to the next round, not executed
  to certain (b)-failure — deferral is invisible to the client except
  as latency (measured in rounds).
- **at-most-one execution**: every submission is executed exactly once;
  a CAS that fails condition (a) (stale expected values) completes its
  future with ``success=False``.  Retry policy belongs to the caller —
  the KV front (`repro.service.KVService`) recompiles and resubmits.
- **cross-shard serialization**: ops whose targets span shards execute
  in a dedicated GLOBAL round — one at a time, with no concurrent shard
  rounds — so multi-word atomicity is never split across interleavings.
  With durable shards, atomicity across a *crash* additionally needs the
  decision log (:class:`repro.service.CrossShardJournal`): pass one, and
  call :meth:`recover` after re-attaching crashed shards.
- **epoch durability is bounded-loss at this layer**: unlike the KV
  front (which withholds acks behind open epochs), the raw scheduler
  completes futures at commit time — under ``epoch_rounds > 1`` a
  completed-but-unsynced op can be lost to a crash, bounded by the
  epoch window.  :meth:`drain` closes every shard's open epoch before
  returning, so a drained scheduler is fully durable; callers needing
  a mid-stream barrier call :meth:`sync_epochs` explicitly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.obs import instant, span, tracing_enabled
from repro.pmwcas import Backend, MwCASOp, OpResult, Target

from .executor import execute_wave, schedule_wave, select_executor
from .journal import CrossShardJournal
from .router import RoutedOp, ShardRouter
from .stats import ServiceStats, collect_durability, fresh_stats


class ServiceError(RuntimeError):
    """The service observed a state its protocol rules out."""


class OpFuture:
    """Client handle for one submitted op (completed by ``step``)."""

    __slots__ = ("op", "client", "shard", "seq", "op_id", "submit_step",
                 "submit_ns", "done", "result", "latency_rounds")

    def __init__(self, op: MwCASOp, client, shard: int, seq: int,
                 submit_step: int):
        self.op = op
        self.client = client
        self.shard = shard
        self.seq = seq
        # stable causal identity for the op's trace events (DESIGN §13)
        self.op_id = f"op{seq}"
        self.submit_step = submit_step
        self.submit_ns = time.perf_counter_ns()
        self.done = False
        self.result: Optional[OpResult] = None
        self.latency_rounds = 0

    @property
    def success(self) -> bool:
        return bool(self.done and self.result and self.result.success)

    def __repr__(self) -> str:
        state = (f"done success={self.result.success}" if self.done
                 else "pending")
        return f"OpFuture(client={self.client}, shard={self.shard}, {state})"


@dataclasses.dataclass
class _Pending:
    """Internal queue entry: the routed op plus its future."""
    routed: RoutedOp
    future: OpFuture

    @property
    def local(self) -> MwCASOp:          # build_rounds reads .local
        return self.routed.local


class BatchScheduler:
    def __init__(self, backends: Sequence[Backend], router: ShardRouter, *,
                 round_cap: int = 16, executor=None,
                 journal: Optional[CrossShardJournal] = None,
                 journal_prune_every: int = 16,
                 wal_prune_every: int = 0):
        """``journal_prune_every``: GC the cross-shard decision journal
        every N serialized global rounds (0 disables).  Without the
        cadence a long-running service grows ``xwal/`` one record per
        cross-shard op, forever — the scheduler-level analogue of the
        committer's ``prune_completed`` WAL hygiene.

        ``wal_prune_every``: the same hygiene one layer down — every N
        round waves, durably drop spent PER-SHARD committer WAL records
        (``DurableBackend.prune_completed``) on shards that support it
        (0 disables)."""
        if router.n_shards != len(backends):
            raise ValueError(f"router has {router.n_shards} shards, got "
                             f"{len(backends)} backends")
        if round_cap < 1:
            raise ValueError("round_cap must be >= 1")
        if journal_prune_every < 0:
            raise ValueError("journal_prune_every must be >= 0")
        if wal_prune_every < 0:
            raise ValueError("wal_prune_every must be >= 0")
        self.backends = list(backends)
        self.router = router
        self.round_cap = round_cap
        self.executor = executor or select_executor(self.backends,
                                                    round_cap=round_cap)
        self.journal = journal
        self.journal_prune_every = journal_prune_every
        self.wal_prune_every = wal_prune_every
        self.stats: ServiceStats = fresh_stats(len(backends), round_cap)
        self._queues: Dict[int, List[_Pending]] = {
            s: [] for s in range(len(backends))}
        self._cross: List[_Pending] = []
        self._seq = 0

    # -- submission ------------------------------------------------------------
    def submit(self, op: MwCASOp, client=0) -> OpFuture:
        routed = self.router.classify(op)
        fut = OpFuture(op, client, routed.shard, self._seq, self.stats.steps)
        self._seq += 1
        self.stats.submitted += 1
        if tracing_enabled():
            instant("op.submit", op_id=fut.op_id, client=client,
                    shard=routed.shard, cross=routed.is_cross,
                    step=self.stats.steps)
        if routed.is_cross:
            self._cross.append(_Pending(routed, fut))
        else:
            self._queues[routed.shard].append(_Pending(routed, fut))
        return fut

    def submit_many(self, ops: Sequence[MwCASOp],
                    client=0) -> List[OpFuture]:
        return [self.submit(op, client) for op in ops]

    @property
    def pending_count(self) -> int:
        return len(self._cross) + sum(len(q) for q in self._queues.values())

    # -- execution -------------------------------------------------------------
    def step(self) -> int:
        """Drive one round wave; returns futures completed.

        If cross-shard ops are queued, this step is a serialized GLOBAL
        round (each queued cross op runs alone, in submission order) and
        no shard rounds execute; otherwise one conflict-free round per
        shard executes, all in the same wave.
        """
        if not self.pending_count:
            return 0
        self.stats.steps += 1
        with span("scheduler.wave", step=self.stats.steps) as sp:
            if self._cross:
                completed = self._global_round()
            else:
                completed = self._shard_rounds()
            if (self.wal_prune_every and
                    self.stats.steps % self.wal_prune_every == 0):
                # per-shard committer WAL hygiene, on a wave cadence
                for b in self.backends:
                    prune = getattr(b, "prune_completed", None)
                    if prune is not None:
                        self.stats.wal_pruned += prune()
            sp.set(completed=completed)
        return completed

    def drain(self, max_steps: Optional[int] = None) -> int:
        """Step until every queue is empty; returns futures completed.
        Terminates: every step executes (or serially completes) at least
        one queued op."""
        limit = (self.pending_count + 4) if max_steps is None else max_steps
        done = 0
        for _ in range(limit):
            if not self.pending_count:
                break
            done += self.step()
        if self.pending_count:
            raise ServiceError(
                f"drain did not converge in {limit} steps "
                f"({self.pending_count} ops still queued)")
        # a drained scheduler promises durability: close open epochs so
        # every completed future's round is actually on the medium
        self.sync_epochs()
        return done

    def sync_epochs(self) -> int:
        """Durability barrier over the shards: close every open epoch
        (one fence each).  Returns rounds made durable; counted in
        ``stats.epoch_syncs`` when anything flushed."""
        synced = 0
        for b in self.backends:
            sync = getattr(b, "sync", None)
            if sync is not None:
                synced += sync()
        if synced:
            self.stats.epoch_syncs += 1
        return synced

    def read(self, addr: int) -> int:
        """Read one word through the shard that owns it."""
        shard = self.router.shard_of_addr(addr)
        return self.backends[shard].read(self.router.local(addr))

    # -- shard rounds ----------------------------------------------------------
    def _shard_rounds(self) -> int:
        with span("wave.schedule"):
            rounds, leftovers = schedule_wave(
                {s: q for s, q in self._queues.items() if q},
                self.round_cap, self.stats)
            for s in self._queues:
                self._queues[s] = leftovers.get(s, [])
        if not rounds:
            return 0
        completed = 0
        with span("wave.dispatch", shards=len(rounds)):
            dispatch_start_ns = time.perf_counter_ns()
            persist_ns0 = self._persist_ns_total()
            wave = execute_wave(self.executor, self.backends, rounds,
                                self.stats)
        with span("wave.complete"):
            # the wave's fence wall-clock splits evenly across its ops
            # (one group-commit record covers the whole round)
            persist_wave_ns = self._persist_ns_total() - persist_ns0
            n_done = sum(len(pairs) for pairs in wave.values())
            persist_share_us = (persist_wave_ns / 1e3 / n_done
                                if n_done else 0.0)
            for pairs in wave.values():
                for pending, ok in pairs:     # executed verdicts are final
                    self._complete(pending.future, ok,
                                   dispatch_start_ns=dispatch_start_ns,
                                   persist_share_us=persist_share_us)
                    completed += 1
        return completed

    def _persist_ns_total(self) -> int:
        """Wall-clock the durable shards have spent inside persist
        fences, summed (0 for kernel/sim deployments)."""
        total = 0
        for b in self.backends:
            pool = getattr(b, "pool", None)
            if pool is not None:
                total += pool.persist_ns
        return total

    # -- the serialized global round -------------------------------------------
    def _global_round(self) -> int:
        self.stats.cross_rounds += 1
        batch, self._cross = self._cross, []
        completed = 0
        with span("wave.global_round", ops=len(batch)):
            for pending in batch:
                dispatch_start_ns = time.perf_counter_ns()
                persist_ns0 = self._persist_ns_total()
                ok = self._execute_cross(pending.routed)
                self.stats.cross_ops += 1
                self._complete(
                    pending.future, ok,
                    dispatch_start_ns=dispatch_start_ns,
                    persist_share_us=(self._persist_ns_total()
                                      - persist_ns0) / 1e3)
                completed += 1
            if (self.journal is not None and self.journal_prune_every and
                    self.stats.cross_rounds % self.journal_prune_every
                    == 0):
                # journal hygiene on a cadence: COMPLETED decision
                # records are spent (redo never consults them), drop them
                self.stats.journal_pruned += self.journal.prune()
        return completed

    def _execute_cross(self, routed: RoutedOp) -> bool:
        """One cross-shard op: validate, decide (journal), apply per
        shard, complete.  Runs with nothing else in flight (the global
        round is the only execution this step)."""
        parts = routed.parts
        for shard, targets in parts.items():
            for t in targets:
                if self.backends[shard].read(t.addr) != t.expected:
                    return False                       # failed condition (a)
        op_id = f"x{self._seq}-{routed.op.addrs[0]}"
        self._seq += 1
        if self.journal is not None:
            self.journal.decide(op_id, [
                (shard, t.addr, t.expected, t.desired)
                for shard, targets in sorted(parts.items())
                for t in targets])
        for shard in sorted(parts):
            (res,) = self.backends[shard].execute([MwCASOp(parts[shard])])
            if not res.success:
                # nothing else runs during a global round and validation
                # just passed, so a sub-op can never legitimately lose
                raise ServiceError(
                    f"cross-shard sub-op lost on shard {shard} during a "
                    "serialized global round")
        if self.journal is not None:
            self.journal.complete(op_id)
        return True

    # -- crash recovery --------------------------------------------------------
    def recover(self) -> int:
        """Redo incomplete cross-shard decisions from the journal.

        Call after re-attaching recovered shard backends (each durable
        shard's own WAL recovery runs in ``DurableBackend.crash()``).
        Returns the number of ops redone.  Idempotent.
        """
        if self.journal is None:
            return 0
        redone = 0
        with span("scheduler.recover") as sp:
            redone = self._recover_pending()
            sp.set(redone=redone)
        return redone

    def _recover_pending(self) -> int:
        redone = 0
        for rec in self.journal.pending():
            by_shard: Dict[int, List[Target]] = {}
            for shard, addr, exp, des in self.journal.targets_of(rec):
                by_shard.setdefault(shard, []).append(Target(addr, exp, des))
            for shard, targets in sorted(by_shard.items()):
                vals = [self.backends[shard].read(t.addr) for t in targets]
                if all(v == t.desired for v, t in zip(vals, targets)):
                    continue                   # this shard already applied
                if not all(v == t.expected for v, t in zip(vals, targets)):
                    raise ServiceError(
                        f"journal redo of {rec['id']}: shard {shard} words "
                        f"{[t.addr for t in targets]} hold {vals}, neither "
                        "expected nor desired — torn sub-op")
                (res,) = self.backends[shard].execute([MwCASOp(targets)])
                if not res.success:
                    raise ServiceError(
                        f"journal redo of {rec['id']} lost its CAS on "
                        f"shard {shard}")
            self.journal.complete(rec["id"])
            redone += 1
        return redone

    # -- instrumentation -------------------------------------------------------
    def durability_stats(self):
        """Merged committer flush accounting over the durable shards
        (None when no shard is durable)."""
        return collect_durability(self.backends)

    # -- completion ------------------------------------------------------------
    def _complete(self, fut: OpFuture, success: bool, *,
                  dispatch_start_ns: Optional[int] = None,
                  persist_share_us: float = 0.0) -> None:
        fut.done = True
        fut.latency_rounds = self.stats.steps - fut.submit_step
        fut.result = OpResult(index=fut.seq, success=success,
                              backend="service", op=fut.op)
        status = "ok" if success else "conflict"
        latency_us = (time.perf_counter_ns() - fut.submit_ns) / 1e3
        # queue + dispatch + persist partition latency_us exactly (the
        # same decomposition as KVService._complete; the scheduler
        # executes each submission once, so retry_waves is always 0)
        if dispatch_start_ns is None:
            queue_us, dispatch_us, persist_us = latency_us, 0.0, 0.0
        else:
            queue_us = min(max(
                (dispatch_start_ns - fut.submit_ns) / 1e3, 0.0), latency_us)
            persist_us = min(max(persist_share_us, 0.0),
                             latency_us - queue_us)
            dispatch_us = latency_us - queue_us - persist_us
        self.stats.record_completion(
            fut.latency_rounds, status, latency_us=latency_us,
            queue_us=queue_us, dispatch_us=dispatch_us,
            persist_us=persist_us, retry_waves=0)
        if tracing_enabled():
            instant("op.complete", op_id=fut.op_id, status=status,
                    queue_us=round(queue_us, 1),
                    dispatch_us=round(dispatch_us, 1),
                    persist_us=round(persist_us, 1), step=self.stats.steps)

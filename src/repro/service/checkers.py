"""Service-layer crash checker: the online shard-migration sweep.

The structure-level sweeps live in :mod:`repro.structures.checkers`;
this one needs a whole :class:`KVService` (shard pools + the migration
decision log), so it sits in the service layer — structures must not
import upward.
"""
from __future__ import annotations

import pathlib
from typing import Dict

from repro import SimulatedCrash
from repro.structures import CrashCheckError, INSERT, KVOp, OK

from .service import KVService


def check_migration_crash_sweep(load: Dict[int, int], root, *,
                                lo: int, hi: int, dst: int,
                                n_shards: int = 3, n_buckets: int = 32,
                                migration_chunk: int = 2,
                                max_doublings: int = 0,
                                max_crash_points: int = 400) -> int:
    """Crash-at-every-persist sweep through an online shard migration.

    Builds a durable :class:`repro.service.KVService`, loads ``load``,
    then runs ``migrate_range(lo, hi, dst)`` with a crash trap armed on
    ONE pool at a time — each shard's WAL pool and the migration
    decision-log pool in turn — at every persist ordinal until the
    migration completes untrapped.  After each crash the recovered
    service must satisfy, at every point:

    - ``check_integrity()`` equals the loaded items (a migration moves
      keys, it never creates, loses or tears one — rollback deletes
      half-copied residue, roll-forward redoes cleanup);
    - the route table is all-or-nothing: fully swung ``(lo, hi, dst)``
      or absent, per the ROUTED record — never a half-installed route;
    - the decision log has no pending record;
    - every key still reads its loaded value through routing;
    - a SECOND crash/recover cycle reproduces the identical state
      (recovery is idempotent).

    Returns the total number of crash points swept across all pools.
    """
    root = pathlib.Path(root)
    kvops = [KVOp(INSERT, k, v) for k, v in sorted(load.items())]

    def build(run_root):
        svc = KVService(n_shards, backend="durable", n_buckets=n_buckets,
                        max_doublings=max_doublings, durable_root=run_root,
                        migration_chunk=migration_chunk)
        res = svc.apply(kvops)
        if any(r.status != OK for r in res):
            raise CrashCheckError(
                f"migration sweep load failed: "
                f"{[r.status for r in res if r.status != OK]}")
        return svc, svc.check_integrity()

    def pools_of(svc):
        return [b.pool for b in svc.backends] + [svc.mig_pool]

    swept = 0
    for pool_idx in range(n_shards + 1):
        for crash_at in range(max_crash_points + 1):
            svc, before = build(root / f"p{pool_idx}c{crash_at}")
            pool = pools_of(svc)[pool_idx]
            pool.crash_after = pool.persist_count + crash_at
            crashed = False
            try:
                svc.migrate_range(lo, hi, dst)
            except SimulatedCrash:
                crashed = True
            pool.crash_after = None
            svc2 = svc.crash()
            swept += 1
            tag = f"pool={pool_idx} crash_at={crash_at}"
            items = svc2.check_integrity()
            if items != before:
                raise CrashCheckError(
                    f"{tag}: recovered items diverged from load")
            if svc2.router.ranges not in ([], [(lo, hi, dst)]):
                raise CrashCheckError(
                    f"{tag}: half-installed routes {svc2.router.ranges}")
            if svc2.mig_log.pending():
                raise CrashCheckError(
                    f"{tag}: pending record survived recovery")
            for k, v in load.items():
                got = svc2.lookup(k)
                if got != v:
                    raise CrashCheckError(
                        f"{tag}: key {k} reads {got}, loaded {v}")
            svc3 = svc2.crash()
            if (svc3.check_integrity() != items
                    or svc3.router.ranges != svc2.router.ranges):
                raise CrashCheckError(
                    f"{tag}: second crash/recover changed state")
            if not crashed:
                break           # this pool's persists are fully swept
        else:
            raise CrashCheckError(
                f"pool {pool_idx}: migration never completed within "
                f"{max_crash_points} persists")
    return swept

"""Service instrumentation: per-shard round accounting + op latency.

The vocabulary mirrors the paper's evaluation axes — how many CAS rounds
the substrate actually ran, how full each batch was, and how often ops
were deferred (the service's replacement for a lost CAS) or lost a real
conflict — plus client-visible latency measured in ROUNDS, the
substrate-independent unit (a round is one backend batch; wall time per
round is a property of the backend, not of the service).

Two hot-path waste counters ride along (DESIGN.md Sec. 9): the
executor's :class:`~repro.service.DispatchStats` (XLA traces vs cache
hits of the stacked dispatch) attaches after every wave, and
:func:`collect_durability` merges the per-shard committer
:class:`repro.pmwcas.DurabilityStats` (flushes issued vs saved,
commit fences) for durable deployments."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import Histogram, get_registry
from repro.pmwcas import DurabilityStats


def collect_durability(backends: Sequence) -> Optional[DurabilityStats]:
    """Merged flush accounting over every shard whose backend exposes
    ``durability_stats`` (None when no shard is durable)."""
    merged = None
    for b in backends:
        stats = getattr(b, "durability_stats", None)
        if stats is not None:
            merged = DurabilityStats() if merged is None else merged
            merged.merge(stats)
    return merged


@dataclasses.dataclass
class ShardStats:
    """One shard's round accounting."""
    shard: int
    rounds: int = 0              # backend batches executed
    ops_executed: int = 0        # CAS ops submitted across those batches
    ops_won: int = 0             # CAS ops that committed
    defers: int = 0              # conflict-deferred (duplicate target in round)
    overflows: int = 0           # deferred because the round hit round_cap
    out_of_regions: int = 0      # allocator-exhausted FULL verdicts (trees)

    @property
    def conflict_losses(self) -> int:
        return self.ops_executed - self.ops_won


@dataclasses.dataclass
class ServiceStats:
    """Aggregate service instrumentation (scheduler and KV front)."""
    round_cap: int
    shards: List[ShardStats]
    steps: int = 0               # round waves driven (shards run in parallel)
    submitted: int = 0           # client submissions accepted
    completed: int = 0           # futures completed (any status)
    cross_rounds: int = 0        # serialized global rounds
    cross_ops: int = 0           # cross-shard ops executed in them
    journal_pruned: int = 0      # cross-shard records GC'd on cadence
    wal_pruned: int = 0          # spent per-shard WAL records GC'd on cadence
    migrations: int = 0          # key-range migrations decided
    keys_moved: int = 0          # keys copied to their new shard
    # epoch durability (DESIGN.md Sec. 14): acks withheld behind an open
    # epoch, and explicit sync_epochs() barriers that flushed something
    acks_held: int = 0
    epoch_syncs: int = 0
    # per-migration pause: how long the range was held, in service waves
    # (substrate-independent) and wall microseconds (this backend)
    mig_pause_waves: List[int] = dataclasses.field(default_factory=list)
    mig_pause_us: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.mig_pause_us"))
    # the executor's trace-cache accounting, attached after every wave
    # (None until a wave ran or the executor carries no stats)
    dispatch: Optional[object] = None
    latencies: List[int] = dataclasses.field(default_factory=list)
    # wall-clock completion latency alongside the round-based one: rounds
    # stay the substrate-independent unit, microseconds answer "what did a
    # client actually wait" on THIS backend
    latency_us: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.latency_us"))
    # the op-lifecycle breakdown (DESIGN §13): latency_us decomposes as
    # queue_us (submit -> wave dispatch start) + dispatch_us (device +
    # host scheduling) + persist_us (this op's share of the wave's fence
    # wall-clock) — the three sum to latency_us per op BY CONSTRUCTION,
    # so the histograms' means must reconcile (bench-asserted).
    queue_us: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.queue_us"))
    dispatch_us: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.dispatch_us"))
    persist_us: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.persist_us"))
    # waves an op was scheduled into before completing (0 = first try)
    retry_waves: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.retry_waves"))
    by_status: Dict[str, int] = dataclasses.field(default_factory=dict)

    # percentile window: a long-running service would otherwise grow the
    # sample list without bound; the percentiles describe recent traffic
    MAX_LATENCY_SAMPLES = 4096

    # -- recorders -------------------------------------------------------------
    def record_completion(self, latency_rounds: int, status: str,
                          latency_us: Optional[float] = None,
                          queue_us: Optional[float] = None,
                          dispatch_us: Optional[float] = None,
                          persist_us: Optional[float] = None,
                          retry_waves: Optional[int] = None) -> None:
        self.completed += 1
        self.latencies.append(int(latency_rounds))
        if len(self.latencies) > self.MAX_LATENCY_SAMPLES:
            del self.latencies[:len(self.latencies)
                               - self.MAX_LATENCY_SAMPLES]
        if latency_us is not None:
            self.latency_us.record(latency_us)
        # mirror the breakdown into the global registry (same series the
        # benchmark windows and obs_report read) alongside the dataclass
        reg = get_registry()
        if queue_us is not None:
            self.queue_us.record(queue_us)
            reg.histogram("queue_us", component="service").record(queue_us)
        if dispatch_us is not None:
            self.dispatch_us.record(dispatch_us)
            reg.histogram("dispatch_us",
                          component="service").record(dispatch_us)
        if persist_us is not None:
            self.persist_us.record(persist_us)
            reg.histogram("persist_us",
                          component="service").record(persist_us)
        if retry_waves is not None:
            self.retry_waves.record(retry_waves)
            reg.histogram("retry_waves",
                          component="service").record(retry_waves)
        self.by_status[status] = self.by_status.get(status, 0) + 1

    # -- aggregates ------------------------------------------------------------
    @property
    def rounds(self) -> int:
        return sum(s.rounds for s in self.shards) + self.cross_rounds

    @property
    def ops_executed(self) -> int:
        return sum(s.ops_executed for s in self.shards) + self.cross_ops

    @property
    def defers(self) -> int:
        return sum(s.defers for s in self.shards)

    @property
    def defer_rate(self) -> float:
        """Conflict-defers per scheduling decision (deferred ops come up
        for scheduling again, so the denominator counts attempts)."""
        attempts = self.ops_executed + self.defers \
            + sum(s.overflows for s in self.shards)
        return self.defers / attempts if attempts else 0.0

    @property
    def conflict_rate(self) -> float:
        """Executed CAS ops that lost their round."""
        if not self.ops_executed:
            return 0.0
        return sum(s.conflict_losses for s in self.shards) \
            / self.ops_executed

    @property
    def occupancy(self) -> float:
        """Mean batch fill across every executed shard round."""
        rounds = sum(s.rounds for s in self.shards)
        if not rounds or not self.round_cap:
            return 0.0
        return sum(s.ops_executed for s in self.shards) \
            / (rounds * self.round_cap)

    @property
    def ops_per_step(self) -> float:
        """Aggregate round throughput: completions per round wave —
        the quantity that must scale with shard count."""
        return self.completed / self.steps if self.steps else 0.0

    def latency_rounds(self, q: float) -> float:
        """Client-visible latency percentile, in rounds-to-completion,
        over the most recent ``MAX_LATENCY_SAMPLES`` completions."""
        if not self.latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies), q))

    @property
    def p50_latency_rounds(self) -> float:
        return self.latency_rounds(50.0)

    @property
    def p99_latency_rounds(self) -> float:
        return self.latency_rounds(99.0)

    @property
    def p50_latency_us(self) -> float:
        return self.latency_us.p50_us

    @property
    def p99_latency_us(self) -> float:
        return self.latency_us.p99_us

    # -- reporting -------------------------------------------------------------
    def as_row(self) -> Dict[str, float]:
        """Flat record for the benchmark JSON."""
        row = {
            "steps": self.steps, "rounds": self.rounds,
            "completed": self.completed,
            "ops_per_step": round(self.ops_per_step, 3),
            "occupancy": round(self.occupancy, 3),
            "defer_rate": round(self.defer_rate, 3),
            "conflict_rate": round(self.conflict_rate, 3),
            "cross_rounds": self.cross_rounds,
            "wal_pruned": self.wal_pruned,
            "p50_latency_rounds": self.p50_latency_rounds,
            "p99_latency_rounds": self.p99_latency_rounds,
            "p50_latency_us": round(self.p50_latency_us, 3),
            "p99_latency_us": round(self.p99_latency_us, 3),
        }
        if self.queue_us.count:
            row.update({
                "queue_us_p50": round(self.queue_us.p50_us, 3),
                "queue_us_p99": round(self.queue_us.p99_us, 3),
                "dispatch_us_p50": round(self.dispatch_us.p50_us, 3),
                "dispatch_us_p99": round(self.dispatch_us.p99_us, 3),
                "persist_us_p50": round(self.persist_us.p50_us, 3),
                "persist_us_p99": round(self.persist_us.p99_us, 3),
                # means reconcile with latency_us_mean exactly (the
                # three components partition each op's latency)
                "queue_us_mean": round(self.queue_us.mean_us, 3),
                "dispatch_us_mean": round(self.dispatch_us.mean_us, 3),
                "persist_us_mean": round(self.persist_us.mean_us, 3),
                "latency_us_mean": round(self.latency_us.mean_us, 3),
                "retry_waves_max": int(self.retry_waves.max_us),
            })
        if self.acks_held or self.epoch_syncs:
            row.update({
                "acks_held": self.acks_held,
                "epoch_syncs": self.epoch_syncs,
            })
        if self.migrations:
            row.update({
                "migrations": self.migrations,
                "keys_moved": self.keys_moved,
                "mig_pause_waves_max": max(self.mig_pause_waves, default=0),
                "mig_pause_us_p99": round(self.mig_pause_us.p99_us, 3),
            })
        if self.dispatch is not None:
            row.update({
                "traces": self.dispatch.traces,
                "dispatch_hits": self.dispatch.hits,
                "stacked_dispatches": self.dispatch.dispatches,
                "serial_rounds": self.dispatch.serial_rounds,
                "bytes_padded": self.dispatch.bytes_padded,
            })
        return row

    def summary(self) -> str:
        lines = [f"service: {self.completed}/{self.submitted} ops in "
                 f"{self.steps} steps ({self.ops_per_step:.1f} ops/step), "
                 f"{self.rounds} rounds "
                 f"(occupancy {self.occupancy:.2f}, defer rate "
                 f"{self.defer_rate:.3f}, conflict rate "
                 f"{self.conflict_rate:.3f})",
                 f"  latency p50={self.p50_latency_rounds:.0f} "
                 f"p99={self.p99_latency_rounds:.0f} rounds; "
                 f"cross-shard: {self.cross_ops} ops in "
                 f"{self.cross_rounds} serialized rounds"]
        for s in self.shards:
            lines.append(
                f"  shard {s.shard}: rounds={s.rounds} "
                f"cas={s.ops_executed} won={s.ops_won} "
                f"defers={s.defers} overflows={s.overflows}")
        return "\n".join(lines)


def fresh_stats(n_shards: int, round_cap: int) -> ServiceStats:
    return ServiceStats(round_cap=round_cap,
                        shards=[ShardStats(i) for i in range(n_shards)])

"""KVService: many logical clients on sharded persistent structures.

The service front for the structures layer: S shards, each owning its
own backend instance (built through the ``repro.pmwcas`` factory hooks)
and its own structure partition (:class:`repro.structures.HashMap` or
:class:`repro.structures.BzTreeIndex`).  Keys are routed by
multiplicative hash, so every logical op is shard-local by construction
— cross-shard atomicity only arises at the raw-op layer
(:class:`repro.service.BatchScheduler`), never for single-key KV ops.

Execution is the structures' snapshot-compile/round-execute loop lifted
across shards: each ``step`` compiles every shard's pending ops against
that shard's snapshot, forms ONE conflict-free round per shard (the
conflict-defer rule: duplicate-target ops wait a round instead of
executing to certain failure), and runs all shard rounds in a single
wave — kernel shards through the stacked vmapped dispatch, so S rounds
cost one device call.  CAS losers recompile against the next snapshot;
tree shards run the split protocol between waves, exactly like
``BzTreeIndex.apply`` does between rounds.
"""
from __future__ import annotations

import pathlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro import PMemPool
from repro.obs import (flush_reason, instant, reset_metrics, span,
                       tracing_enabled)
from repro.pmwcas import Backend, MwCASOp, make_backend
from repro.structures import (BzTreeIndex, DELETE, EXHAUSTED, FULL, HashMap,
                              INSERT, KVOp, NeedsResize, NeedsSplit, OK,
                              OutOfRegions, SCAN, StructResult)

from .executor import DispatchStats, execute_wave, schedule_wave, \
    select_executor
from .journal import MIG_MIGRATING, MIG_ROUTED, MigrationLog
from .router import ShardRouter
from .stats import ServiceStats, collect_durability, fresh_stats


class KVFuture:
    """Client handle for one submitted logical op."""

    __slots__ = ("op", "client", "shard", "seq", "op_id", "submit_step",
                 "submit_ns", "done", "done_step", "result")

    def __init__(self, op: KVOp, client, shard: int, seq: int,
                 submit_step: int):
        self.op = op
        self.client = client
        self.shard = shard
        self.seq = seq
        # the stable causal identity: every trace event of this op's
        # lifecycle (submit -> defer/requeue -> dispatch -> complete)
        # carries it, so the timeline reassembles from the trace alone
        self.op_id = f"kv{seq}"
        self.submit_step = submit_step
        self.submit_ns = time.perf_counter_ns()
        self.done = False
        # the wave that DECIDED the op (epoch mode can ack later than it
        # decides; history checkers need the decision wave)
        self.done_step: Optional[int] = None
        self.result: Optional[StructResult] = None

    @property
    def status(self) -> Optional[str]:
        return self.result.status if self.done else None

    def __repr__(self) -> str:
        state = f"done {self.result.status}" if self.done else "pending"
        return f"KVFuture(client={self.client}, shard={self.shard}, {state})"


class _PendingKV:
    """Queue entry: future + the op compiled for the CURRENT wave.

    ``attempts`` counts EXECUTED-and-lost CAS rounds plus split retries —
    not waves spent queued behind the round cap.  Queue delay is latency,
    not failure; only genuine retry churn can exhaust an op.
    """

    __slots__ = ("future", "local", "attempts")

    def __init__(self, future: KVFuture):
        self.future = future
        self.local: Optional[MwCASOp] = None      # set per wave
        self.attempts = 0


class _Migration:
    """One in-flight key-range migration (service-side state; the
    durable truth is the :class:`MigrationLog` record)."""

    __slots__ = ("mig_id", "lo", "hi", "dst", "held", "start_step",
                 "start_ns")

    def __init__(self, mig_id: str, lo: int, hi: int, dst: int,
                 start_step: int):
        self.mig_id = mig_id
        self.lo = lo
        self.hi = hi
        self.dst = dst
        self.held: List[_PendingKV] = []     # ops parked until the swing
        self.start_step = start_step
        self.start_ns = time.perf_counter_ns()

    def covers(self, key: int) -> bool:
        return self.lo <= key < self.hi


class KVService:
    """Sharded, batched KV execution service (see module docstring).

    ``backend`` is a registered backend kind (``"kernel"``/``"durable"``/
    custom), a factory callable, or a list of pre-built per-shard
    backends.  ``structure`` selects the per-shard partition type:
    ``"hashmap"`` (sized by ``n_buckets`` per shard) or ``"bztree"``
    (sized by ``leaf_cap``/``root_cap``/``n_regions`` per shard).
    """

    def __init__(self, n_shards: int, *,
                 structure: str = "hashmap",
                 backend: Union[str, Callable[..., Backend],
                                Sequence[Backend]] = "kernel",
                 n_buckets: int = 64, max_doublings: int = 0,
                 leaf_cap: int = 4, root_cap: int = 8, n_regions: int = 8,
                 round_cap: int = 16, max_op_rounds: Optional[int] = None,
                 durable_root: Union[str, pathlib.Path, None] = None,
                 group_commit: bool = True,
                 epoch_rounds: int = 1, checkpoint_every: int = 0,
                 wal_prune_every: int = 0,
                 migration_pool=None, migration_chunk: int = 8,
                 use_kernel: bool = False, interpret: bool = True,
                 executor=None):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        if structure not in ("hashmap", "bztree"):
            raise ValueError(f"unknown structure {structure!r}")
        self.structure = structure
        self.n_buckets = n_buckets
        self.max_doublings = max_doublings
        self.tree_shape = dict(leaf_cap=leaf_cap, root_cap=root_cap,
                               n_regions=n_regions)
        if structure == "hashmap":
            words = HashMap.words_needed(n_buckets, max_doublings)
        else:
            words = BzTreeIndex.words_needed(leaf_cap, root_cap, n_regions)
        self.words_per_shard = words
        self.router = ShardRouter(n_shards, words_per_shard=words,
                                  policy="range")
        self.epoch_rounds = max(1, int(epoch_rounds))
        self.checkpoint_every = max(0, int(checkpoint_every))
        self.backends = self._build_backends(
            backend, n_shards, words, durable_root, group_commit,
            self.epoch_rounds, self.checkpoint_every,
            use_kernel, interpret)
        self.structs = [self._attach(b) for b in self.backends]
        # epoch ack gate (DESIGN.md Sec. 14): decisions made while ANY
        # durable shard has an open epoch are withheld here, in decide
        # order, until the global durability frontier passes them
        self._held: List[tuple] = []
        self._epoch_open_since: Dict[int, int] = {}
        self._epochs_closed_seen: Dict[int, int] = {}
        self.round_cap = round_cap
        self.max_op_rounds = (2 * round_cap + 8 if max_op_rounds is None
                              else max_op_rounds)
        if wal_prune_every < 0:
            raise ValueError("wal_prune_every must be >= 0")
        self.wal_prune_every = wal_prune_every
        self.executor = executor or select_executor(self.backends,
                                                    round_cap=round_cap)
        self.stats: ServiceStats = fresh_stats(n_shards, round_cap)
        self._queues: List[List[_PendingKV]] = [[] for _ in range(n_shards)]
        self._seq = 0
        # online key-range migration (decide -> copy -> swing; DESIGN.md
        # Sec. 12): the durable decision log lives in its own pool so
        # its persists are crash-sweepable like any shard's
        if migration_chunk < 1:
            raise ValueError("migration_chunk must be >= 1")
        self.migration_chunk = migration_chunk
        if migration_pool is None and durable_root is not None:
            migration_pool = PMemPool(pathlib.Path(durable_root) / "miglog")
        elif isinstance(migration_pool, (str, pathlib.Path)):
            migration_pool = PMemPool(migration_pool)
        self.mig_pool = migration_pool
        self.mig_log = (MigrationLog(migration_pool)
                        if migration_pool is not None else None)
        self._migrations: List[_Migration] = []
        self._mig_seq = 0
        self._recover_migrations()

    # -- construction ----------------------------------------------------------
    @staticmethod
    def _build_backends(spec, n_shards, words, durable_root, group_commit,
                        epoch_rounds, checkpoint_every,
                        use_kernel, interpret) -> List[Backend]:
        if isinstance(spec, (list, tuple)):
            if len(spec) != n_shards:
                raise ValueError(f"{len(spec)} backends for {n_shards} "
                                 "shards")
            return list(spec)
        out = []
        for s in range(n_shards):
            if spec == "kernel":
                kw = dict(n_words=words, use_kernel=use_kernel,
                          interpret=interpret)
            elif spec == "durable":
                root = (None if durable_root is None
                        else pathlib.Path(durable_root) / f"shard{s}")
                kw = dict(root=root, group_commit=group_commit,
                          epoch_rounds=epoch_rounds,
                          checkpoint_every=checkpoint_every)
            else:                       # sim / custom kind / factory
                kw = dict(n_words=words)
            out.append(make_backend(spec, **kw))
        return out

    def _attach(self, backend: Backend):
        if self.structure == "hashmap":
            return HashMap(backend, self.n_buckets,
                           max_doublings=self.max_doublings)
        return BzTreeIndex(backend, **self.tree_shape)

    # -- submission ------------------------------------------------------------
    def submit(self, op: KVOp, client=0) -> KVFuture:
        shard = self.router.shard_of_key(op.key)
        fut = KVFuture(op, client, shard, self._seq, self.stats.steps)
        self._seq += 1
        self.stats.submitted += 1
        if tracing_enabled():
            instant("op.submit", op_id=fut.op_id, client=client,
                    shard=shard, kind=op.kind, step=self.stats.steps)
        mig = self._covering_migration(op)
        if mig is not None:
            # park until the routing swings; released ops re-route
            mig.held.append(_PendingKV(fut))
        else:
            self._queues[shard].append(_PendingKV(fut))
        return fut

    def submit_many(self, ops: Sequence[KVOp], client=0) -> List[KVFuture]:
        return [self.submit(op, client) for op in ops]

    @property
    def pending_count(self) -> int:
        # held acks count as pending: the client has no verdict yet, and
        # drain() must not return while an epoch still owes them a fence
        return sum(len(q) for q in self._queues) \
            + sum(len(m.held) for m in self._migrations) \
            + len(self._held)

    # -- execution -------------------------------------------------------------
    def step(self) -> int:
        """One service wave: compile, form rounds, execute, complete —
        plus one copy chunk of every in-flight migration (the
        incremental materialize; the swing runs the wave the copy
        drains).  Returns the number of futures completed this wave."""
        if not self.pending_count and not self._migrations:
            return 0
        self.stats.steps += 1
        with span("service.wave", step=self.stats.steps) as sp:
            completed = self._execute_step()
            if self._migrations:
                self._advance_migrations()
            if (self.wal_prune_every and
                    self.stats.steps % self.wal_prune_every == 0):
                # per-shard WAL hygiene on a wave cadence (the committer
                # analogue of the scheduler's journal_prune_every):
                # without it a long-running durable service grows wal/
                # one record per committed round, forever
                self.prune_wal()
            if self._held and not any(self._queues) \
                    and not self._migrations:
                # only withheld acks remain: no further round will close
                # the epochs naturally, so pay the barrier now (this is
                # what makes drain() a durability barrier)
                self.sync_epochs()
            self._settle_epochs()
            sp.set(completed=completed)
        return completed

    def _execute_step(self) -> int:
        completed = 0
        compiled_queues: Dict[int, List[_PendingKV]] = {}
        with span("wave.compile"):
            for s in range(len(self.structs)):
                if not self._queues[s]:
                    continue
                ready, done = self._compile_shard(s)
                completed += done
                if ready:
                    compiled_queues[s] = ready
        if not compiled_queues:
            return completed
        with span("wave.schedule"):
            rounds, leftovers = schedule_wave(compiled_queues,
                                              self.round_cap, self.stats)
            # deferred ops recompile next wave (their snapshot is stale
            # by construction once this wave's round commits)
            for s, later in leftovers.items():
                self._requeue(s, later)
        with span("wave.dispatch", shards=len(rounds)):
            dispatch_start_ns = time.perf_counter_ns()
            persist_ns0 = self._persist_ns_total()
            wave = execute_wave(self.executor, self.backends, rounds,
                                self.stats)
        with span("wave.complete"):
            # this op's persist share: the wave's fence wall-clock is a
            # group property (one round record covers every winner), so
            # it splits evenly across the winners it made durable
            persist_wave_ns = self._persist_ns_total() - persist_ns0
            winners = sum(1 for pairs in wave.values()
                          for _p, ok in pairs if ok)
            persist_share_us = (persist_wave_ns / 1e3 / winners
                                if winners else 0.0)
            for s, pairs in wave.items():
                losers = []
                for pending, ok in pairs:
                    if ok:
                        self._finish(pending.future, OK,
                                     dispatch_start_ns=dispatch_start_ns,
                                     persist_share_us=persist_share_us,
                                     retry_waves=pending.attempts)
                        completed += 1
                    else:
                        pending.attempts += 1
                        losers.append(pending)   # recompile next wave
                self._requeue(s, losers)
        return completed

    def _persist_ns_total(self) -> int:
        """Wall-clock the durable shards have spent inside persist
        fences, summed (0 for kernel/sim deployments)."""
        total = 0
        for b in self.backends:
            pool = getattr(b, "pool", None)
            if pool is not None:
                total += pool.persist_ns
        return total

    def prune_wal(self) -> int:
        """Durably drop spent descriptor records on every shard whose
        backend supports it; returns records pruned (also accumulated in
        ``stats.wal_pruned``)."""
        pruned = 0
        for b in self.backends:
            prune = getattr(b, "prune_completed", None)
            if prune is not None:
                pruned += prune()
        self.stats.wal_pruned += pruned
        return pruned

    def drain(self, max_steps: Optional[int] = None) -> int:
        """Step until no op is pending.  Per-op round budgets
        (``max_op_rounds`` -> EXHAUSTED) bound the loop."""
        limit = ((self.pending_count + 4) * (self.max_op_rounds + 2)
                 if max_steps is None else max_steps)
        done = 0
        for _ in range(limit):
            if not self.pending_count:
                break
            done += self.step()
        if self.pending_count:
            raise RuntimeError(
                f"service drain did not converge in {limit} steps")
        return done

    def apply(self, ops: Sequence[KVOp], client=0) -> List[StructResult]:
        """Synchronous convenience: submit a batch, drain, return results
        in submission order (the ``HashMap.apply`` signature, served)."""
        futs = self.submit_many(ops, client)
        self.drain()
        return [f.result for f in futs]

    # -- wave internals --------------------------------------------------------
    def _compile_shard(self, s: int):
        """Compile shard ``s``'s queue against one snapshot.  Immediate
        results complete; split/resize requests run the structure's grow
        protocol (ops recompile next wave); CAS-compiled ops return for
        round formation."""
        struct = self.structs[s]
        if getattr(struct, "hdr", 0) and struct.migrating:
            # an in-flight directory doubling pumps a chunk per wave
            with flush_reason("structures", "doubling_pump"):
                struct.resize_step(max_moves=max(len(self._queues[s]), 2))
        snap = struct.snapshot()
        ready: List[_PendingKV] = []
        later: List[_PendingKV] = []
        done = 0
        splits: Dict[int, List[_PendingKV]] = {}
        resizes: List[_PendingKV] = []
        for pending in self._queues[s]:
            fut = pending.future
            if pending.attempts > self.max_op_rounds:
                self._finish(fut, EXHAUSTED,
                             retry_waves=pending.attempts)
                done += 1
                continue
            compiled = struct.compile_op(fut.op, snap)
            if isinstance(compiled, NeedsResize):
                resizes.append(pending)
            elif isinstance(compiled, StructResult):
                if fut.op.kind == SCAN and compiled.status == OK:
                    # scans cover the whole keyspace: sum the count over
                    # every shard partition (each against its own wave
                    # snapshot — disjoint key sets, so a plain sum)
                    value = (compiled.value or 0) + sum(
                        (other.compile_op(fut.op, other.snapshot()).value
                         or 0)
                        for s2, other in enumerate(self.structs)
                        if s2 != s)
                    self._finish(fut, OK, value,
                                 retry_waves=pending.attempts)
                else:
                    self._finish(fut, compiled.status, compiled.value,
                                 retry_waves=pending.attempts)
                done += 1
            elif isinstance(compiled, NeedsSplit):
                splits.setdefault(compiled.leaf_base, []).append(pending)
            else:
                pending.local = compiled
                ready.append(pending)
        self._queues[s] = []
        if resizes:
            # publish the doubling decision; the waiters recompile next
            # wave against the split-brain table (room is immediate: a
            # fresh generation has twice the buckets)
            with flush_reason("structures", "doubling_swing"):
                began = struct.begin_resize()
            if began:
                for pending in resizes:
                    pending.attempts += 1
                later.extend(resizes)
            else:
                for pending in resizes:
                    self._finish(pending.future, FULL,
                                 retry_waves=pending.attempts)
                    done += 1
        if splits:
            # grow first; this wave's compiled ops would mostly lose
            # (the split freezes their leaf's meta), so everything on
            # this shard recompiles next wave — BzTreeIndex.apply's rule
            for leaf_base, waiters in sorted(splits.items()):
                try:
                    grew = self.structs[s].ensure_room(leaf_base)
                except OutOfRegions:
                    grew = False
                    self.stats.shards[s].out_of_regions += 1
                if grew:
                    for pending in waiters:
                        pending.attempts += 1
                    later.extend(waiters)
                else:
                    for pending in waiters:
                        self._finish(pending.future, FULL,
                                     retry_waves=pending.attempts)
                        done += 1
            self._requeue(s, ready + later)
            return [], done
        self._requeue(s, later)
        return ready, done

    def _requeue(self, s: int, entries: List[_PendingKV]) -> None:
        """Merge entries back into the shard queue in submission order
        (FIFO fairness across defers, losses and recompiles)."""
        if entries:
            if tracing_enabled():
                for pending in entries:
                    instant("op.requeue", op_id=pending.future.op_id,
                            shard=s, attempts=pending.attempts,
                            step=self.stats.steps)
            self._queues[s].extend(entries)
            self._queues[s].sort(key=lambda p: p.future.seq)

    # -- epoch ack gate (DESIGN.md Sec. 14) ------------------------------------
    def _finish(self, fut: KVFuture, status: str, value=None, *,
                dispatch_start_ns: Optional[int] = None,
                persist_share_us: float = 0.0,
                retry_waves: int = 0) -> None:
        """Completion gate for the epoch window.  The decision (status/
        value) is final here, but while ANY durable shard has an open
        epoch the ack is withheld GLOBALLY — released in decide order
        once every shard has durably passed the deciding step.  A
        global gate (not per-shard) because cross-shard reads (scans)
        observe every shard's visible state: acking a scan before a
        slower shard's epoch closes could expose a round a crash then
        revokes.  Outside epoch mode the gate is always open and this
        is exactly :meth:`_complete`."""
        if any(getattr(b, "epoch_pending", 0) for b in self.backends):
            self._held.append((self.stats.steps, fut, status, value, dict(
                dispatch_start_ns=dispatch_start_ns,
                persist_share_us=persist_share_us,
                retry_waves=retry_waves)))
            self.stats.acks_held += 1
            if tracing_enabled():
                instant("op.ack_held", op_id=fut.op_id, status=status,
                        step=self.stats.steps)
        else:
            self._complete(fut, status, value,
                           dispatch_start_ns=dispatch_start_ns,
                           persist_share_us=persist_share_us,
                           retry_waves=retry_waves)

    def _settle_epochs(self) -> None:
        """End-of-wave epoch bookkeeping: note which shards hold an open
        epoch (and since when), then release held acks up to the global
        durability frontier — the last step EVERY durable shard has
        durably passed.  A shard that paid a fence this wave restarts
        its open-since mark: whatever epoch is open now only holds
        rounds from this wave."""
        open_since = self._epoch_open_since
        for s, b in enumerate(self.backends):
            pending = getattr(b, "epoch_pending", 0)
            stats = getattr(getattr(b, "committer", None), "stats", None)
            closed = getattr(stats, "epochs_closed", 0)
            fenced = closed > self._epochs_closed_seen.get(s, closed)
            self._epochs_closed_seen[s] = closed
            if not pending:
                open_since.pop(s, None)
            elif fenced:
                open_since[s] = self.stats.steps
            else:
                open_since.setdefault(s, self.stats.steps)
        if self._held:
            frontier = (min(open_since.values()) - 1 if open_since
                        else None)
            self._release_held(frontier)

    def _release_held(self, frontier: Optional[int]) -> None:
        """Ack held completions whose deciding step the frontier has
        passed (``None`` = everything), in decide order."""
        if not self._held:
            return
        keep: List[tuple] = []
        for item in self._held:
            step, fut, status, value, kw = item
            if frontier is None or step <= frontier:
                self._complete(fut, status, value, decided_step=step, **kw)
            else:
                keep.append(item)
        self._held = keep

    def sync_epochs(self) -> int:
        """Explicit durability barrier: close every shard's open epoch
        (one fence each) and release every withheld ack.  Returns rounds
        made durable across shards."""
        synced = 0
        for b in self.backends:
            sync = getattr(b, "sync", None)
            if sync is not None:
                synced += sync()
        if synced:
            self.stats.epoch_syncs += 1
        self._epoch_open_since.clear()
        self._release_held(None)
        return synced

    def _complete(self, fut: KVFuture, status: str, value=None, *,
                  dispatch_start_ns: Optional[int] = None,
                  persist_share_us: float = 0.0,
                  retry_waves: int = 0,
                  decided_step: Optional[int] = None) -> None:
        fut.done = True
        fut.done_step = (self.stats.steps if decided_step is None
                         else decided_step)
        latency = max(1, self.stats.steps - fut.submit_step)
        fut.result = StructResult(fut.op, status, value=value,
                                  rounds=latency)
        now_ns = time.perf_counter_ns()
        latency_us = (now_ns - fut.submit_ns) / 1e3
        # decompose: queue (submit -> this wave's dispatch start),
        # persist (the op's share of the wave's fence wall-clock),
        # dispatch (the rest).  The three sum to latency_us exactly —
        # compile-time completions (reads, EXHAUSTED, FULL) never reach
        # a dispatch, so their whole latency is queueing.
        if dispatch_start_ns is None:
            queue_us, dispatch_us, persist_us = latency_us, 0.0, 0.0
        else:
            queue_us = min(max(
                (dispatch_start_ns - fut.submit_ns) / 1e3, 0.0), latency_us)
            persist_us = min(max(persist_share_us, 0.0),
                             latency_us - queue_us)
            dispatch_us = latency_us - queue_us - persist_us
        self.stats.record_completion(
            latency, status, latency_us=latency_us, queue_us=queue_us,
            dispatch_us=dispatch_us, persist_us=persist_us,
            retry_waves=retry_waves)
        if tracing_enabled():
            instant("op.complete", op_id=fut.op_id, status=status,
                    latency_us=round(latency_us, 1),
                    queue_us=round(queue_us, 1),
                    dispatch_us=round(dispatch_us, 1),
                    persist_us=round(persist_us, 1),
                    retry_waves=retry_waves, step=self.stats.steps)

    # -- online key-range migration --------------------------------------------
    def _covering_migration(self, op: KVOp) -> Optional[_Migration]:
        """The in-flight migration that must hold this op, if any.
        Scans are held by ANY migration: their count sums every shard,
        and during a copy a key is (correctly) present on two shards."""
        for m in self._migrations:
            if m.covers(op.key) or op.kind == SCAN:
                return m
        return None

    def start_migration(self, lo: int, hi: int, dst: int) -> str:
        """Decide: persist the MIGRATING record and start holding the
        range.  The copy then proceeds one chunk per ``step`` wave; the
        swing (route flip + cleanup + held-op release) runs in the wave
        the copy drains.  Returns the migration id."""
        if not lo < hi:
            raise ValueError(f"empty key range [{lo}, {hi})")
        if not 0 <= dst < len(self.structs):
            raise ValueError(f"shard {dst} out of range")
        for m in self._migrations:
            if lo < m.hi and m.lo < hi:
                raise RuntimeError(
                    f"range [{lo}, {hi}) overlaps in-flight migration "
                    f"{m.mig_id}")
        if self.mig_log is None and any(
                getattr(b, "pool", None) is not None for b in self.backends):
            # crash-capable shards without a decision log would lose the
            # route table on crash while keeping the moved keys — silent
            # misrouting; make it a loud configuration error instead
            raise ValueError(
                "durable shards need a migration decision log: pass "
                "migration_pool= or durable_root= to KVService")
        mig_id = f"mig{self._mig_seq:04d}"
        self._mig_seq += 1
        if self.mig_log is not None:
            self.mig_log.decide(mig_id, lo, hi, dst)    # decide persist
        m = _Migration(mig_id, lo, hi, dst, self.stats.steps)
        self._migrations.append(m)
        self.stats.migrations += 1
        # ops already queued for the range (and all scans) park too
        for s in range(len(self._queues)):
            keep = []
            for pending in self._queues[s]:
                op = pending.future.op
                if m.covers(op.key) or op.kind == SCAN:
                    m.held.append(pending)
                else:
                    keep.append(pending)
            self._queues[s] = keep
        m.held.sort(key=lambda p: p.future.seq)
        return mig_id

    def migrate_range(self, lo: int, hi: int, dst: int,
                      max_steps: int = 10_000) -> str:
        """Synchronous convenience: start a migration and step the
        service until it (and everything it held) completes."""
        mig_id = self.start_migration(lo, hi, dst)
        for _ in range(max_steps):
            if not any(m.mig_id == mig_id for m in self._migrations):
                return mig_id
            self.step()
        raise RuntimeError(f"migration {mig_id} did not converge in "
                           f"{max_steps} steps")

    def _advance_migrations(self) -> None:
        for m in list(self._migrations):
            with span("service.migration_chunk", mig=m.mig_id):
                copied = self._copy_chunk(m)
            if copied == 0:
                self._swing_migration(m)
                self._migrations.remove(m)

    def _copy_chunk(self, m: _Migration) -> int:
        """Materialize: copy up to ``migration_chunk`` in-range keys to
        the destination in one batched-MwCAS ``apply``.  Returns keys
        copied; 0 means the copy has drained."""
        dst_struct = self.structs[m.dst]
        already = set(dst_struct.items())
        batch: List[KVOp] = []
        for s, struct in enumerate(self.structs):
            if s == m.dst:
                continue
            for k, v in sorted(struct.items().items()):
                if m.covers(k) and k not in already:
                    batch.append(KVOp(INSERT, k, v))
                    if len(batch) >= self.migration_chunk:
                        break
            if len(batch) >= self.migration_chunk:
                break
        if not batch:
            return 0
        moved = 0
        for r in dst_struct.apply(batch):
            if r.status == FULL:
                raise RuntimeError(
                    f"migration {m.mig_id}: destination shard {m.dst} is "
                    "full — size it for the range or make it elastic")
            if r.status == OK:
                moved += 1
        self.stats.keys_moved += moved
        return len(batch)

    def _swing_migration(self, m: _Migration) -> None:
        """Swing: ROUTED record persist (the linearization point), then
        the route table, then cleanup + release.  A crash after the
        first persist rolls forward; before it, back."""
        with span("service.migration_swing", mig=m.mig_id):
            # the ROUTED record redirects reads to the destination, so
            # every copied key must be durable there FIRST — close the
            # destination's open epoch before the linearization point
            sync = getattr(self.backends[m.dst], "sync", None)
            if sync is not None:
                sync()
            if self.mig_log is not None:
                self.mig_log.mark_routed(m.mig_id)
            self.router.set_range(m.lo, m.hi, m.dst)
            if self.mig_log is not None:
                self.mig_log.save_routes(self.router.ranges)
            self._cleanup_range(m.lo, m.hi, m.dst)
            if self.mig_log is not None:
                self.mig_log.complete(m.mig_id)
        self.stats.mig_pause_waves.append(
            max(1, self.stats.steps - m.start_step))
        self.stats.mig_pause_us.record(
            (time.perf_counter_ns() - m.start_ns) / 1e3)
        # release: held ops re-route (the override now wins) and rejoin
        # the wave loop in submission order
        for pending in sorted(m.held, key=lambda p: p.future.seq):
            shard = self.router.shard_of_key(pending.future.op.key)
            pending.future.shard = shard
            self._requeue(shard, [pending])

    def _cleanup_range(self, lo: int, hi: int, dst: int) -> None:
        """Delete now-unroutable source copies of [lo, hi): in-range
        keys living where the CURRENT route table does not send them.
        At swing time that is every source copy; at recovery-redo time
        the routing check also protects keys a LATER migration has
        since legitimately moved elsewhere."""
        for s, struct in enumerate(self.structs):
            if s == dst:
                continue
            dels = [KVOp(DELETE, k) for k in sorted(struct.items())
                    if lo <= k < hi and self.router.shard_of_key(k) != s]
            if dels:
                struct.apply(dels)

    def _recover_migrations(self) -> None:
        """Redo/rollback from the decision log (constructor + crash).

        MIGRATING records roll BACK: the migration never routed, so
        in-range keys on the destination that do not route there are
        half-copied residue — delete them, drop the record.  ROUTED
        records roll FORWARD: re-install the override, re-persist the
        route table, redo the cleanup, mark COMPLETED.  Every redo step
        is idempotent, so a crash during recovery just recovers again.
        """
        if self.mig_log is None:
            return
        self.router.ranges = self.mig_log.load_routes()
        seqs = [int(r["id"][3:]) for r in self.mig_log.records()
                if r["id"].startswith("mig") and r["id"][3:].isdigit()]
        self._mig_seq = 1 + max(seqs) if seqs else 0
        pend = self.mig_log.pending()
        # install every pending ROUTED override FIRST, in decision order
        # (ids are monotone, records() sorts by them): COMPLETED marks
        # are lazy, so several routed migrations may replay at once, and
        # each cleanup below must judge against the FINAL route table —
        # an earlier record's redo must not delete keys a later
        # migration has since moved onto their rightful shard
        routed = [r for r in pend if r["state"] == MIG_ROUTED]
        for rec in routed:
            self.router.set_range(rec["lo"], rec["hi"], rec["dst"])
        if routed:
            self.mig_log.save_routes(self.router.ranges)
        for rec in pend:
            lo, hi, dst = rec["lo"], rec["hi"], rec["dst"]
            if rec["state"] == MIG_MIGRATING:
                # rollback: half-copied residue is any in-range key on
                # the destination that does not route there
                struct = self.structs[dst]
                dels = [KVOp(DELETE, k) for k in sorted(struct.items())
                        if lo <= k < hi
                        and self.router.shard_of_key(k) != dst]
                if dels:
                    struct.apply(dels)
                self.mig_log.abort(rec["id"])
            else:                                   # ROUTED: roll forward
                self._cleanup_range(lo, hi, dst)
                self.mig_log.complete(rec["id"])

    # -- reads / integrity -----------------------------------------------------
    def lookup(self, key: int) -> Optional[int]:
        key_shard = self.router.shard_of_key(key)
        return self.structs[key_shard].lookup(key)

    def items(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for struct in self.structs:
            out.update(struct.items())
        return out

    def check_integrity(self) -> Dict[int, int]:
        """Per-shard structure invariants + the routing invariant (no
        key lives on a shard it doesn't route to).  During an in-flight
        migration the destination legitimately holds not-yet-routed
        copies of in-range keys; those are exempt from the routing and
        duplicate checks but must MATCH the source value — held writes
        guarantee the copy can never diverge."""
        out: Dict[int, int] = {}
        copies: Dict[int, int] = {}
        for s, struct in enumerate(self.structs):
            items = struct.check_integrity()
            for k, v in items.items():
                route = self.router.shard_of_key(k)
                if route != s:
                    if any(m.dst == s and m.covers(k)
                           for m in self._migrations):
                        copies[k] = v
                        continue
                    raise RuntimeError(
                        f"key {k} lives on shard {s} but routes to "
                        f"{route}")
                if k in out:
                    raise RuntimeError(f"key {k} live on two shards")
                out[k] = v
        for k, v in copies.items():
            if k in out and out[k] != v:
                raise RuntimeError(
                    f"migration copy of key {k} diverged: source holds "
                    f"{out[k]}, destination copy holds {v}")
        return out

    def gc_regions(self) -> int:
        """Region GC across every tree shard (no-op for hash maps)."""
        return sum(getattr(s, "gc_regions", lambda: 0)()
                   for s in self.structs)

    def reset_stats(self) -> None:
        """Start a fresh measurement window (e.g. after a load phase).

        The global metrics registry resets with the window (it is the
        same measurement — benchmarks read both and compare them), and
        the executor's dispatch counters reset too, but the executor's
        TRACE CACHE survives — a warmed-up service must show zero
        retraces in the new window, and that is exactly what the
        benchmark asserts."""
        self.stats = fresh_stats(len(self.backends), self.round_cap)
        if hasattr(self.executor, "stats"):
            self.executor.stats = DispatchStats()
        reset_metrics()

    def durability_stats(self):
        """Merged committer flush accounting over the durable shards
        (None when no shard is durable)."""
        return collect_durability(self.backends)

    # -- durability ------------------------------------------------------------
    def crash(self) -> "KVService":
        """Durable services only: crash every shard (drop unpersisted
        writes), recover each from its own WAL, and re-attach the
        structure partitions.  Returns the recovered service.

        The measurement window SURVIVES the crash: the recovered service
        keeps this service's ``ServiceStats`` (steps, completions,
        latency windows — all monotone across the cycle; the backends
        likewise carry their ``DurabilityStats`` through
        ``DurableBackend.crash``) and its executor, whose trace cache a
        crash has no reason to invalidate."""
        with span("service.crash_recover", shards=len(self.backends)):
            recovered = []
            for b in self.backends:
                crash = getattr(b, "crash", None)
                if crash is None:
                    raise TypeError(
                        f"backend {b.name} cannot crash/recover")
                recovered.append(crash())
            new = KVService(len(recovered), structure=self.structure,
                            backend=recovered, n_buckets=self.n_buckets,
                            max_doublings=self.max_doublings,
                            round_cap=self.round_cap,
                            max_op_rounds=self.max_op_rounds,
                            wal_prune_every=self.wal_prune_every,
                            epoch_rounds=self.epoch_rounds,
                            checkpoint_every=self.checkpoint_every,
                            migration_pool=(self.mig_pool.crash()
                                            if self.mig_pool is not None
                                            else None),
                            migration_chunk=self.migration_chunk,
                            **self.tree_shape)
            new.stats = self.stats
            new.executor = self.executor
        return new

"""Durable decision log for cross-shard MwCAS ops.

A cross-shard op cannot be one backend commit: its targets live in
different shards' pools.  The service therefore serializes cross-shard
ops into a global round and makes each one atomic the same way the paper
makes everything atomic — a persisted descriptor as its own write-ahead
log, here one level up:

1. validate every target against its shard (reads only, nothing moves);
2. persist the decision record ``{state: SUCCEEDED, targets}`` — THE
   durability linearization point of the whole cross-shard op;
3. apply each shard's sub-op through that shard's own backend (each
   application is per-shard atomic; a durable shard writes its own WAL
   record as usual);
4. mark the record COMPLETED (lazy persist — redo is idempotent).

A crash anywhere leaves either (i) no decision record → nothing moved
(validation reads don't write), or (ii) a SUCCEEDED record → recovery
REDOES the op: any shard whose words still hold the expected values gets
its sub-op re-applied, shards already holding the desired values are
skipped.  Because the global round is serialized (no other op touches
those words until the record is COMPLETED), a word can only hold the
expected or the desired value at redo time — anything else is a torn
state and raises.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.obs import flush_reason

ST_SUCCEEDED = "SUCCEEDED"
ST_COMPLETED = "COMPLETED"

# (shard, local addr-or-slot, expected, desired)
CrossTarget = Tuple[int, object, int, int]


def _rel(op_id: str) -> str:
    return f"xwal/{op_id}.json"


class CrossShardJournal:
    """Decision log over one :class:`repro.PMemPool`.

    The pool should be its own directory (or a dedicated subtree of a
    shard's pool) — the journal never collides with committer layouts
    because every record lives under ``xwal/``.
    """

    def __init__(self, pool):
        self.pool = pool

    # -- the 2 persists of the protocol ---------------------------------------
    def decide(self, op_id: str, targets: Sequence[CrossTarget]) -> None:
        """Persist the SUCCEEDED decision record (linearization point)."""
        with flush_reason("service", "journal_decide"):
            self.pool.write_record(_rel(op_id), {
                "id": op_id, "state": ST_SUCCEEDED,
                "targets": [list(t) for t in targets]})

    def complete(self, op_id: str) -> None:
        """Mark the record spent.  Lazy persist (no durability barrier):
        losing this write to a crash only means one idempotent redo."""
        rec = self.pool.read_record(_rel(op_id))
        if rec is None:
            return
        rec["state"] = ST_COMPLETED
        self.pool.write_record(_rel(op_id), rec, persist=False)

    # -- recovery --------------------------------------------------------------
    def pending(self) -> List[Dict]:
        """Decision records whose application may be incomplete."""
        out = []
        for fn in self.pool.listdir("xwal"):
            rec = self.pool.read_record(f"xwal/{fn}")
            if rec is None:
                # torn record: the decision never became durable, so the
                # op never happened — drop the residue
                self.pool.delete(f"xwal/{fn}")
                continue
            if rec.get("state") == ST_SUCCEEDED:
                out.append(rec)
        return out

    def prune(self) -> int:
        """Durably drop COMPLETED records (journal hygiene, the
        ``prune_completed`` analogue).  Returns how many were pruned."""
        pruned = 0
        for fn in self.pool.listdir("xwal"):
            rec = self.pool.read_record(f"xwal/{fn}")
            if rec is not None and rec.get("state") != ST_COMPLETED:
                continue
            with flush_reason("service", "journal_prune"):
                self.pool.delete_persist(f"xwal/{fn}")
            pruned += 1
        return pruned

    @staticmethod
    def targets_of(rec: Dict) -> List[CrossTarget]:
        return [tuple(t) for t in rec["targets"]]

    def __len__(self) -> int:
        return len(self.pool.listdir("xwal"))

    def __repr__(self) -> str:
        return f"CrossShardJournal({len(self)} records)"


# -- online shard migration ----------------------------------------------------
MIG_MIGRATING = "MIGRATING"      # decided; copy in flight; rollback on crash
MIG_ROUTED = "ROUTED"            # routing swung; cleanup redo on crash
MIG_COMPLETED = "COMPLETED"      # spent (prune-able)

_ROUTES = "mig_routes.json"


def _mig_rel(mig_id: str) -> str:
    return f"mig/{mig_id}.json"


class MigrationLog:
    """Decision log for online key-range shard migrations — the same
    journal idiom as :class:`CrossShardJournal`, one protocol level up:

    1. persist ``{state: MIGRATING, lo, hi, dst}`` — the *decide*
       record.  From here until ROUTED, a crash rolls the migration
       BACK: copies on ``dst`` (in-range keys that hash-route
       elsewhere) are deleted and the record dropped — the migration
       never happened;
    2. the service copies in-range keys to ``dst`` in batched MwCAS
       rounds (*materialize*; each round per-shard atomic as usual);
    3. flip the record to ``ROUTED`` (THE durability linearization
       point of the migration), then persist the route table with the
       new override (*swing*).  From here a crash rolls FORWARD:
       recovery re-installs the override and redoes the cleanup;
    4. delete the now-unroutable source copies, mark ``COMPLETED``
       (lazy persist — redo is idempotent).

    The route table ``mig_routes.json`` is the persistent image of
    :attr:`ShardRouter.ranges`; it is rewritten under a completed
    record's authority only, so its content is always implied by the
    record states.
    """

    def __init__(self, pool):
        self.pool = pool

    # -- the persists of the protocol ------------------------------------------
    def decide(self, mig_id: str, lo: int, hi: int, dst: int) -> None:
        with flush_reason("service", "migration_decide"):
            self.pool.write_record(_mig_rel(mig_id), {
                "id": mig_id, "state": MIG_MIGRATING,
                "lo": lo, "hi": hi, "dst": dst})

    def mark_routed(self, mig_id: str) -> None:
        rec = self.pool.read_record(_mig_rel(mig_id))
        rec["state"] = MIG_ROUTED
        with flush_reason("service", "migration_routed"):
            self.pool.write_record(_mig_rel(mig_id), rec)

    def complete(self, mig_id: str) -> None:
        rec = self.pool.read_record(_mig_rel(mig_id))
        if rec is None:
            return
        rec["state"] = MIG_COMPLETED
        self.pool.write_record(_mig_rel(mig_id), rec, persist=False)

    def abort(self, mig_id: str) -> None:
        """Drop a MIGRATING record (rollback's final persist)."""
        with flush_reason("service", "migration_abort"):
            self.pool.delete_persist(_mig_rel(mig_id))

    # -- the route table -------------------------------------------------------
    def save_routes(self, ranges) -> None:
        with flush_reason("service", "migration_routes"):
            self.pool.write_record(_ROUTES, {
                "ranges": [list(r) for r in ranges]})

    def load_routes(self) -> List[Tuple[int, int, int]]:
        rec = self.pool.read_record(_ROUTES)
        if rec is None:
            return []
        return [tuple(r) for r in rec["ranges"]]

    # -- recovery --------------------------------------------------------------
    def records(self) -> List[Dict]:
        """Every readable migration record (torn records are residue of
        an unpersisted decide — the migration never happened — and are
        dropped)."""
        out = []
        for fn in sorted(self.pool.listdir("mig")):
            rec = self.pool.read_record(f"mig/{fn}")
            if rec is None:
                self.pool.delete(f"mig/{fn}")
                continue
            out.append(rec)
        return out

    def pending(self) -> List[Dict]:
        """Records whose migration is not COMPLETED (recovery work)."""
        return [r for r in self.records()
                if r.get("state") != MIG_COMPLETED]

    def prune(self) -> int:
        """Durably drop COMPLETED records; returns how many."""
        pruned = 0
        for fn in self.pool.listdir("mig"):
            rec = self.pool.read_record(f"mig/{fn}")
            if rec is not None and rec.get("state") != MIG_COMPLETED:
                continue
            with flush_reason("service", "migration_prune"):
                self.pool.delete_persist(f"mig/{fn}")
            pruned += 1
        return pruned

    def __len__(self) -> int:
        return len(self.pool.listdir("mig"))

    def __repr__(self) -> str:
        return f"MigrationLog({len(self)} records)"

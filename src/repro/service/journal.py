"""Durable decision log for cross-shard MwCAS ops.

A cross-shard op cannot be one backend commit: its targets live in
different shards' pools.  The service therefore serializes cross-shard
ops into a global round and makes each one atomic the same way the paper
makes everything atomic — a persisted descriptor as its own write-ahead
log, here one level up:

1. validate every target against its shard (reads only, nothing moves);
2. persist the decision record ``{state: SUCCEEDED, targets}`` — THE
   durability linearization point of the whole cross-shard op;
3. apply each shard's sub-op through that shard's own backend (each
   application is per-shard atomic; a durable shard writes its own WAL
   record as usual);
4. mark the record COMPLETED (lazy persist — redo is idempotent).

A crash anywhere leaves either (i) no decision record → nothing moved
(validation reads don't write), or (ii) a SUCCEEDED record → recovery
REDOES the op: any shard whose words still hold the expected values gets
its sub-op re-applied, shards already holding the desired values are
skipped.  Because the global round is serialized (no other op touches
those words until the record is COMPLETED), a word can only hold the
expected or the desired value at redo time — anything else is a torn
state and raises.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

ST_SUCCEEDED = "SUCCEEDED"
ST_COMPLETED = "COMPLETED"

# (shard, local addr-or-slot, expected, desired)
CrossTarget = Tuple[int, object, int, int]


def _rel(op_id: str) -> str:
    return f"xwal/{op_id}.json"


class CrossShardJournal:
    """Decision log over one :class:`repro.PMemPool`.

    The pool should be its own directory (or a dedicated subtree of a
    shard's pool) — the journal never collides with committer layouts
    because every record lives under ``xwal/``.
    """

    def __init__(self, pool):
        self.pool = pool

    # -- the 2 persists of the protocol ---------------------------------------
    def decide(self, op_id: str, targets: Sequence[CrossTarget]) -> None:
        """Persist the SUCCEEDED decision record (linearization point)."""
        self.pool.write_record(_rel(op_id), {
            "id": op_id, "state": ST_SUCCEEDED,
            "targets": [list(t) for t in targets]})

    def complete(self, op_id: str) -> None:
        """Mark the record spent.  Lazy persist (no durability barrier):
        losing this write to a crash only means one idempotent redo."""
        rec = self.pool.read_record(_rel(op_id))
        if rec is None:
            return
        rec["state"] = ST_COMPLETED
        self.pool.write_record(_rel(op_id), rec, persist=False)

    # -- recovery --------------------------------------------------------------
    def pending(self) -> List[Dict]:
        """Decision records whose application may be incomplete."""
        out = []
        for fn in self.pool.listdir("xwal"):
            rec = self.pool.read_record(f"xwal/{fn}")
            if rec is None:
                # torn record: the decision never became durable, so the
                # op never happened — drop the residue
                self.pool.delete(f"xwal/{fn}")
                continue
            if rec.get("state") == ST_SUCCEEDED:
                out.append(rec)
        return out

    def prune(self) -> int:
        """Durably drop COMPLETED records (journal hygiene, the
        ``prune_completed`` analogue).  Returns how many were pruned."""
        pruned = 0
        for fn in self.pool.listdir("xwal"):
            rec = self.pool.read_record(f"xwal/{fn}")
            if rec is not None and rec.get("state") != ST_COMPLETED:
                continue
            self.pool.delete_persist(f"xwal/{fn}")
            pruned += 1
        return pruned

    @staticmethod
    def targets_of(rec: Dict) -> List[CrossTarget]:
        return [tuple(t) for t in rec["targets"]]

    def __len__(self) -> int:
        return len(self.pool.listdir("xwal"))

    def __repr__(self) -> str:
        return f"CrossShardJournal({len(self)} records)"

"""Shard-round execution engines.

One service step produces at most one CAS round per shard; the executor
runs all of those rounds "concurrently".  For kernel shards concurrency
is real data parallelism: every shard round is padded to a common
``[B, K]`` shape, the shard word tables are stacked into ``[S, W]``, and
ONE ``jax.vmap``-ped ``pmwcas_apply`` resolves every shard's round in a
single device dispatch — the batched analogue of S cores retiring their
CAS rounds in the same cycle, and the reason service throughput scales
with shard count instead of paying one dispatch per shard.

Shards whose backend is not stackable (durable, sim, or kernel shards
with mismatched shapes/flags) fall back to per-shard ``execute`` calls.

The stacked dispatch is CACHED, not just batched (DESIGN.md Sec. 9.2):
every distinct ``[S, B, K]`` shape fed to the jitted dispatch pays an
XLA retrace, so the executor pins all three axes — S is the FULL kernel
shard group (shards with no round this wave ride along as all-padding
rows), B is the scheduler's ``round_cap``, K is the next power of two —
and steady-state waves reuse one compiled program.  ``DispatchStats``
counts traces vs cache hits and the padding bytes the stability costs;
the stacked word tables are donated to the dispatch so the device never
holds two copies per wave.

Round FORMATION also lives here (:func:`build_rounds`): the service's
conflict-defer rule — an op whose targets collide with an op already in
this round's claim set is pushed to the NEXT round instead of being
executed-to-lose.  Under the deterministic one-shot semantics a
duplicate-target op is guaranteed to fail condition (b), so executing it
would burn batch slots and CAS work on a known outcome; deferral keeps
every submitted CAS a potential winner (the paper's fewer-CASes lever,
applied at the batching layer).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.obs import span
from repro.pmwcas import (Backend, KernelBackend, MwCASOp,
                          ops_to_arrays, pmwcas_apply_stacked)


@dataclasses.dataclass
class DispatchStats:
    """Trace-cache accounting for the stacked kernel dispatch.

    ``traces`` counts dispatches whose ``[S, B, K]`` (+ table width and
    kernel flags) shape had never been seen by this executor — each one
    is an XLA recompile.  ``hits`` are dispatches served by an
    already-compiled shape; a steady-state service must retrace ZERO
    times (the bench asserts it).  ``bytes_padded`` is what shape
    stability costs: pad cells shipped to the device per dispatch
    (addr+exp+des, 4 bytes each)."""
    traces: int = 0
    hits: int = 0
    dispatches: int = 0          # stacked device calls issued
    serial_rounds: int = 0       # rounds executed by per-shard fallback
    bytes_padded: int = 0

    def as_row(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def build_rounds(queues: Dict[int, Sequence], round_cap: int
                 ) -> Tuple[Dict[int, list], Dict[int, list],
                            Dict[int, int], Dict[int, int]]:
    """Form one conflict-free round per shard from FIFO queues.

    ``queues`` maps shard -> sequence of entries, each entry an object
    with a ``local`` attribute (a shard-local :class:`MwCASOp`).
    Returns ``(rounds, leftovers, defers, overflows)``:
    ``rounds[s]`` the entries scheduled this round, ``leftovers[s]`` the
    entries to retry next round (conflict-deferred or over ``round_cap``,
    original order preserved), and the two defer counters per shard.
    """
    rounds: Dict[int, list] = {}
    leftovers: Dict[int, list] = {}
    defers: Dict[int, int] = {}
    overflows: Dict[int, int] = {}
    for shard, queue in queues.items():
        claimed: set = set()
        sched, later = [], []
        n_defer = n_over = 0
        for entry in queue:
            targets = set(entry.local.addrs)
            if targets & claimed:
                n_defer += 1           # conflict-defer wins the attribution
                later.append(entry)
            elif len(sched) >= round_cap:
                n_over += 1
                later.append(entry)
            else:
                claimed |= targets
                sched.append(entry)
        if sched:
            rounds[shard] = sched
        if later:
            leftovers[shard] = later
        defers[shard] = n_defer
        overflows[shard] = n_over
    return rounds, leftovers, defers, overflows


def schedule_wave(queues: Dict[int, Sequence], round_cap: int, stats
                  ) -> Tuple[Dict[int, list], Dict[int, list]]:
    """:func:`build_rounds` plus defer/overflow accounting into a
    :class:`~repro.service.ServiceStats` — the wave-formation step both
    the raw scheduler and the KV front run."""
    rounds, leftovers, defers, overflows = build_rounds(queues, round_cap)
    for s, n in defers.items():
        stats.shards[s].defers += n
    for s, n in overflows.items():
        stats.shards[s].overflows += n
    return rounds, leftovers


def execute_wave(executor, backends: Sequence[Backend],
                 rounds: Dict[int, Sequence], stats
                 ) -> Dict[int, List[Tuple[object, bool]]]:
    """Run one wave of formed shard rounds and record the per-shard
    round/CAS accounting; returns ``{shard: [(entry, won)]}`` for the
    caller to complete futures / requeue losers from."""
    verdicts = executor.execute(
        backends, {s: [p.local for p in entries]
                   for s, entries in rounds.items()})
    stats.dispatch = getattr(executor, "stats", None)
    out: Dict[int, List[Tuple[object, bool]]] = {}
    for s, entries in rounds.items():
        st = stats.shards[s]
        st.rounds += 1
        st.ops_executed += len(entries)
        pairs = []
        for ok, entry in zip(verdicts[s], entries):
            if ok:
                st.ops_won += 1
            pairs.append((entry, bool(ok)))
        out[s] = pairs
    return out


class SerialShardExecutor:
    """Reference engine: one ``backend.execute`` call per shard round."""

    name = "serial"

    def __init__(self):
        self.stats = DispatchStats()

    def execute(self, backends: Sequence[Backend],
                rounds: Dict[int, List[MwCASOp]]) -> Dict[int, List[bool]]:
        out: Dict[int, List[bool]] = {}
        for shard, ops in rounds.items():
            with span("executor.serial_round", shard=shard, ops=len(ops)):
                verdicts = backends[shard].execute(ops)
            out[shard] = [bool(r.success) for r in verdicts]
            self.stats.serial_rounds += 1
        return out


class StackedKernelExecutor:
    """Kernel shard rounds in ONE vmapped dispatch; serial fallback for
    everything else.  ``last_stacked`` records how many shard rounds the
    most recent call actually stacked (tests and benches read it).

    Every distinct stacked shape pays one XLA retrace, so the dispatch
    is pinned to SHAPE BUCKETS ``[S, B_bucket, K_bucket]``:

    - **S** is the whole kernel shard group, every wave — a shard with
      no round this wave rides along as all-padding rows rather than
      shrinking the stack (a varying S would retrace);
    - **B_bucket** is ``round_cap`` when known (rounds never exceed it),
      else the next power of two of the widest round;
    - **K_bucket** is the next power of two of the widest op.

    Padded rows/slots are ``addr = -1`` no-ops.  The stacked word-table
    temporary is donated to the dispatch (`pmwcas_apply_stacked`), and
    ``stats``/:class:`DispatchStats` counts traces vs cache hits plus
    the padding bytes bucketing ships — steady-state waves must be
    all hits.
    """

    name = "stacked"

    def __init__(self, round_cap: Optional[int] = None):
        self._serial = SerialShardExecutor()
        self.round_cap = round_cap
        self.last_stacked = 0
        self.stacked_dispatches = 0
        self.stats = DispatchStats()
        self._shapes: Set[Hashable] = set()     # mirror of XLA's trace cache

    @staticmethod
    def _group_key(backend: KernelBackend) -> Hashable:
        return (backend.n_words, backend.use_kernel, backend.interpret)

    def execute(self, backends: Sequence[Backend],
                rounds: Dict[int, List[MwCASOp]]) -> Dict[int, List[bool]]:
        import jax.numpy as jnp
        # group EVERY kernel shard (not just those with a round this
        # wave): group membership fixes the stacked S axis
        groups: Dict[Hashable, List[int]] = {}
        rest: Dict[int, List[MwCASOp]] = {}
        for shard, b in enumerate(backends):
            if isinstance(b, KernelBackend):
                groups.setdefault(self._group_key(b), []).append(shard)
        for shard, ops in rounds.items():
            if not isinstance(backends[shard], KernelBackend):
                rest[shard] = ops
        out: Dict[int, List[bool]] = {}
        self.last_stacked = 0
        for key, shards in groups.items():
            active = [s for s in shards if s in rounds]
            if not active:
                continue
            if len(shards) < 2:
                # a lone kernel shard gains nothing from stacking
                rest[shards[0]] = rounds[shards[0]]
                continue
            n_words, use_kernel, interpret = key
            B = max(len(rounds[s]) for s in active)
            if self.round_cap and self.round_cap >= B:
                B = self.round_cap
            else:
                B = 1 << (B - 1).bit_length()    # capless: pow2 bucket
            K = max(op.k for s in active for op in rounds[s])
            K = 1 << (K - 1).bit_length()        # next power of two
            shape = (len(shards), B, K, n_words, use_kernel, interpret)
            if shape in self._shapes:
                self.stats.hits += 1
                traced = False
            else:
                self._shapes.add(shape)
                self.stats.traces += 1
                traced = True
            addr = np.full((len(shards), B, K), -1, np.int32)
            exp = np.zeros((len(shards), B, K), np.uint32)
            des = np.zeros((len(shards), B, K), np.uint32)
            for i, s in enumerate(shards):
                if s not in rounds:
                    continue
                a, e, d = ops_to_arrays(rounds[s], K)
                addr[i, :a.shape[0]] = a
                exp[i, :a.shape[0]] = e
                des[i, :a.shape[0]] = d
            real_cells = sum(op.k for s in active for op in rounds[s])
            self.stats.bytes_padded += \
                (len(shards) * B * K - real_cells) * 3 * 4
            with span("executor.stacked_dispatch", shards=len(shards),
                      B=B, K=K, traced=traced):
                words = jnp.stack([backends[s].word_table()
                                   for s in shards])
                new, success = pmwcas_apply_stacked(
                    words, jnp.asarray(addr), jnp.asarray(exp),
                    jnp.asarray(des), use_kernel=use_kernel,
                    interpret=interpret)
                success = np.asarray(success)
            for i, s in enumerate(shards):
                backends[s].set_word_table(new[i])
                if s in rounds:
                    out[s] = [bool(v)
                              for v in success[i, :len(rounds[s])]]
            self.last_stacked += len(active)
            self.stacked_dispatches += 1
            self.stats.dispatches += 1
        if rest:
            out.update(self._serial.execute(backends, rest))
            self.stats.serial_rounds += len(rest)
        return out


def select_executor(backends: Sequence[Backend], stack_kernel: bool = True,
                    round_cap: Optional[int] = None):
    """Stacked engine whenever >= 2 shards are kernel-backed; pass the
    scheduler's ``round_cap`` so stacked shapes stay compile-stable."""
    n_kernel = sum(isinstance(b, KernelBackend) for b in backends)
    if stack_kernel and n_kernel >= 2:
        return StackedKernelExecutor(round_cap)
    return SerialShardExecutor()

"""repro.service — sharded, batched PMwCAS execution for many clients.

The paper's throughput levers are fewer CASes and descriptor-as-WAL
batching; this package applies both one level up, where many logical
clients multiplex onto the kernel/durable substrates:

- :class:`ShardRouter` — partitions the word space into S shards
  (range or interleaved-hash), each shard owning its own backend
  instance; bijective global<->local address translation, plus
  multiplicative-hash key routing for the KV front.
- :class:`BatchScheduler` — async raw-op layer: clients ``submit``
  :class:`repro.pmwcas.MwCASOp`\\ s and get :class:`OpFuture`\\ s; queued
  ops coalesce into conflict-free per-shard rounds (duplicate-target
  ops are DEFERRED to the next round, never executed to certain
  failure), all shard rounds execute in one wave, and cross-shard ops
  run in a serialized global round (journaled when shards are durable,
  so no crash can half-apply one).
- :class:`StackedKernelExecutor` — kernel shards' rounds stacked into
  one ``jax.vmap``-ped ``pmwcas_apply`` dispatch: S rounds, one device
  call.
- :class:`KVService` — the structures front: per-shard
  :class:`repro.structures.HashMap` / ``BzTreeIndex`` partitions,
  logical :class:`repro.structures.KVOp` submissions compiled
  per-snapshot and retried across waves, split/GC protocols included.
- :class:`ServiceStats` — per-shard round counts, batch occupancy,
  defer/conflict rates, p50/p99 op latency in rounds.

See DESIGN.md Sec. 8 for the architecture and the cross-shard
serialization argument; ``examples/kv_service.py`` is the walkthrough.
"""
from .checkers import check_migration_crash_sweep
from .executor import (DispatchStats, SerialShardExecutor,
                       StackedKernelExecutor, build_rounds, execute_wave,
                       schedule_wave, select_executor)
from .journal import (CrossShardJournal, MIG_COMPLETED, MIG_MIGRATING,
                      MIG_ROUTED, MigrationLog)
from .router import CROSS_SHARD, RoutedOp, ShardRouter
from .scheduler import BatchScheduler, OpFuture, ServiceError
from .service import KVFuture, KVService
from .stats import (ServiceStats, ShardStats, collect_durability,
                    fresh_stats)

__all__ = [
    "ShardRouter", "RoutedOp", "CROSS_SHARD",
    "BatchScheduler", "OpFuture", "ServiceError",
    "KVService", "KVFuture",
    "SerialShardExecutor", "StackedKernelExecutor", "DispatchStats",
    "build_rounds", "schedule_wave", "execute_wave", "select_executor",
    "CrossShardJournal",
    "MigrationLog", "MIG_MIGRATING", "MIG_ROUTED", "MIG_COMPLETED",
    "check_migration_crash_sweep",
    "ServiceStats", "ShardStats", "collect_durability", "fresh_stats",
]

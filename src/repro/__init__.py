"""repro — reproduction of "Practical Persistent Multi-Word Compare-and-
Swap Algorithms for Many-Core CPUs" grown into a jax/Pallas system.

Public surface (import from here or from :mod:`repro.pmwcas`):

- ``repro.pmwcas`` — the unified PMwCAS API: operation model
  (``Target``/``MwCASOp``/``OpResult``), algorithm strategies
  (``OURS``/``OURS_DF``/``ORIGINAL``/``PCAS``), pluggable backends
  (``SimBackend``/``KernelBackend``/``DurableBackend``), the fluent
  ``SimSession`` builder and cross-backend ``run_differential``.
- ``repro.structures`` — lock-free persistent data structures built on
  the unified API (``HashMap``, ``SortedNode``, the multi-node
  ``BzTreeIndex``, ``FreeListAllocator``), plus the YCSB-style workload
  compiler, structure-level crash checkers and
  ``run_struct_differential``.
- ``repro.service`` — sharded, batched execution for many-client
  workloads (``KVService``, ``BatchScheduler``, ``ShardRouter``, the
  stacked kernel dispatch, cross-shard journal and ``ServiceStats``).
- ``repro.chaos`` — statechart-driven workload & fault harness
  (``ScenarioDriver``, client/fault ``Machine`` statecharts, the named
  scenario families, ``chaos_sweep`` and the linearizability checker).
- ``repro.obs`` — unified tracing & metrics (``MetricsRegistry``,
  ``SpanTracer``, ``span``, ``enable_tracing``, the Chrome-trace/JSONL
  exporters and the ``fold_*`` stats adapters).
- checkpoint layer: ``Committer``, ``MarkerCommitter``,
  ``CheckpointManager``, ``AsyncCheckpointManager``, ``PMemPool``,
  ``SimulatedCrash``.

Attribute access is lazy so ``import repro`` never initializes a jax
backend (``launch.dryrun`` must set XLA flags first).
"""
from __future__ import annotations

import importlib
from typing import Any

__version__ = "0.1.0"

# name -> providing module (resolved lazily on first attribute access)
_CHECKPOINT = ("Committer", "MarkerCommitter", "CheckpointManager",
               "AsyncCheckpointManager", "PMemPool", "SimulatedCrash",
               "data_rel")
_STRUCTURES = ("HashMap", "KVOp", "StructResult", "SortedNode",
               "BzTreeIndex", "LeafNode", "NeedsSplit",
               "FreeListAllocator", "OutOfRegions",
               "WorkloadSpec", "WorkloadStats",
               "compile_workload", "run_workload", "client_streams",
               "interleave", "partition_ops",
               "run_struct_differential", "StructDifferentialReport",
               "check_durable_crash_sweep", "check_sim_crash_sweep",
               "check_tree_crash_sweep",
               "TornStructure", "CrashCheckError")
_SERVICE = ("KVService", "KVFuture", "BatchScheduler", "OpFuture",
            "ShardRouter", "CROSS_SHARD", "ServiceStats", "ServiceError",
            "CrossShardJournal", "StackedKernelExecutor", "DispatchStats",
            "collect_durability")
_PMWCAS = (
    "Addr", "Target", "MwCASOp", "Descriptor", "OpResult",
    "batch_width", "ops_to_arrays", "ops_from_arrays", "results_from_mask",
    "Algorithm", "OURS", "OURS_DF", "ORIGINAL", "PCAS", "STRATEGIES",
    "resolve", "ALGORITHMS",
    "Backend", "SimBackend", "KernelBackend", "DurableBackend",
    "UnsupportedBatch", "DurabilityStats",
    "make_backend", "register_backend", "BACKEND_FACTORIES",
    "SimSession", "SimConfig", "SimResult", "CostModel",
    "run_sim", "run_until", "generate_ops", "generate_schedule",
    "zipf_probs", "pmwcas_apply_stacked",
    "recover", "committed_histogram", "check_crash_consistency",
    "RecoveryError",
    "run_differential", "increment_batch", "DifferentialReport",
    "pmwcas_apply", "pmwcas_apply_ref", "pmwcas_success_ref",
    "pmwcas_success_pallas", "reserve_slots", "sequential_oracle",
    "CNT_CAS", "CNT_CYCLES", "CNT_FAILS", "CNT_FLUSH", "CNT_HELPS",
    "CNT_INVAL", "CNT_LOAD", "CNT_OPS", "CNT_STORE",
    "TAG_DESC", "TAG_DESC_DIRTY", "TAG_DIRTY", "TAG_MASK", "TAG_PAYLOAD",
    "TAG_SHIFT",
)
_CHAOS = ("Scenario", "ScenarioDriver", "ChaosReport",
          "ClientMachine", "ClientSpec", "FaultMachine", "FaultSpec",
          "Machine", "Transition", "Event",
          "HistoryRecorder", "check_history", "CheckStats",
          "LinearizabilityError", "chaos_sweep", "default_scenarios",
          "run_scenario")
_OBS = ("MetricsRegistry", "Counter", "Gauge", "Histogram",
        "get_registry", "reset_metrics",
        "SpanTracer", "span", "instant", "get_tracer",
        "enable_tracing", "disable_tracing", "tracing_enabled",
        "chrome_trace", "export_chrome_trace", "export_jsonl",
        "validate_chrome_trace", "span_tree",
        "fold_durability", "fold_dispatch", "fold_service",
        "fold_check", "fold_workload")
_LAZY = {name: "repro.pmwcas" for name in _PMWCAS}
_LAZY.update({name: "repro.checkpoint" for name in _CHECKPOINT})
_LAZY.update({name: "repro.structures" for name in _STRUCTURES})
_LAZY.update({name: "repro.service" for name in _SERVICE})
_LAZY.update({name: "repro.chaos" for name in _CHAOS})
_LAZY.update({name: "repro.obs" for name in _OBS})

__all__ = sorted(_LAZY) + ["chaos", "obs", "pmwcas", "service",
                           "structures"]


def __getattr__(name: str) -> Any:
    if name in ("chaos", "obs", "pmwcas", "structures", "service"):
        return importlib.import_module(f"repro.{name}")
    try:
        module = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__():
    return __all__

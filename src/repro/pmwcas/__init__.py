"""Unified PMwCAS API: one operation model, pluggable backends.

The paper's contribution — persistent multi-word CAS with descriptors as
write-ahead logs — exists in this repo on three substrates.  This package
is the single public surface over all of them:

- operation model: :class:`Target`, :class:`MwCASOp`, :class:`Descriptor`,
  :class:`OpResult`
- :class:`Backend` protocol with :class:`SimBackend`,
  :class:`KernelBackend`, :class:`DurableBackend`
- algorithm strategies :data:`OURS`, :data:`OURS_DF`, :data:`ORIGINAL`,
  :data:`PCAS` (replacing the legacy magic strings)
- the fluent :class:`SimSession` builder over the cycle-accurate simulator
- :func:`run_differential` for cross-backend agreement checks

Legacy entry points (``repro.core.run_sim``, ``repro.kernels.
pmwcas_apply.ops``, ``repro.checkpoint.Committer``) remain importable as
the implementation layer for one deprecation cycle; new code should
import from here or from ``repro`` directly.  See DESIGN.md Sec. 3 for
the backend matrix and Sec. 4 for the migration table.
"""
from repro.core import (CostModel, RecoveryError, SimConfig, SimResult,
                        check_crash_consistency, committed_histogram,
                        recover, run_sim, run_until)
# Instrumentation vocabulary (counter slots / tag bits) — re-exported so
# benchmarks and tests never reach into core.model.
from repro.core.model import (ALGORITHMS, CNT_CAS, CNT_CYCLES, CNT_FAILS,
                              CNT_FLUSH, CNT_HELPS, CNT_INVAL, CNT_LOAD,
                              CNT_OPS, CNT_STORE, TAG_DESC, TAG_DESC_DIRTY,
                              TAG_DIRTY, TAG_MASK, TAG_PAYLOAD, TAG_SHIFT,
                              generate_ops, generate_schedule, zipf_probs)

from .algorithms import (Algorithm, ORIGINAL, OURS, OURS_DF, PCAS,
                         STRATEGIES, resolve)
from repro.checkpoint.committer import DurabilityStats

from .backends import (BACKEND_FACTORIES, Backend, DurableBackend,
                       KernelBackend, SimBackend, UnsupportedBatch,
                       make_backend, register_backend)
from .descriptor import (Addr, Descriptor, MwCASOp, OpResult, Target,
                         batch_width, ops_from_arrays, ops_to_arrays,
                         results_from_mask)
from .differential import (DifferentialReport, increment_batch,
                           run_differential)
from .session import SimSession


# Batched-primitive entry points (wrap the kernel layer lazily: Pallas
# imports are deferred until first use so `import repro.pmwcas` stays
# cheap on machines without a compiled jaxlib cache).
def pmwcas_apply(words, addr, exp, des, **kw):
    """Batched MwCAS against a word table; see kernels.pmwcas_apply.ops."""
    from repro.kernels.pmwcas_apply.ops import pmwcas_apply as _impl
    return _impl(words, addr, exp, des, **kw)


def pmwcas_apply_stacked(words, addr, exp, des, **kw):
    """S stacked shard rounds in one vmapped dispatch (words donated);
    see kernels.pmwcas_apply.ops."""
    from repro.kernels.pmwcas_apply.ops import \
        pmwcas_apply_stacked as _impl
    return _impl(words, addr, exp, des, **kw)


def reserve_slots(free_mask, requests, **kw):
    """Atomic K-slot reservation on a free-bitmap (serving layer)."""
    from repro.kernels.pmwcas_apply.ops import reserve_slots as _impl
    return _impl(free_mask, requests, **kw)


def pmwcas_apply_ref(words, addr, exp, des):
    """Pure-jnp oracle of :func:`pmwcas_apply` (no Pallas)."""
    from repro.kernels.pmwcas_apply.ref import pmwcas_apply as _impl
    return _impl(words, addr, exp, des)


def pmwcas_success_ref(addr, cur, exp):
    """Pure-jnp success verdicts (condition (a) + (b))."""
    from repro.kernels.pmwcas_apply.ref import pmwcas_success as _impl
    return _impl(addr, cur, exp)


def sequential_oracle(words, addr, exp, des):
    """Numpy sequential one-touch oracle (containment reference)."""
    from repro.kernels.pmwcas_apply.ref import sequential_oracle as _impl
    return _impl(words, addr, exp, des)


def pmwcas_success_pallas(addr, cur, exp, **kw):
    """Raw Pallas success verdicts (tiling/interpret knobs exposed)."""
    from repro.kernels.pmwcas_apply.kernel import \
        pmwcas_success_pallas as _impl
    return _impl(addr, cur, exp, **kw)


__all__ = [
    # operation model
    "Addr", "Target", "MwCASOp", "Descriptor", "OpResult",
    "batch_width", "ops_to_arrays", "ops_from_arrays", "results_from_mask",
    # strategies
    "Algorithm", "OURS", "OURS_DF", "ORIGINAL", "PCAS", "STRATEGIES",
    "resolve", "ALGORITHMS",
    # backends
    "Backend", "SimBackend", "KernelBackend", "DurableBackend",
    "UnsupportedBatch", "DurabilityStats",
    "make_backend", "register_backend", "BACKEND_FACTORIES",
    # session + sim surface
    "SimSession", "SimConfig", "SimResult", "CostModel",
    "run_sim", "run_until", "generate_ops", "generate_schedule",
    "zipf_probs",
    # recovery
    "recover", "committed_histogram", "check_crash_consistency",
    "RecoveryError",
    # differential
    "run_differential", "increment_batch", "DifferentialReport",
    # batched primitives
    "pmwcas_apply", "pmwcas_apply_stacked", "pmwcas_apply_ref",
    "pmwcas_success_ref", "pmwcas_success_pallas", "reserve_slots",
    "sequential_oracle",
    # instrumentation vocabulary
    "CNT_CAS", "CNT_CYCLES", "CNT_FAILS", "CNT_FLUSH", "CNT_HELPS",
    "CNT_INVAL", "CNT_LOAD", "CNT_OPS", "CNT_STORE",
    "TAG_DESC", "TAG_DESC_DIRTY", "TAG_DIRTY", "TAG_MASK", "TAG_PAYLOAD",
    "TAG_SHIFT",
]

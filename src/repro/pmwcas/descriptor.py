"""Canonical PMwCAS operation model shared by every backend.

The paper's single algorithmic object is the *descriptor*: a persisted
record of (address, expected, desired) triples plus a state word, acting
as its own write-ahead log.  This repo executes that object on three very
different substrates — a cycle-accurate many-core simulator, a batched
Pallas kernel, and a file-granularity durable committer — and this module
defines the one vocabulary all of them accept:

- :class:`Target`      one (addr, expected, desired) triple
- :class:`MwCASOp`     an atomic multi-word compare-and-swap (>=1 targets)
- :class:`Descriptor`  the WAL view of an op (op id + state + targets)
- :class:`OpResult`    per-op verdict returned by a backend

Addresses are ``int`` word indices for the array-shaped backends
(simulator / kernel) and ``str`` slot names for the durable backend; an
``int`` address is mapped to the slot name ``w<addr>`` so the same
``MwCASOp`` batch can run against every backend (the cross-backend
differential test relies on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

Addr = Union[int, str]

# Descriptor states, shared vocabulary with checkpoint.committer and
# core.model (paper Table 1).
STATE_COMPLETED = "COMPLETED"
STATE_FAILED = "FAILED"
STATE_SUCCEEDED = "SUCCEEDED"
STATE_UNDECIDED = "UNDECIDED"


@dataclasses.dataclass(frozen=True)
class Target:
    """One word of a PMwCAS: CAS ``addr`` from ``expected`` to ``desired``."""
    addr: Addr
    expected: int
    desired: int

    def __post_init__(self):
        if isinstance(self.addr, int) and self.addr < 0:
            raise ValueError(f"negative address {self.addr} (reserved for "
                             "padding in array form)")

    @property
    def slot_name(self) -> str:
        """Slot-name form of the address (durable backend)."""
        return self.addr if isinstance(self.addr, str) else f"w{self.addr}"


@dataclasses.dataclass(frozen=True)
class MwCASOp:
    """An atomic multi-word CAS: all targets move together or none do.

    Targets must not repeat an address (the paper's descriptors embed each
    word once; duplicates would make success ill-defined).  For backends
    that require the paper's canonical embedding order, use
    :meth:`sorted`.
    """
    targets: Tuple[Target, ...]

    def __init__(self, targets: Iterable[Union[Target, Tuple[Addr, int, int]]]):
        tgts = tuple(t if isinstance(t, Target) else Target(*t)
                     for t in targets)
        if not tgts:
            raise ValueError("MwCASOp needs at least one target")
        addrs = [t.addr for t in tgts]
        if len(set(addrs)) != len(addrs):
            raise ValueError(f"duplicate target addresses in {addrs}")
        object.__setattr__(self, "targets", tgts)

    # -- views ---------------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.targets)

    @property
    def addrs(self) -> Tuple[Addr, ...]:
        return tuple(t.addr for t in self.targets)

    def sorted(self) -> "MwCASOp":
        """Canonical (address-sorted) embedding order — deadlock freedom for
        lock-style reservation (paper Sec. 2.1)."""
        return MwCASOp(tuple(sorted(self.targets, key=lambda t: t.addr)))

    def is_increment(self) -> bool:
        """True when every target moves expected -> expected + 1 (the
        paper's benchmark workload; the only shape the cycle-accurate
        simulator executes natively)."""
        return all(t.desired == t.expected + 1 for t in self.targets)

    # -- constructors --------------------------------------------------------
    @classmethod
    def increment(cls, addrs: Sequence[Addr],
                  current: Sequence[int]) -> "MwCASOp":
        """The benchmark op: CAS each word from its current value to +1."""
        return cls(tuple(Target(a, int(c), int(c) + 1)
                         for a, c in zip(addrs, current)))


@dataclasses.dataclass
class Descriptor:
    """Write-ahead-log view of an op.

    ``DurableBackend`` derives its commit targets from
    :meth:`slot_targets`; the committer then persists an equivalent
    record (same id / state vocabulary / targets list) under ``wal/``.
    The simulator holds the same information in its ``d_*`` arrays; the
    kernel never materializes it (one batch = one implicit generation of
    descriptors, index order = linearization).
    """
    op_id: str
    op: MwCASOp
    state: str = STATE_FAILED

    def slot_targets(self) -> List[Tuple[str, int, int]]:
        """(slot, expected, desired) triples in committer wire format."""
        return [(t.slot_name, t.expected, t.desired)
                for t in self.op.targets]

    def as_record(self) -> Dict:
        return {"id": self.op_id, "state": self.state,
                "targets": [list(t) for t in self.slot_targets()]}


@dataclasses.dataclass(frozen=True)
class OpResult:
    """Per-op verdict from one backend execution."""
    index: int                 # position in the submitted batch
    success: bool
    backend: str               # backend.name that produced the verdict
    op: MwCASOp

    def __bool__(self) -> bool:  # `if result:` reads naturally
        return self.success


# ---------------------------------------------------------------------------
# Array bridging (simulator / kernel backends)
# ---------------------------------------------------------------------------

def batch_width(ops: Sequence[MwCASOp]) -> int:
    return max(op.k for op in ops)


def ops_to_arrays(ops: Sequence[MwCASOp], k: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack a batch into (addr int32[B,K] with -1 padding, exp, des uint32).

    This is the wire format of ``repro.pmwcas.KernelBackend`` (and of the
    underlying Pallas kernel).  Addresses must be ints.
    """
    if not ops:
        raise ValueError("empty batch")
    K = k or batch_width(ops)
    B = len(ops)
    addr = np.full((B, K), -1, np.int32)
    exp = np.zeros((B, K), np.uint32)
    des = np.zeros((B, K), np.uint32)
    for i, op in enumerate(ops):
        if op.k > K:
            raise ValueError(f"op {i} has {op.k} targets > batch width {K}")
        for j, t in enumerate(op.targets):
            if not isinstance(t.addr, int):
                raise TypeError(
                    f"op {i} target {j} has non-int address {t.addr!r}; "
                    "array backends need word indices")
            addr[i, j] = t.addr
            exp[i, j] = t.expected
            des[i, j] = t.desired
    return addr, exp, des


def ops_from_arrays(addr, exp, des) -> List[MwCASOp]:
    """Inverse of :func:`ops_to_arrays` (drops padded slots)."""
    addr, exp, des = (np.asarray(x) for x in (addr, exp, des))
    ops = []
    for i in range(addr.shape[0]):
        tgts = [Target(int(a), int(e), int(d))
                for a, e, d in zip(addr[i], exp[i], des[i]) if a >= 0]
        ops.append(MwCASOp(tgts))
    return ops


def results_from_mask(ops: Sequence[MwCASOp], mask, backend: str
                      ) -> List[OpResult]:
    mask = np.asarray(mask)
    return [OpResult(index=i, success=bool(mask[i]), backend=backend, op=op)
            for i, op in enumerate(ops)]

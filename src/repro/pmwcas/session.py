"""Fluent simulation sessions over the cycle-accurate PMwCAS simulator.

Replaces the ``SimConfig`` + free-function spread (``run_sim`` /
``run_until`` / ``check_crash_consistency``) with one chainable builder::

    result = (SimSession()
              .with_algorithm(OURS)
              .with_threads(32).with_k(3).with_skew(1.0)
              .with_steps(60_000)
              .run())

    rec, hist = (SimSession().with_algorithm(OURS_DF)
                 .with_threads(4).with_words(64)
                 .crash_at(423))          # run_until + recovery check

Sessions are immutable: every ``with_*`` returns a new session, so a base
session can be forked per sweep point (the benchmark pattern).  ``run``
results are plain :class:`repro.core.SimResult` objects — instrumentation
accessors are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core import (CostModel, SimConfig, SimResult,
                        check_crash_consistency, run_sim, run_until)
from .algorithms import Algorithm, resolve


@dataclasses.dataclass(frozen=True)
class SimSession:
    """Immutable builder; terminal operations: run / run_until / crash_at."""
    cfg: SimConfig = dataclasses.field(default_factory=SimConfig)
    ops: Optional[np.ndarray] = None          # pre-generated [T, max_ops, k]
    schedule: Optional[np.ndarray] = None     # explicit interleaving

    # -- generic configuration ----------------------------------------------
    def configure(self, **overrides) -> "SimSession":
        """Override any SimConfig field by name."""
        if "algorithm" in overrides:
            overrides["algorithm"] = resolve(overrides["algorithm"]).name
        return dataclasses.replace(
            self, cfg=dataclasses.replace(self.cfg, **overrides))

    # -- named builders -------------------------------------------------------
    def with_algorithm(self, alg: Union[str, Algorithm]) -> "SimSession":
        return self.configure(algorithm=alg)

    def with_threads(self, n: int) -> "SimSession":
        return self.configure(n_threads=n)

    def with_words(self, n: int) -> "SimSession":
        return self.configure(n_words=n)

    def with_k(self, k: int) -> "SimSession":
        return self.configure(k=k)

    def with_skew(self, alpha: float) -> "SimSession":
        """Zipf skew of the benchmark's word popularity (paper Eq. 1)."""
        return self.configure(alpha=alpha)

    def with_blocks(self, block_bytes: int) -> "SimSession":
        """Memory-block size (Fig. 14 false-sharing lever)."""
        return self.configure(block_bytes=block_bytes)

    def with_steps(self, n: int) -> "SimSession":
        return self.configure(n_steps=n)

    def with_max_ops(self, n: int) -> "SimSession":
        return self.configure(max_ops=n)

    def with_seed(self, seed: int) -> "SimSession":
        return self.configure(seed=seed)

    def with_backoff(self, init: int, cap: int) -> "SimSession":
        return self.configure(backoff_init=init, backoff_cap=cap)

    def with_cost_model(self, cost: CostModel) -> "SimSession":
        return self.configure(cost=cost)

    # -- explicit workload/interleaving ---------------------------------------
    def with_ops(self, ops: np.ndarray) -> "SimSession":
        """Pin the pre-generated target table ([T, max_ops, k] word ids)."""
        return dataclasses.replace(self, ops=np.asarray(ops, np.int32))

    def with_schedule(self, schedule: np.ndarray) -> "SimSession":
        """Pin the thread interleaving (int32[n_steps]; <0 entries no-op)."""
        return dataclasses.replace(
            self, schedule=np.asarray(schedule, np.int32))

    # -- introspection ---------------------------------------------------------
    @property
    def algorithm(self) -> Algorithm:
        return resolve(self.cfg.algorithm)

    def describe(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self.cfg)
        d["algorithm"] = self.algorithm.title
        return d

    # -- terminal operations ---------------------------------------------------
    def run(self, drain: bool = True) -> SimResult:
        """Run the configured schedule; drain to quiescence by default."""
        return run_sim(self.cfg.validate(), ops=self.ops,
                       schedule=self.schedule, drain=drain)

    def run_until(self, n_steps: int) -> SimResult:
        """Run exactly n_steps micro-ops WITHOUT draining (crash studies)."""
        return run_until(self.cfg.validate(), n_steps, ops=self.ops,
                         schedule=self.schedule)

    def crash_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Crash after ``step`` micro-ops, recover from the persisted
        descriptors, and verify the crash invariant.  Returns
        (recovered pmem, committed per-word histogram)."""
        r = self.run_until(step)
        return check_crash_consistency(self.cfg, r.state)

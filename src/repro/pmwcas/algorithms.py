"""Algorithm variants as strategy objects (paper Section 5's competitors).

The simulator historically selected its state machine with magic strings
("ours", "ours_df", ...).  These strategy objects carry the same selector
plus the paper's analytical properties (Sec. 2.1 instruction counts, GC
and helping requirements) so call sites can reason about a variant
without string comparisons::

    SimSession().with_algorithm(OURS).run()
    OURS.cas_per_op(k=3)        # -> 6, the Sec. 2.1 claim tests assert

``resolve`` accepts either a strategy or the legacy string, so the old
spelling keeps working for one deprecation cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.core.model import (ALG_ORIGINAL, ALG_OURS, ALG_OURS_DF, ALG_PCAS)


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """One PMwCAS variant: simulator selector + analytical properties."""
    name: str                    # core.model selector (jit specialization key)
    title: str                   # human-readable label
    dirty_flags: bool            # pays the per-word dirty-flag double flush
    helping: bool                # readers complete foreign ops (needs GC)
    max_k: Optional[int] = None  # None = any width

    # -- paper Sec. 2.1 no-conflict instruction counts ----------------------
    def cas_per_op(self, k: int) -> int:
        """CAS-class events per successful k-word op, zero conflicts."""
        if self.name == ALG_PCAS:
            return 2                      # CAS + atomic clear-store
        if self.name == ALG_ORIGINAL:
            return 4 * k                  # RDCSS + promote + finalize + clear
        return 2 * k                      # reserve + finalize (ours/ours_df)

    def flush_per_op(self, k: int, desc_lines: int = 1) -> Optional[int]:
        """Persist events per successful op, zero conflicts.

        ours: WAL (desc_lines) + installed targets (k) + state (1) +
        finalized targets (k).  Dirty flags add one more flush per target
        (Fig. 4 line 22).  The original algorithm has no closed form here
        (its helper-fused persists depend on interleaving); None.
        """
        if self.name == ALG_PCAS:
            return 1
        if self.name == ALG_ORIGINAL:
            return None
        base = desc_lines + 2 * k + 1
        if self.dirty_flags:
            base += k
        return base

    def supports_k(self, k: int) -> bool:
        return self.max_k is None or k <= self.max_k

    def __str__(self) -> str:  # str(OURS) == "ours": drop-in for cfg fields
        return self.name


OURS = Algorithm(name=ALG_OURS, title="ours (no dirty flags, Sec. 4)",
                 dirty_flags=False, helping=False)
OURS_DF = Algorithm(name=ALG_OURS_DF, title="ours + dirty flags (Sec. 3)",
                    dirty_flags=True, helping=False)
ORIGINAL = Algorithm(name=ALG_ORIGINAL, title="Wang et al. (ICDE'18)",
                     dirty_flags=True, helping=True)
PCAS = Algorithm(name=ALG_PCAS, title="persistent single-word CAS",
                 dirty_flags=True, helping=False, max_k=1)

STRATEGIES = (OURS, OURS_DF, ORIGINAL, PCAS)
_BY_NAME = {a.name: a for a in STRATEGIES}


def resolve(alg: Union[str, Algorithm]) -> Algorithm:
    """Accept a strategy object or a legacy magic string."""
    if isinstance(alg, Algorithm):
        return alg
    try:
        return _BY_NAME[alg]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {alg!r}; expected one of "
            f"{sorted(_BY_NAME)} or an Algorithm strategy") from None

"""Cross-backend differential execution: the same ``MwCASOp`` batch runs
through the simulator, the Pallas kernel, and the durable committer, and
the per-op success verdicts (plus final word values) must agree.

This is the payoff of the unified operation model: the three
implementations of the paper's algorithm check each other.  ``scripts/
ci.sh`` and ``tests/test_pmwcas_api.py`` both drive :func:`run_differential`.

Batch construction caveat (see backends module docstring): the simulator
executes one attempt per op with winner-blocking conflict semantics,
while kernel/durable use the conservative one-shot verdict.  The two
coincide whenever every pair of address-sharing ops involves an actual
winner; :func:`increment_batch` builds batches with that property.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Union

import numpy as np

from .algorithms import Algorithm, OURS
from .backends import DurableBackend, KernelBackend, SimBackend
from .descriptor import MwCASOp


@dataclasses.dataclass
class DifferentialReport:
    ops: List[MwCASOp]
    verdicts: Dict[str, np.ndarray]        # backend name -> bool[B]
    values: Dict[str, np.ndarray]          # backend name -> final word values
    agree: bool

    def summary(self) -> str:
        lines = [f"differential over {len(self.ops)} ops: "
                 f"{'AGREE' if self.agree else 'DISAGREE'}"]
        for name, v in self.verdicts.items():
            lines.append(f"  {name:8s} verdicts={v.astype(int).tolist()}")
        return "\n".join(lines)


def increment_batch(n_words: int, k: int, n_ops: int,
                    seed: int = 0) -> tuple:
    """A random increment batch whose conflict graph only contains
    winner-involving edges (sim == kernel == durable verdicts).

    Strategy: ops are built in index order; an op either reuses addresses
    of the current round's *winner set* (guaranteed conflict with a
    winner) or draws fresh untouched addresses (guaranteed win).  Returns
    (initial_values, ops).
    """
    rng = np.random.default_rng(seed)
    initial = rng.integers(0, 7, n_words).astype(np.uint32)
    winners_addrs: set = set()
    free = list(range(n_words))
    rng.shuffle(free)
    ops = []
    for i in range(n_ops):
        conflict = winners_addrs and rng.random() < 0.5
        if conflict and len(winners_addrs) >= 1 and len(free) >= k - 1:
            stolen = rng.choice(sorted(winners_addrs))
            fresh = [free.pop() for _ in range(k - 1)]
            addrs = sorted([int(stolen)] + fresh)
        elif len(free) >= k:
            addrs = sorted(free.pop() for _ in range(k))
            winners_addrs.update(addrs)
        else:
            break
        ops.append(MwCASOp.increment(addrs, [int(initial[a])
                                             for a in addrs]))
    return initial, ops


def run_differential(ops: Sequence[MwCASOp],
                     initial_values: Sequence[int], *,
                     algorithm: Union[str, Algorithm] = OURS,
                     durable_root=None,
                     use_kernel: bool = True,
                     interpret: bool = True) -> DifferentialReport:
    """Execute one batch on all three backends and compare outcomes."""
    initial = np.asarray(initial_values, np.uint32)
    n_words = len(initial)
    addrs = sorted({a for op in ops for a in op.addrs})

    kernel = KernelBackend(values=initial, use_kernel=use_kernel,
                           interpret=interpret)
    sim = SimBackend(n_words, algorithm=algorithm, values=initial)
    durable = DurableBackend(durable_root)
    durable.seed({a: int(initial[a]) for a in addrs})

    verdicts: Dict[str, np.ndarray] = {}
    values: Dict[str, np.ndarray] = {}
    for backend in (sim, kernel, durable):
        results = backend.execute(list(ops))
        verdicts[backend.name] = np.asarray([r.success for r in results])
        values[backend.name] = np.asarray(
            [backend.read(a) for a in addrs], np.int64)

    names = list(verdicts)
    agree = all(
        np.array_equal(verdicts[names[0]], verdicts[n]) and
        np.array_equal(values[names[0]], values[n])
        for n in names[1:])
    return DifferentialReport(ops=list(ops), verdicts=verdicts,
                              values=values, agree=agree)

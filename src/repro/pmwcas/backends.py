"""Pluggable execution backends for the canonical PMwCAS operation model.

One batch semantics, three substrates:

=============  ==========================================  ==================
backend        substrate                                   wraps
=============  ==========================================  ==================
SimBackend     cycle-accurate many-core simulator          core.engine / sim
KernelBackend  batched Pallas kernel (TPU / interpret)     kernels.pmwcas_apply
DurableBackend file-granularity descriptor-WAL committer   checkpoint.committer
=============  ==========================================  ==================

Canonical batch semantics (DESIGN.md Sec. 3.2) — *deterministic one-shot*:
the batch executes against the pre-batch state with index order as the
linearization.  Op ``i`` succeeds iff

  (a) every target's expected value matches the pre-batch state, and
  (b) no lower-index op that also passes (a) targets a shared address.

``KernelBackend`` and ``DurableBackend`` implement exactly this.
``SimBackend`` replays the batch through the micro-op state machines (one
attempt per op, expected values read before any attempt runs) which
yields the *winner-blocking* refinement of (b): an (a)-passing op that
itself lost does not block later ops, because the state machine rolls its
reservations back before the next attempt starts.  The two verdicts
coincide on any batch in which every pair of address-sharing ops involves
an actual winner — the differential test constructs such batches, and
``repro.pmwcas.differential`` asserts three-way agreement.
"""
from __future__ import annotations

import functools
import pathlib
import tempfile
from typing import (Callable, Dict, List, Mapping, Optional, Protocol,
                    Sequence, Tuple, Union, runtime_checkable)

import numpy as np

from repro.checkpoint.committer import (Committer, DurabilityStats,
                                        _slot_rel, data_rel)
from repro.checkpoint.marker_committer import MarkerCommitter
from repro.checkpoint.pmem import PMemPool
from repro.core import SimConfig
from repro.core import engine as _engine
from repro.core.model import (ALG_PCAS, PC, TAG_MASK, TAG_SHIFT,
                              init_state)
from repro.obs import span

from .algorithms import Algorithm, OURS, resolve
from .descriptor import (Addr, Descriptor, MwCASOp, OpResult,
                         ops_to_arrays, results_from_mask)


@runtime_checkable
class Backend(Protocol):
    """What every PMwCAS execution backend provides."""
    name: str

    def execute(self, ops: Sequence[MwCASOp]) -> List[OpResult]:
        """Run one batch under the deterministic one-shot semantics."""
        ...

    def read(self, addr: Addr) -> int:
        """Current committed value of one word/slot."""
        ...


class UnsupportedBatch(ValueError):
    """The backend cannot express this batch (see SimBackend limits)."""


@functools.lru_cache(maxsize=32)
def _compiled_step(cfg: SimConfig):
    """One jitted engine.step per SimConfig, reused across execute calls."""
    import jax
    return jax.jit(functools.partial(_engine.step, cfg))


# ===========================================================================
# Kernel backend
# ===========================================================================

class KernelBackend:
    """Word table + the batched Pallas conflict-resolution kernel.

    ``use_kernel=False`` routes verdicts through the pure-jnp oracle
    (``kernels.pmwcas_apply.ref``) — bit-identical by test, useful when
    Pallas interpret mode is too slow for a sweep.
    """
    name = "kernel"

    def __init__(self, n_words: Optional[int] = None,
                 values: Optional[Sequence[int]] = None, *,
                 use_kernel: bool = True, interpret: bool = True):
        import jax.numpy as jnp
        if values is not None:
            self._words = jnp.asarray(np.asarray(values, np.uint32))
        elif n_words is not None:
            self._words = jnp.zeros(n_words, jnp.uint32)
        else:
            raise ValueError("need n_words or values")
        self.use_kernel = use_kernel
        self.interpret = interpret

    # -- Backend protocol ------------------------------------------------------
    def execute(self, ops: Sequence[MwCASOp],
                k: Optional[int] = None) -> List[OpResult]:
        from repro.kernels.pmwcas_apply.ops import pmwcas_apply
        import jax.numpy as jnp
        with span("mwcas.round", backend=self.name, ops=len(ops)):
            addr, exp, des = ops_to_arrays(ops, k)
            new, success = pmwcas_apply(
                self._words, jnp.asarray(addr), jnp.asarray(exp),
                jnp.asarray(des), use_kernel=self.use_kernel,
                interpret=self.interpret)
            self._words = new
            return results_from_mask(ops, np.asarray(success), self.name)

    def read(self, addr: Addr) -> int:
        if not isinstance(addr, int):
            raise TypeError(f"kernel backend uses int addresses, got {addr!r}")
        return int(self._words[addr])

    def values(self) -> np.ndarray:
        return np.asarray(self._words)

    # -- sharded-service surface ----------------------------------------------
    @property
    def n_words(self) -> int:
        return int(self._words.shape[0])

    def word_table(self):
        """The live device word table (jnp uint32[W]).  The sharded
        service's stacked dispatch reads the tables of several kernel
        shards, stacks them into one [S, W] array and runs ONE vmapped
        ``pmwcas_apply`` over all shard rounds."""
        return self._words

    def set_word_table(self, new) -> None:
        """Install an updated table (the write-back half of the stacked
        dispatch).  Must have the same shape/dtype as :meth:`word_table`."""
        import jax.numpy as jnp
        new = jnp.asarray(new)
        if new.shape != self._words.shape:
            raise ValueError(f"word table shape {new.shape} != "
                             f"{self._words.shape}")
        self._words = new


# ===========================================================================
# Simulator backend
# ===========================================================================

class SimBackend:
    """One-shot batches through the cycle-accurate micro-op state machines.

    Each op becomes one simulated thread running exactly one attempt:
    every thread first reads its targets (so all expected values are
    pre-batch), then attempts run to their operation boundary in index
    order.  Success is the thread's own verdict (op_idx advanced); the
    word table is carried across ``execute`` calls.

    Arbitrary desired values are native: the machines take explicit
    per-target desired payloads (``ops_des`` in the engine state), so
    structure rounds — whose desireds are keys, values and TOMBSTONEs,
    not increments — run without shadowing onto fresh words.  Two
    per-batch remaps make that fit the engine:

    - a *value codec*: the machines compare words only for equality, so
      payloads are injectively renumbered into small ids (keeping every
      real value, including ``TOMBSTONE = 2**32 - 1``, inside the
      ``32 - TAG_SHIFT``-bit payload field) and decoded on write-back;
    - *address compression + private pads*: touched addresses compress
      to ``0..n-1`` (monotonic, so canonical sorted order is preserved)
      and each op narrower than the batch's widest is padded to uniform
      width with fresh private guard words (expected == desired == 0)
      appended above the compressed range — invisible to the conflict
      graph, required because one engine config has a single ``k``.

    Limits (``UnsupportedBatch`` otherwise):

    - expected values must equal the current stored values: one-shot
      batches take pre-batch expecteds;
    - addresses sorted (the paper's canonical embedding order), distinct
      within an op, int only, in range;
    - the PCAS strategy only supports k == 1 and increment-shaped ops
      (its state machine is hard-wired to ``CAS(v -> v+1)``).

    Instrumentation: ``last_result``-style counters are exposed via
    ``counters`` after each batch (CAS/flush/invalidation totals), so the
    same batch can be costed in modeled cycles.
    """
    name = "sim"

    def __init__(self, n_words: int,
                 algorithm: Union[str, Algorithm] = OURS,
                 values: Optional[Sequence[int]] = None, *,
                 attempt_cap: int = 10_000):
        self.algorithm = resolve(algorithm)
        self.n_words = n_words
        self._values = (np.zeros(n_words, np.uint32) if values is None
                        else np.asarray(values, np.uint32).copy())
        if self._values.shape != (n_words,):
            raise ValueError("values shape mismatch")
        self.attempt_cap = attempt_cap
        self.counters: Optional[np.ndarray] = None

    # -- validation ------------------------------------------------------------
    def _check_batch(self, ops: Sequence[MwCASOp]) -> int:
        if not ops:
            raise UnsupportedBatch("empty batch")
        k_max = max(op.k for op in ops)
        for i, op in enumerate(ops):
            if not self.algorithm.supports_k(op.k):
                raise UnsupportedBatch(
                    f"{self.algorithm.name} supports k<="
                    f"{self.algorithm.max_k}, got {op.k}")
            if self.algorithm.name == ALG_PCAS and not op.is_increment():
                raise UnsupportedBatch(
                    f"op {i} is not increment-shaped; the PCAS machine is "
                    "hard-wired to CAS(v -> v+1)")
            addrs = list(op.addrs)
            if any(not isinstance(a, int) for a in addrs):
                raise UnsupportedBatch(f"op {i} has non-int addresses")
            if addrs != sorted(addrs):
                raise UnsupportedBatch(
                    f"op {i} addresses not in canonical sorted order")
            if len(set(addrs)) != len(addrs):
                raise UnsupportedBatch(f"op {i} has duplicate addresses")
            if any(a < 0 or a >= self.n_words for a in addrs):
                raise UnsupportedBatch(f"op {i} address out of range")
            for t in op.targets:
                if t.expected != int(self._values[t.addr]):
                    raise UnsupportedBatch(
                        f"op {i} expects {t.expected} at word {t.addr} but "
                        f"the simulator holds {int(self._values[t.addr])}; "
                        "one-shot batches take pre-batch expected values")
        return k_max

    # -- Backend protocol ------------------------------------------------------
    def execute(self, ops: Sequence[MwCASOp]) -> List[OpResult]:
        with span("mwcas.round", backend=self.name, ops=len(ops)):
            return self._execute(ops)

    def _execute(self, ops: Sequence[MwCASOp]) -> List[OpResult]:
        import jax.numpy as jnp
        k_max = self._check_batch(ops)
        B = len(ops)
        # compress touched addresses to 0..n-1 (monotonic) and lay private
        # pad words above the compressed range
        touched = sorted({a for op in ops for a in op.addrs})
        index = {a: i for i, a in enumerate(touched)}
        n_pads = sum(k_max - op.k for op in ops)
        # value codec: renumber payloads into dense ids (0 always encodes
        # to id 0, so pad words need no seeding)
        vals = sorted({0} | {int(self._values[a]) for a in touched}
                      | {int(t.desired) for op in ops for t in op.targets})
        if len(vals) >= 1 << (32 - TAG_SHIFT):
            raise UnsupportedBatch("too many distinct payload values")
        enc = {v: i for i, v in enumerate(vals)}
        dec = np.asarray(vals, np.uint32)
        addr_rows: List[List[int]] = []
        des_rows: List[List[int]] = []
        next_pad = len(touched)
        for op in ops:
            pads = list(range(next_pad, next_pad + (k_max - op.k)))
            next_pad += len(pads)
            addr_rows.append([index[a] for a in op.addrs] + pads)
            des_rows.append([enc[int(t.desired)] for t in op.targets]
                            + [0] * len(pads))
        # quantize the word count to a power of two so the jitted engine
        # step sees a bounded family of shapes across batches
        n_sim = max(k_max, len(touched) + n_pads)
        n_sim = 1 << (n_sim - 1).bit_length() if n_sim > 1 else 1
        cfg = SimConfig(algorithm=self.algorithm.name, n_threads=B,
                        n_words=n_sim, k=k_max, max_ops=1, n_steps=1)
        ops_arr = np.asarray(addr_rows, np.int32).reshape(B, 1, k_max)
        des_arr = np.asarray(des_rows, np.uint32).reshape(B, 1, k_max)
        st = init_state(cfg, ops_arr, ops_des=des_arr)
        mem = np.zeros(n_sim, np.uint32)
        mem[:len(touched)] = [enc[int(self._values[a])] for a in touched]
        word = mem << TAG_SHIFT
        st = dict(st)
        st["cache"] = jnp.asarray(word)
        st["pmem"] = jnp.asarray(word)

        step = _compiled_step(cfg)
        from repro.core.model import CNT_FAILS

        def _pc(t):
            return int(np.asarray(st["pc"])[t])

        # phase 1: every thread reads its targets (pre-batch expecteds)
        read_pcs = ({PC.P_READ} if self.algorithm.name == ALG_PCAS
                    else {PC.READ_TGT, PC.READ_WAIT})
        for t in range(B):
            n = 0
            while _pc(t) in read_pcs:
                st = step(st, jnp.int32(t))
                n += 1
                if n > self.attempt_cap:
                    raise RuntimeError("read phase did not converge")
        # phase 2: attempts run to their op boundary in index order
        for t in range(B):
            n = 0
            while (int(np.asarray(st["op_idx"])[t]) < 1 and
                   int(np.asarray(st["counters"])[t, CNT_FAILS]) < 1):
                st = step(st, jnp.int32(t))
                n += 1
                if n > self.attempt_cap:
                    raise RuntimeError(f"attempt of op {t} did not converge")

        success = np.asarray(st["op_idx"]) == 1
        cache = np.asarray(st["cache"])
        tags = cache & int(TAG_MASK)
        assert (tags == 0).all(), "batch left non-payload tags in cache"
        ids = (cache >> TAG_SHIFT).astype(np.int64)
        for a, i in index.items():          # decode ids back to real values
            self._values[a] = dec[ids[i]]
        self.counters = np.asarray(st["counters"])
        return results_from_mask(ops, success, self.name)

    def read(self, addr: Addr) -> int:
        if not isinstance(addr, int):
            raise TypeError(f"sim backend uses int addresses, got {addr!r}")
        return int(self._values[addr])

    def values(self) -> np.ndarray:
        return self._values.copy()


# ===========================================================================
# Durable backend
# ===========================================================================

class DurableBackend:
    """Descriptor-WAL committer as a PMwCAS backend (values = slot versions).

    Every successful op is a real :class:`repro.checkpoint.Committer`
    commit — persisted WAL record, durability linearization point,
    finalize — so a crash at any point recovers to a batch prefix.  The
    one-shot verdict logic (condition (b) above) runs on a pre-batch
    snapshot of slot versions, mirroring the kernel's conservative
    semantics exactly.

    With ``group_commit=True`` (the default; requires the WAL
    committer) a whole batch commits through
    :meth:`repro.checkpoint.Committer.commit_round`: one coalesced WAL
    record and ONE persist fence per round instead of the per-op
    3k+2-flush protocol.  Crash windows collapse to a single question —
    was the round record durable?  (yes → recovery redoes the round; no
    → the round never happened.)  ``durability_stats`` exposes the
    flushes issued/saved and fence counts.

    With ``epoch_rounds > 1`` (requires group commit) rounds buffer into
    a durability epoch sharing ONE fence (DESIGN.md Sec. 14): a round's
    verdict is final at :meth:`execute` return but durable only at the
    next epoch close — :meth:`sync` is the explicit barrier, and a crash
    loses at most ``epoch_rounds - 1`` committed rounds, never a torn
    one.  ``checkpoint_every = N`` persists a checkpoint image every N
    epoch closes so recovery replay stays bounded; :attr:`epoch_pending`
    exposes the open window.
    """
    name = "durable"

    def __init__(self, root: Union[str, pathlib.Path, None] = None, *,
                 pool: Optional[PMemPool] = None,
                 committer: Union[str, type] = "wal",
                 group_commit: bool = True, epoch_rounds: int = 1,
                 checkpoint_every: int = 0):
        self._tmpdir = None
        if pool is None:
            if root is None:
                # auto-cleaned on GC/interpreter exit (no /tmp litter)
                self._tmpdir = tempfile.TemporaryDirectory(
                    prefix="pmwcas_durable_")
                root = self._tmpdir.name
            pool = PMemPool(root)
        self.pool = pool
        if committer in ("wal", Committer):
            self._committer_cls = Committer
        elif committer in ("marker", MarkerCommitter):
            self._committer_cls = MarkerCommitter
        else:
            raise ValueError(f"unknown committer {committer!r}")
        self.committer = self._committer_cls(
            pool, epoch_rounds=epoch_rounds,
            checkpoint_every=checkpoint_every)
        self.group_commit = bool(group_commit) and getattr(
            self._committer_cls, "supports_rounds", False)
        if int(epoch_rounds) > 1 and not self.group_commit:
            raise ValueError("epoch_rounds > 1 requires group commit "
                             "(epochs buffer coalesced round records)")
        self.epoch_rounds = max(1, int(epoch_rounds))
        self.checkpoint_every = max(0, int(checkpoint_every))
        self._seq = 0

    # -- setup -----------------------------------------------------------------
    def seed(self, values: Mapping[Addr, int],
             payload_for=None) -> None:
        """Initialize slot versions (and their data files) directly."""
        payload_for = payload_for or self._default_payload
        for addr, ver in values.items():
            name = addr if isinstance(addr, str) else f"w{addr}"
            self.pool.write_record(_slot_rel(name), {"version": int(ver)})
            if ver:
                self.pool.write_persist(data_rel(name, int(ver)),
                                        payload_for(name, int(ver)))

    @staticmethod
    def _default_payload(name: str, version: int) -> bytes:
        return f"{name}:v{version}".encode()

    # -- Backend protocol ------------------------------------------------------
    def execute(self, ops: Sequence[MwCASOp],
                payloads: Optional[Mapping[str, bytes]] = None
                ) -> List[OpResult]:
        with span("mwcas.round", backend=self.name, ops=len(ops)):
            return self._execute(ops, payloads)

    def _execute(self, ops: Sequence[MwCASOp],
                 payloads: Optional[Mapping[str, bytes]] = None
                 ) -> List[OpResult]:
        names = {t.slot_name for op in ops for t in op.targets}
        snapshot = {n: self.committer.slot_version(n) for n in names}
        claimed: set = set()
        verdicts: List[bool] = []
        to_commit: List[Tuple[int, Descriptor]] = []
        pls: Dict[str, bytes] = {}
        for i, op in enumerate(ops):
            op_names = [t.slot_name for t in op.targets]
            passes = all(snapshot[n] == t.expected
                         for n, t in zip(op_names, op.targets))
            blocked = passes and any(n in claimed for n in op_names)
            if passes:
                claimed.update(op_names)
            ok = passes and not blocked
            if ok:
                # guard words (desired == expected) participate in the
                # verdict above but are trivially satisfied — the committer
                # only moves targets whose version actually advances
                moving = [t for t in op.targets if t.desired != t.expected]
                if moving:
                    to_commit.append((i, Descriptor(
                        op_id=f"mwcas-{self._seq}-{i}", op=MwCASOp(moving))))
                    pls.update({t.slot_name: (payloads or {}).get(
                        t.slot_name,
                        self._default_payload(t.slot_name, t.desired))
                        for t in moving})
            verdicts.append(ok)
        if to_commit:
            if self.group_commit:
                # one coalesced WAL record, one persist fence per round
                round_ok = self.committer.commit_round(
                    [(desc.op_id, desc.slot_targets())
                     for _i, desc in to_commit], pls)
                for (i, _desc), ok in zip(to_commit, round_ok):
                    verdicts[i] = ok
            else:
                for i, desc in to_commit:
                    op_pls = {n: pls[n] for n, _e, _d in desc.slot_targets()}
                    verdicts[i] = self.committer.commit(
                        desc.op_id, desc.slot_targets(), op_pls)
        results = [OpResult(index=i, success=ok, backend=self.name, op=op)
                   for i, (op, ok) in enumerate(zip(ops, verdicts))]
        self._seq += 1
        return results

    def read(self, addr: Addr) -> int:
        name = addr if isinstance(addr, str) else f"w{addr}"
        return self.committer.slot_version(name)

    # -- durability surface ----------------------------------------------------
    @property
    def durability_stats(self) -> DurabilityStats:
        """Flush/fence accounting of the underlying committer."""
        return self.committer.stats

    def recover(self) -> Dict[str, int]:
        return self.committer.recover()

    def sync(self) -> int:
        """Close the open durability epoch (one fence); returns rounds
        made durable.  No-op outside epoch mode."""
        return self.committer.sync()

    def checkpoint(self) -> int:
        """Persist a checkpoint image and durably drop the round/epoch
        records it covers (closes the open epoch first).  No-op for the
        marker baseline (its commits are durable per slot already)."""
        ckpt = getattr(self.committer, "checkpoint", None)
        return ckpt() if ckpt is not None else 0

    @property
    def epoch_pending(self) -> int:
        """Rounds committed-but-unfenced in the open epoch."""
        return getattr(self.committer, "epoch_pending", 0)

    def prune_completed(self) -> int:
        """WAL hygiene: durably drop spent descriptor records (every op
        writes one; without pruning ``wal/`` grows without bound).  Safe
        at any point — recovery never consults an unreferenced record —
        and the structure crash sweeps assert exactly that in their
        teardown."""
        return self.committer.prune_completed()

    def crash(self) -> "DurableBackend":
        """Simulate a crash: drop unpersisted writes, reopen, recover.

        The durability ledger survives the crash: the new backend's
        committer keeps accumulating into THIS backend's
        ``DurabilityStats`` object, so flush/fence counters are monotone
        across crash/recover cycles (a crash must never zero — or
        double-count — the measurement window)."""
        with span("backend.crash_recover", backend=self.name):
            new = DurableBackend(pool=self.pool.crash(),
                                 committer=self._committer_cls,
                                 group_commit=self.group_commit,
                                 epoch_rounds=self.epoch_rounds,
                                 checkpoint_every=self.checkpoint_every)
            new.committer.stats = self.committer.stats
            new.recover()
        return new


# ===========================================================================
# Backend factory hooks (the sharded service builds per-shard backends
# through this registry, so deployments can plug in their own substrate)
# ===========================================================================

def _make_sim(n_words: Optional[int] = None, **kw) -> SimBackend:
    if n_words is None:
        raise ValueError("sim backend needs n_words")
    return SimBackend(n_words, **kw)


def _make_kernel(n_words: Optional[int] = None, **kw) -> KernelBackend:
    return KernelBackend(n_words=n_words, **kw)


def _make_durable(n_words: Optional[int] = None, **kw) -> DurableBackend:
    # the durable word space is the (unbounded) slot-name namespace, so
    # n_words is accepted-and-ignored for factory-signature uniformity
    return DurableBackend(**kw)


BACKEND_FACTORIES: Dict[str, Callable[..., Backend]] = {
    "sim": _make_sim,
    "kernel": _make_kernel,
    "durable": _make_durable,
}


def register_backend(name: str, factory: Callable[..., Backend],
                     replace: bool = False) -> None:
    """Register a custom backend factory under ``name`` (usable anywhere
    a backend kind string is accepted, e.g. ``KVService(backend=name)``).
    The factory must accept ``n_words`` as a keyword (ignore it if the
    substrate is not array-shaped)."""
    if name in BACKEND_FACTORIES and not replace:
        raise ValueError(f"backend kind {name!r} already registered")
    BACKEND_FACTORIES[name] = factory


def make_backend(spec: Union[str, Callable[..., Backend], Backend],
                 **kw) -> Backend:
    """Resolve a backend spec into an instance.

    ``spec`` may be a registered kind name (``"sim"`` / ``"kernel"`` /
    ``"durable"`` / anything added via :func:`register_backend`), a
    callable factory (called with the keyword arguments), or an existing
    :class:`Backend` instance (returned as-is; passing construction
    kwargs alongside an instance is an error).
    """
    if isinstance(spec, str):
        try:
            factory = BACKEND_FACTORIES[spec]
        except KeyError:
            raise ValueError(
                f"unknown backend kind {spec!r}; registered: "
                f"{sorted(BACKEND_FACTORIES)}") from None
        return factory(**kw)
    # classes pass the runtime Protocol check (their *attributes* exist on
    # the class object), so treat any type as a factory first
    if not isinstance(spec, type) and isinstance(spec, Backend):
        if kw:
            raise ValueError(
                f"cannot apply kwargs {sorted(kw)} to an existing "
                "backend instance")
        return spec
    if callable(spec):
        return spec(**kw)
    raise TypeError(f"backend spec {spec!r} is not a kind name, factory "
                    "or Backend")

"""Deterministic, sharded, checkpointable synthetic token pipeline.

A stateless function of (seed, step, host) — so the "iterator state" that
must be committed atomically with params/opt is just {seed, step}.  The
stream is a mixture of Zipf-distributed tokens with Markov structure so
cross-entropy is learnable (examples/train_lm.py drives loss well below
the uniform bound)."""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    zipf_alpha: float = 1.1


class SyntheticStream:
    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step
        c = cfg
        ranks = np.arange(1, c.vocab + 1, dtype=np.float64)
        p = ranks ** (-c.zipf_alpha)
        self._p = p / p.sum()
        # fixed "grammar": each token deterministically prefers a successor
        g = np.random.default_rng(c.seed ^ 0xBADC0DE)
        self._succ = g.integers(0, c.vocab, size=c.vocab)

    @property
    def local_batch(self) -> int:
        assert self.cfg.global_batch % self.cfg.n_hosts == 0
        return self.cfg.global_batch // self.cfg.n_hosts

    def state(self) -> Dict[str, int]:
        return {"seed": self.cfg.seed, "step": self.step}

    @classmethod
    def from_state(cls, cfg: DataConfig, state) -> "SyntheticStream":
        return cls(dataclasses.replace(cfg, seed=int(state["seed"])),
                   step=int(state["step"]))

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, self.step, c.host_id))
        B, S = self.local_batch, c.seq_len
        toks = rng.choice(c.vocab, size=(B, S), p=self._p)
        # 75% of positions follow the grammar: predictable successor
        follow = rng.random((B, S - 1)) < 0.75
        nxt = self._succ[toks[:, :-1]]
        toks[:, 1:] = np.where(follow, nxt, toks[:, 1:])
        batch = {
            "tokens": toks.astype(np.int32),
            "labels": np.concatenate(
                [toks[:, 1:], toks[:, :1]], axis=1).astype(np.int32),
        }
        self.step += 1
        return batch

"""CheckpointManager: atomic, elastic, optionally-async training-state
checkpoints built on the descriptor-WAL committer.

The "multi-word" set committed atomically per step is
  {params shards} U {opt shards} U {data-iterator state} U {rng} U {meta}
— a crash between any two of them can never produce a torn checkpoint
(the linked-list/payload problem of the paper's Fig. 1, at cluster scale).

Shards: every host commits its own slots; slots are named
``<group>.h<host>of<nhosts>``.  Elastic restore re-concatenates and
re-splits when the host count changes.
"""
from __future__ import annotations

import io
import json
import pickle
import queue
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from .committer import Committer, data_rel
from .pmem import PMemPool


def _pack(tree) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, *[np.asarray(x) for x in leaves])
    return pickle.dumps({"treedef": pickle.dumps(treedef),
                         "npz": buf.getvalue()})


def _unpack(data: bytes):
    obj = pickle.loads(data)
    treedef = pickle.loads(obj["treedef"])
    npz = np.load(io.BytesIO(obj["npz"]))
    leaves = [npz[k] for k in npz.files]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _split_tree(tree, n: int) -> List[Any]:
    """Split every leaf along axis 0 into n host shards (pad-free split of
    the leading dim when divisible; otherwise shard 0 holds the leaf)."""
    def split(leaf):
        leaf = np.asarray(leaf)
        if leaf.ndim >= 1 and leaf.shape[0] % n == 0:
            return np.split(leaf, n, axis=0)
        return [leaf] + [np.zeros((0,) + leaf.shape[1:], leaf.dtype)] * (n - 1)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    per_host = [[] for _ in range(n)]
    for leaf in leaves:
        for h, part in enumerate(split(leaf)):
            per_host[h].append(part)
    return [jax.tree_util.tree_unflatten(treedef, parts)
            for parts in per_host]


def _merge_trees(shards: List[Any]):
    def merge(*parts):
        parts = [np.asarray(p) for p in parts if np.asarray(p).size or
                 np.asarray(p).ndim == 0]
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts, axis=0)

    return jax.tree_util.tree_map(merge, *shards)


class CheckpointManager:
    def __init__(self, directory, n_hosts: int = 1, keep: int = 3,
                 pool: Optional[PMemPool] = None):
        self.pool = pool or PMemPool(directory)
        self.committer = Committer(self.pool)
        self.n_hosts = n_hosts
        self.keep = keep

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any]) -> bool:
        """Atomically commit all groups of `state` (one slot per group x
        host) as checkpoint `step`."""
        payloads: Dict[str, bytes] = {}
        targets: List[Tuple[str, int, int]] = []
        for group, tree in state.items():
            shards = _split_tree(tree, self.n_hosts)
            for h, shard in enumerate(shards):
                name = f"{group}.h{h}of{self.n_hosts}"
                payloads[name] = _pack(shard)
                targets.append((name, self.committer.slot_version(name),
                                step))
        meta = {"step": step, "groups": sorted(state),
                "n_hosts": self.n_hosts}
        name = "meta"
        payloads[name] = json.dumps(meta).encode()
        targets.append((name, self.committer.slot_version(name), step))
        return self.committer.commit(f"ckpt-{step}", targets, payloads)

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        self.committer.recover()
        v = self.committer.slot_version("meta")
        return v or None

    def restore(self, n_hosts: Optional[int] = None
                ) -> Optional[Tuple[int, Dict[str, Any]]]:
        """Recover + load the newest committed checkpoint, resharding to
        `n_hosts` if the cluster size changed (elastic restart)."""
        step = self.latest_step()
        if not step:
            return None
        meta = json.loads(self.pool.read(data_rel("meta", step)))
        saved_hosts = meta["n_hosts"]
        state = {}
        for group in meta["groups"]:
            shards = []
            for h in range(saved_hosts):
                name = f"{group}.h{h}of{saved_hosts}"
                ver = self.committer.slot_version(name)
                shards.append(_unpack(self.pool.read(data_rel(name, ver))))
            state[group] = _merge_trees(shards)
        return step, state


class AsyncCheckpointManager(CheckpointManager):
    """Double-buffered background checkpointing: `save_async` snapshots to
    host memory synchronously (cheap) and commits on a worker thread,
    overlapping the fsync-heavy commit with subsequent training steps."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._results: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                ok = self.save(step, state)
                self._results.put((step, ok, None))
            except Exception as e:  # noqa: BLE001
                self._results.put((step, False, e))

    def save_async(self, step: int, state: Dict[str, Any]):
        snap = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), state)
        self._q.put((step, snap))  # blocks if previous commit still running

    def wait(self):
        self._q.join() if False else None
        results = []
        while not self._results.empty():
            results.append(self._results.get())
        return results

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=30)

from .committer import Committer, DurabilityStats, data_rel
from .manager import AsyncCheckpointManager, CheckpointManager
from .marker_committer import MarkerCommitter
from .pmem import PMemPool, SimulatedCrash

__all__ = ["Committer", "DurabilityStats", "MarkerCommitter",
           "CheckpointManager", "AsyncCheckpointManager", "PMemPool",
           "SimulatedCrash", "data_rel"]

"""A directory-backed "persistent memory" with explicit persist boundaries.

Maps the paper's memory model onto files: a *write* is visible (page cache =
"CPU cache") but not durable until *persist* (fsync = "clflush + sfence").
Atomic pointer flips use rename, the filesystem's CAS-like primitive.

Crash injection: constructing the pool with ``crash_after_persists=N``
raises SimulatedCrash on the N-th persist — tests sweep N across the whole
commit protocol, mirroring the simulator's crash sweeps.  A "crash" is then
modeled by REOPENING the directory fresh (page cache dropped is simulated
by the fact that recovery only trusts what was fsynced — we additionally
delete files written-but-not-persisted to emulate lost cache lines).
"""
from __future__ import annotations

import json
import os
import pathlib
import time
import zlib
from typing import Dict, Optional

from ..obs import record_fence, span


class SimulatedCrash(Exception):
    pass


class PMemPool:
    def __init__(self, root, crash_after_persists: Optional[int] = None):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.crash_after = crash_after_persists
        self.persist_count = 0
        self.write_count = 0
        # wall-clock spent inside persist fences (fsync), for the
        # per-op persist_us attribution in the service stats
        self.persist_ns = 0
        # files written but not yet persisted ("dirty cache lines"), mapped
        # to their last DURABLE content (None = never existed durably) so a
        # crash can restore what the medium actually held
        self._unpersisted: Dict[pathlib.Path, Optional[bytes]] = {}

    # -- primitive ops --------------------------------------------------------
    def write(self, rel: str, data: bytes) -> pathlib.Path:
        """Visible but not durable (like a store into CPU cache)."""
        path = self.root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        if path not in self._unpersisted:
            durable = path.read_bytes() if path.exists() else None
            self._unpersisted[path] = durable
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic visibility
        self.write_count += 1
        return path

    def persist(self, rel: str):
        """Durability barrier for one file (clflush analogue)."""
        path = self.root / rel
        self.persist_count += 1
        if self.crash_after is not None and \
                self.persist_count > self.crash_after:
            raise SimulatedCrash(f"crash before persisting {rel}")
        # the line is clean (nothing unpersisted under it) => this fence
        # changes no durable state; the provenance ledger flags it as
        # redundant — the instruction class the paper removes
        redundant = path not in self._unpersisted
        t0 = time.perf_counter_ns()
        with span("pmem.persist", rel=rel):
            with open(path, "rb") as f:
                os.fsync(f.fileno())
        self.persist_ns += time.perf_counter_ns() - t0
        record_fence(redundant=redundant)
        self._unpersisted.pop(path, None)

    def write_persist(self, rel: str, data: bytes):
        self.write(rel, data)
        self.persist(rel)

    def read(self, rel: str) -> bytes:
        with open(self.root / rel, "rb") as f:
            return f.read()

    def exists(self, rel: str) -> bool:
        return (self.root / rel).exists()

    def delete(self, rel: str):
        p = self.root / rel
        if p not in self._unpersisted:
            self._unpersisted[p] = p.read_bytes() if p.exists() else None
        if p.exists():
            p.unlink()

    def delete_persist(self, rel: str):
        """Durable unlink (the directory-fsync analogue): unlike
        :meth:`delete`, the file does NOT come back after a crash.
        Counts as one persist, so crash injection covers it too."""
        p = self.root / rel
        self.persist_count += 1
        if self.crash_after is not None and \
                self.persist_count > self.crash_after:
            raise SimulatedCrash(f"crash before durably deleting {rel}")
        # redundant iff there was durably nothing to delete and no
        # visible-but-dirty file to discard
        redundant = p not in self._unpersisted and not p.exists()
        t0 = time.perf_counter_ns()
        with span("pmem.persist", rel=rel, delete=True):
            if p.exists():
                p.unlink()
        self.persist_ns += time.perf_counter_ns() - t0
        record_fence(redundant=redundant)
        self._unpersisted.pop(p, None)

    def listdir(self, rel: str):
        d = self.root / rel
        if not d.exists():
            return []
        return sorted(x.name for x in d.iterdir())

    # -- cache-line introspection (epoch tests assert the bounded-loss
    # window directly against what is dirty) --------------------------------
    def is_dirty(self, rel: str) -> bool:
        """True if the file is visible but not durable (a crash now
        would revert it to its last persisted content)."""
        return (self.root / rel) in self._unpersisted

    @property
    def dirty_lines(self) -> int:
        """Files currently written-but-unpersisted."""
        return len(self._unpersisted)

    # -- crash model -----------------------------------------------------------
    def crash(self) -> "PMemPool":
        """Revert every file to its last durable content and reopen."""
        for p, durable in self._unpersisted.items():
            if durable is None:
                if p.exists():
                    p.unlink()
            else:
                p.write_bytes(durable)
        return PMemPool(self.root)

    # -- checksummed JSON records ----------------------------------------------
    def write_record(self, rel: str, obj: Dict, persist: bool = True):
        body = json.dumps(obj, sort_keys=True).encode()
        crc = zlib.crc32(body) & 0xFFFFFFFF
        data = json.dumps({"crc": crc,
                           "body": obj}, sort_keys=True).encode()
        if persist:
            self.write_persist(rel, data)
        else:
            self.write(rel, data)

    def read_record(self, rel: str) -> Optional[Dict]:
        try:
            raw = json.loads(self.read(rel))
        except (FileNotFoundError, json.JSONDecodeError):
            return None
        body = raw.get("body")
        crc = zlib.crc32(json.dumps(body, sort_keys=True).encode()) \
            & 0xFFFFFFFF
        if crc != raw.get("crc"):
            return None  # torn write: treat as absent (never persisted)
        return body

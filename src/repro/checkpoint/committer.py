"""Atomic multi-slot checkpoint commit — the paper's PMwCAS without dirty
flags, at file granularity (DESIGN.md Sec. 2.3).

Slots are named pointers (slots/<name> -> data version); a commit atomically
moves a SET of slots from their expected versions to desired versions.  The
protocol is Fig. 4 minus lines 20-22:

  1. prepare: write + persist the desired data files (out-of-place)
  2. WAL:     persist descriptor {state: FAILED, targets: [(slot, exp, des)]}
  3. reserve: flip each slot pointer to reference the descriptor, persist
  4. commit:  persist descriptor state = SUCCEEDED   <- linearization point
  5. finalize: write each slot pointer = desired version, persist
  6. done:    descriptor state = COMPLETED (lazy persist), GC old data

There are NO per-slot commit markers (the dirty-flag analogue; the
baseline committer in marker_committer.py has them for the benchmark).
Recovery reads only descriptors + slot pointers and rolls forward/back.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

from .pmem import PMemPool

ST_COMPLETED, ST_FAILED, ST_SUCCEEDED = "COMPLETED", "FAILED", "SUCCEEDED"


def _slot_rel(name: str) -> str:
    return f"slots/{name}.json"


def _desc_rel(cid: str) -> str:
    return f"wal/{cid}.json"


def data_rel(name: str, version: int) -> str:
    return f"data/{name}.v{version}.bin"


class CommitError(Exception):
    pass


class Committer:
    """The paper's algorithm (no dirty flags)."""

    def __init__(self, pool: PMemPool):
        self.pool = pool

    # -- reads -----------------------------------------------------------------
    def slot_version(self, name: str) -> int:
        """Read procedure (Fig. 5): resolve through in-flight descriptors."""
        rec = self.pool.read_record(_slot_rel(name))
        if rec is None:
            return 0
        if "desc" in rec:
            desc = self.pool.read_record(_desc_rel(rec["desc"]))
            if desc is None:    # descriptor never persisted -> roll back
                return rec["expected"]
            t = {s: (e, d) for s, e, d in desc["targets"]}
            exp, des = t[name]
            return des if desc["state"] == ST_SUCCEEDED else exp
        return rec["version"]

    # -- commit ------------------------------------------------------------------
    def commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
               payloads: Dict[str, bytes]) -> bool:
        """Atomically move every (slot, expected, desired); all-or-nothing.

        payloads: desired data per slot (written out-of-place first).
        """
        pool = self.pool
        # 0. versions must advance.  An exp == des "no-op move" would pass
        # every check and then GC its own live data file in step 6
        # (delete of data_rel(name, exp) == data_rel(name, des)).
        for _name, exp, des in targets:
            if des == exp:
                return False
        # 1. prepare desired values (out-of-place).  A desired version that
        # collides with the slot's LIVE version (stale exp) must not
        # clobber its data: refuse before writing anything.  The exists()
        # stat keeps the common path (fresh desired versions) to one cheap
        # check per target.
        for name, _exp, des in targets:
            if pool.exists(data_rel(name, des)) and \
                    des == self.slot_version(name) and \
                    pool.read(data_rel(name, des)) != payloads[name]:
                return False
        for name, _exp, des in targets:
            pool.write_persist(data_rel(name, des), payloads[name])
        # 2. the descriptor IS the write-ahead log
        desc = {"id": cid, "state": ST_FAILED,
                "targets": [list(t) for t in targets],
                "ts": time.time()}
        pool.write_record(_desc_rel(cid), desc)
        # 3. reserve every slot (embed the descriptor address)
        success = True
        reserved: List[str] = []
        for name, exp, _des in targets:
            cur = self.pool.read_record(_slot_rel(name))
            cur_ver = 0 if cur is None else cur.get("version")
            if cur is not None and "desc" in cur:
                # another in-flight commit: resolve it first (help/wait)
                cur_ver = self.slot_version(name)
            if cur_ver != exp:
                success = False
                break
            pool.write_record(_slot_rel(name),
                              {"desc": cid, "expected": exp})
            reserved.append(name)
        if success:
            # 4. durability linearization point
            desc["state"] = ST_SUCCEEDED
            pool.write_record(_desc_rel(cid), desc)
        # 5. finalize (commit or roll back the reserved prefix)
        t = {s: (e, d) for s, e, d in targets}
        for name in reserved:
            exp, des = t[name]
            ver = des if success else exp
            pool.write_record(_slot_rel(name), {"version": ver})
        # 6. completed (lazy persist is safe: recovery replays idempotently)
        desc["state"] = ST_COMPLETED if success else desc["state"]
        pool.write_record(_desc_rel(cid), desc, persist=False)
        if success:
            for name, exp, _des in targets:
                if exp:
                    pool.delete(data_rel(name, exp))  # GC old version
        else:
            # GC the desired data files written in step 1: the rolled-back
            # slots never reference them, and leaving them would leak
            # orphaned data/*.bin until the next recover()
            for name, _exp, des in targets:
                if des != self.slot_version(name):
                    pool.delete(data_rel(name, des))
        return success

    # -- WAL hygiene --------------------------------------------------------------
    def prune_completed(self) -> int:
        """Remove spent descriptor records from ``wal/``; returns how
        many were pruned.

        Every structure op writes one descriptor, so without pruning the
        WAL grows without bound (ROADMAP: recovery-time GC).  A record is
        *spent* — and safe to drop durably — once no target slot still
        references it: COMPLETED records (the common case, finalize done)
        and FAILED/SUCCEEDED residue that recovery already rolled
        forward/back.  Recovery only ever consults a descriptor through a
        slot's ``desc`` reference, so an unreferenced record cannot
        influence any future recover().
        """
        pool = self.pool
        pruned = 0
        for fn in pool.listdir("wal"):
            rel = f"wal/{fn}"
            desc = pool.read_record(rel)
            if desc is not None:
                referenced = False
                for name, _exp, _des in desc["targets"]:
                    rec = pool.read_record(_slot_rel(name))
                    if rec is not None and rec.get("desc") == desc["id"]:
                        referenced = True
                        break
                if referenced:
                    continue                 # still in-flight: keep
            pool.delete_persist(rel)         # torn/spent: durably drop
            pruned += 1
        return pruned

    # -- recovery -----------------------------------------------------------------
    def recover(self) -> Dict[str, int]:
        """Roll every slot forward/back from the persisted descriptors.
        Idempotent; returns the recovered slot->version map."""
        pool = self.pool
        for fn in pool.listdir("wal"):
            desc = pool.read_record(f"wal/{fn}")
            if desc is None:
                pool.delete(f"wal/{fn}")   # torn/unpersisted WAL record
                continue
            t = {s: (e, d) for s, e, d in desc["targets"]}
            for name, (exp, des) in t.items():
                rec = pool.read_record(_slot_rel(name))
                if rec is not None and rec.get("desc") == desc["id"]:
                    ver = des if desc["state"] == ST_SUCCEEDED else exp
                    pool.write_record(_slot_rel(name), {"version": ver})
            if desc["state"] != ST_COMPLETED:
                desc["state"] = ST_COMPLETED if \
                    desc["state"] == ST_SUCCEEDED else desc["state"]
        # drop data files no slot references (uncommitted desired versions)
        live = set()
        for fn in pool.listdir("slots"):
            name = fn[:-len(".json")]
            live.add(data_rel(name, self.slot_version(name)))
        for fn in pool.listdir("data"):
            if f"data/{fn}" not in live:
                pool.delete(f"data/{fn}")
        return {fn[:-len('.json')]: self.slot_version(fn[:-len('.json')])
                for fn in pool.listdir("slots")}

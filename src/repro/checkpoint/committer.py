"""Atomic multi-slot checkpoint commit — the paper's PMwCAS without dirty
flags, at file granularity (DESIGN.md Sec. 2.3).

Slots are named pointers (slots/<name> -> data version); a commit atomically
moves a SET of slots from their expected versions to desired versions.  The
per-op protocol is Fig. 4 *with* the original algorithm's conservative
read barrier (the flush lines 20-22 exist to back) — it is the measured
baseline that :meth:`Committer.commit_round` optimizes away:

  1. prepare: write + persist the desired data files (out-of-place)
  2. WAL:     persist descriptor {state: FAILED, targets: [(slot, exp, des)]}
  2b. read barrier: fence each existing slot line before trusting its read
      (almost always already clean — the provenance ledger flags each of
      these ``redundant_fences``; group commit never pays them)
  3. reserve: flip each slot pointer to reference the descriptor, persist
  4. commit:  persist descriptor state = SUCCEEDED   <- linearization point
  5. finalize: write each slot pointer = desired version, persist
  6. done:    descriptor state = COMPLETED (lazy persist), GC old data

There are NO per-slot commit markers (the dirty-flag analogue; the
baseline committer in marker_committer.py has them for the benchmark).
Recovery reads only descriptors + slot pointers and rolls forward/back.

Round-level group commit (DESIGN.md Sec. 9): :meth:`Committer.commit_round`
coalesces a whole conflict-free batch round into ONE WAL record — the
record embeds every op's targets AND payloads, so its single persist is
the round's only durability fence.  Data files and slot pointers are
written visibly but flushed lazily; recovery replays round records (in
commit order) exactly like per-op descriptors, rebuilding anything the
crash dropped from the record itself.  Descriptors-as-WAL is unchanged —
only flush *placement* moves, from per-op to per-round.
"""
from __future__ import annotations

import base64
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..obs import flush_reason, get_registry, span
from .pmem import PMemPool

ST_COMPLETED, ST_FAILED, ST_SUCCEEDED = "COMPLETED", "FAILED", "SUCCEEDED"

_ROUND_PREFIX = "round-"


def _slot_rel(name: str) -> str:
    return f"slots/{name}.json"


def _desc_rel(cid: str) -> str:
    return f"wal/{cid}.json"


def data_rel(name: str, version: int) -> str:
    return f"data/{name}.v{version}.bin"


class CommitError(Exception):
    pass


@dataclasses.dataclass
class DurabilityStats:
    """Flush accounting for the commit paths (the paper's fewer-flushes
    lever, measured): how many persists were actually issued, how many
    the per-op protocol would have issued for the same commits, and how
    many commit fences (round-record persists) were paid."""
    flushes_issued: int = 0    # persists actually issued by commit paths
    flushes_saved: int = 0     # per-op-protocol persists coalesced away
    fences: int = 0            # round-record commit fences
    round_commits: int = 0     # commit_round calls that committed >= 1 op
    op_commits: int = 0        # per-op commit() calls
    ops_committed: int = 0     # ops that reached their linearization point

    def merge(self, other: "DurabilityStats") -> "DurabilityStats":
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self

    def as_row(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @property
    def flushes_per_commit(self) -> float:
        return (self.flushes_issued / self.ops_committed
                if self.ops_committed else 0.0)


def _b64(data: bytes) -> str:
    return base64.b64encode(data).decode("ascii")


def _unb64(text: str) -> bytes:
    return base64.b64decode(text.encode("ascii"))


def _per_op_flush_cost(targets: Sequence[Tuple[str, int, int]]) -> int:
    """Persists the per-op protocol pays for one committed op: k data
    prepares + 1 WAL + k reserves + 1 SUCCEEDED + k finalizes."""
    return 3 * len(targets) + 2


def _account(stats: DurabilityStats, **deltas: int) -> None:
    """Apply deltas to BOTH the dataclass and the global registry with
    the same integers — the two ledgers can never drift, which is what
    lets the durable benchmark assert exact equality between them.
    Registry series carry ``component="committer"`` so live commit
    accounting never collides with the adapter snapshot folds."""
    registry = get_registry()
    for name, delta in deltas.items():
        setattr(stats, name, getattr(stats, name) + delta)
        if delta:
            registry.counter(name, component="committer").inc(delta)


class Committer:
    """The paper's algorithm (no dirty flags)."""

    # round-level group commit is a protocol property of THIS committer;
    # the marker baseline keeps its per-slot dirty flags and opts out
    supports_rounds = True

    def __init__(self, pool: PMemPool):
        self.pool = pool
        self.stats = DurabilityStats()
        self._round_seq: Optional[int] = None   # lazily scanned from wal/

    # -- reads -----------------------------------------------------------------
    def slot_version(self, name: str) -> int:
        """Read procedure (Fig. 5): resolve through in-flight descriptors."""
        rec = self.pool.read_record(_slot_rel(name))
        if rec is None:
            return 0
        if "desc" in rec:
            desc = self.pool.read_record(_desc_rel(rec["desc"]))
            if desc is None:    # descriptor never persisted -> roll back
                return rec["expected"]
            t = {s: (e, d) for s, e, d in desc["targets"]}
            exp, des = t[name]
            return des if desc["state"] == ST_SUCCEEDED else exp
        return rec["version"]

    # -- commit ------------------------------------------------------------------
    def commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
               payloads: Dict[str, bytes]) -> bool:
        """Atomically move every (slot, expected, desired); all-or-nothing.

        payloads: desired data per slot (written out-of-place first).
        """
        pool = self.pool
        p0 = pool.persist_count
        with span("wal.commit", slots=len(targets)) as sp:
            try:
                ok = self._commit(cid, targets, payloads)
            finally:
                _account(self.stats, op_commits=1,
                         flushes_issued=pool.persist_count - p0)
            if ok:
                _account(self.stats, ops_committed=1)
            sp.set(ok=ok, flushes=pool.persist_count - p0)
        return ok

    def _commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
                payloads: Dict[str, bytes]) -> bool:
        pool = self.pool
        # 0. versions must advance.  An exp == des "no-op move" would pass
        # every check and then GC its own live data file in step 6
        # (delete of data_rel(name, exp) == data_rel(name, des)).
        for _name, exp, des in targets:
            if des == exp:
                return False
        # 1. prepare desired values (out-of-place).  A desired version that
        # collides with the slot's LIVE version (stale exp) must not
        # clobber its data: refuse before writing anything.  The exists()
        # stat keeps the common path (fresh desired versions) to one cheap
        # check per target.
        for name, _exp, des in targets:
            if pool.exists(data_rel(name, des)) and \
                    des == self.slot_version(name) and \
                    pool.read(data_rel(name, des)) != payloads[name]:
                return False
        with flush_reason("committer", "data_prepare"):
            for name, _exp, des in targets:
                pool.write_persist(data_rel(name, des), payloads[name])
        # 2. the descriptor IS the write-ahead log
        desc = {"id": cid, "state": ST_FAILED,
                "targets": [list(t) for t in targets],
                "ts": time.time()}
        with flush_reason("committer", "descriptor"):
            pool.write_record(_desc_rel(cid), desc)
        # 2b. the original algorithm's conservative read barrier — the
        # flush Fig. 4 lines 20-22 exist to back: before trusting a
        # slot read for the reserve step, fence its line.  In steady
        # state the line is already clean, which is EXACTLY the
        # redundancy the paper's algorithm removes; the per-op protocol
        # keeps it as the measured baseline (the provenance ledger
        # flags each one redundant), and commit_round never pays it.
        with flush_reason("committer", "read_barrier"):
            for name, _exp, _des in targets:
                if pool.exists(_slot_rel(name)):
                    pool.persist(_slot_rel(name))
        # 3. reserve every slot (embed the descriptor address)
        success = True
        reserved: List[str] = []
        for name, exp, _des in targets:
            cur = self.pool.read_record(_slot_rel(name))
            cur_ver = 0 if cur is None else cur.get("version")
            if cur is not None and "desc" in cur:
                # another in-flight commit: resolve it first (help/wait)
                cur_ver = self.slot_version(name)
            if cur_ver != exp:
                success = False
                break
            with flush_reason("committer", "reserve"):
                pool.write_record(_slot_rel(name),
                                  {"desc": cid, "expected": exp})
            reserved.append(name)
        if success:
            # 4. durability linearization point
            desc["state"] = ST_SUCCEEDED
            with flush_reason("committer", "commit_point"):
                pool.write_record(_desc_rel(cid), desc)
        # 5. finalize (commit or roll back the reserved prefix)
        t = {s: (e, d) for s, e, d in targets}
        with flush_reason("committer", "finalize"):
            for name in reserved:
                exp, des = t[name]
                ver = des if success else exp
                pool.write_record(_slot_rel(name), {"version": ver})
        # 6. completed (lazy persist is safe: recovery replays idempotently)
        desc["state"] = ST_COMPLETED if success else desc["state"]
        pool.write_record(_desc_rel(cid), desc, persist=False)
        if success:
            for name, exp, _des in targets:
                if exp:
                    pool.delete(data_rel(name, exp))  # GC old version
        else:
            # GC the desired data files written in step 1: the rolled-back
            # slots never reference them, and leaving them would leak
            # orphaned data/*.bin until the next recover()
            for name, _exp, des in targets:
                if des != self.slot_version(name):
                    pool.delete(data_rel(name, des))
        return success

    # -- round-level group commit --------------------------------------------------
    def _next_round_id(self) -> str:
        """Monotonic round ids; ``wal/`` filename order == commit order
        (recovery replays rounds in that order).  The sequence resumes
        past any surviving round records after a crash."""
        if self._round_seq is None:
            top = 0
            for fn in self.pool.listdir("wal"):
                if fn.startswith(_ROUND_PREFIX) and fn.endswith(".json"):
                    try:
                        top = max(top, 1 + int(
                            fn[len(_ROUND_PREFIX):-len(".json")]))
                    except ValueError:
                        pass
            self._round_seq = top
        rid = f"{_ROUND_PREFIX}{self._round_seq:010d}"
        self._round_seq += 1
        return rid

    def commit_round(self, entries: Sequence[Tuple[str, Sequence[
            Tuple[str, int, int]]]], payloads: Dict[str, bytes]
            ) -> List[bool]:
        """Commit a conflict-free round of ops under ONE durability fence.

        ``entries`` is ``[(op_id, [(slot, expected, desired), ...]), ...]``
        — ops of one batch round; an op whose slots collide with an
        earlier entry, whose expected versions are stale, or whose
        versions do not advance fails individually (per-entry verdicts
        are returned, mirroring per-op :meth:`commit`).

        Protocol (DESIGN.md Sec. 9) — flush placement, not WAL shape,
        is what changes versus per-op commit:

        1. validate every entry against the live slot versions;
        2. write every winner's desired data files (visible, NOT yet
           flushed);
        3. persist ONE coalesced round record ``{id, kind: round,
           state: SUCCEEDED, ops: [{id, targets, payloads}]}`` — the
           single commit fence and durability linearization point of
           every op in the round;
        4. finalize every slot pointer and GC old data files LAZILY —
           the record (which embeds the payloads) is the durable truth
           until :meth:`prune_completed` flushes the final state and
           drops it.

        A crash before (3) leaves no durable record: the round never
        happened.  A crash after (3) is redone by :meth:`recover`
        (rounds replay in commit order; a slot already at its desired
        version is skipped, a slot superseded by a later durable commit
        is left alone).
        """
        pool = self.pool
        p0 = pool.persist_count
        with span("wal.commit_round", ops=len(entries)) as sp:
            verdicts: List[bool] = []
            winners: List[Tuple[str, List[Tuple[str, int, int]]]] = []
            claimed: Set[str] = set()
            for op_id, targets in entries:
                targets = [tuple(t) for t in targets]
                ok = (all(des != exp for _n, exp, des in targets) and
                      not any(name in claimed
                              for name, _e, _d in targets) and
                      all(self.slot_version(name) == exp
                          for name, exp, _d in targets))
                if ok:
                    claimed.update(name for name, _e, _d in targets)
                    winners.append((op_id, targets))
                verdicts.append(ok)
            sp.set(winners=len(winners))
            if not winners:
                return verdicts
            # 2. desired data, visible but unflushed (redo rebuilds it
            # from the record, so no per-file fence is needed)
            for _op_id, targets in winners:
                for name, _exp, des in targets:
                    pool.write(data_rel(name, des), payloads[name])
            # 3. the ONE fence: a coalesced WAL record for the round
            rid = self._next_round_id()
            rec = {"id": rid, "kind": "round", "state": ST_SUCCEEDED,
                   "ops": [{"id": op_id,
                            "targets": [list(t) for t in targets],
                            "payloads": {name: _b64(payloads[name])
                                         for name, _e, _d in targets}}
                           for op_id, targets in winners],
                   "ts": time.time()}
            with flush_reason("committer", "group_record"):
                pool.write_record(_desc_rel(rid), rec)
            # 4. lazy finalize + lazy GC (recovery replays the record)
            for _op_id, targets in winners:
                for name, exp, des in targets:
                    pool.write_record(_slot_rel(name), {"version": des},
                                      persist=False)
                    if exp:
                        pool.delete(data_rel(name, exp))
            rec["state"] = ST_COMPLETED
            pool.write_record(_desc_rel(rid), rec, persist=False)
            issued = pool.persist_count - p0
            _account(self.stats, flushes_issued=issued,
                     flushes_saved=sum(_per_op_flush_cost(t)
                                       for _id, t in winners) - issued,
                     fences=1, round_commits=1,
                     ops_committed=len(winners))
            sp.set(flushes=issued)
            return verdicts

    # -- WAL hygiene --------------------------------------------------------------
    def prune_completed(self) -> int:
        """Remove spent descriptor records from ``wal/``; returns how
        many were pruned.

        Every structure op writes one descriptor, so without pruning the
        WAL grows without bound (ROADMAP: recovery-time GC).  A record is
        *spent* — and safe to drop durably — once no target slot still
        references it: COMPLETED records (the common case, finalize done)
        and FAILED/SUCCEEDED residue that recovery already rolled
        forward/back.  Recovery only ever consults a descriptor through a
        slot's ``desc`` reference, so an unreferenced record cannot
        influence any future recover().

        Round records (group commit) are the ONLY durable copy of their
        round's effects until pruned, so dropping one first flushes the
        final state it guards — each slot pointer and live data file
        exactly once (dedup across rounds touching the same file).
        This is the deferred half of the group-commit bargain: the
        flushes leave the commit hot path and are amortized here.
        """
        pool = self.pool
        pruned = 0
        flushed: Set[str] = set()        # dedup: one persist per file

        def _flush_once(rel: str) -> None:
            if rel not in flushed and pool.exists(rel):
                pool.persist(rel)
                flushed.add(rel)

        with span("wal.prune_completed") as sp, \
                flush_reason("committer", "wal_prune"):
            for fn in pool.listdir("wal"):
                rel = f"wal/{fn}"
                desc = pool.read_record(rel)
                if desc is not None and desc.get("kind") == "round":
                    # REDO the round first (idempotent, exactly what
                    # recover() does): prune may legally run on a
                    # reopened pool before any recover, when the visible
                    # slot state still predates the round — flushing
                    # that stale state and dropping the record would
                    # lose the committed ops.
                    p0 = pool.persist_count
                    self._replay_round(desc)
                    for op in desc["ops"]:
                        for name, _exp, des in op["targets"]:
                            _flush_once(_slot_rel(name))
                            _flush_once(data_rel(name, des))
                    pool.delete_persist(rel)
                    issued = pool.persist_count - p0
                    # honest ledger: the per-op protocol would pay one
                    # delete_persist per op record here (its commit-time
                    # flushes were already credited saved in
                    # commit_round, so every persist THIS pass issues
                    # claws savings back)
                    _account(self.stats, flushes_issued=issued,
                             flushes_saved=len(desc["ops"]) - issued)
                    pruned += 1
                    continue
                if desc is not None:
                    referenced = False
                    for name, _exp, _des in desc["targets"]:
                        rec = pool.read_record(_slot_rel(name))
                        if rec is not None and \
                                rec.get("desc") == desc["id"]:
                            referenced = True
                            break
                    if referenced:
                        continue             # still in-flight: keep
                pool.delete_persist(rel)     # torn/spent: durably drop
                _account(self.stats, flushes_issued=1)  # per-op cost too
                pruned += 1
            sp.set(pruned=pruned)
        return pruned

    def _replay_round(self, desc: Dict) -> None:
        """Idempotent redo of one durable round record (shared by
        :meth:`recover` and :meth:`prune_completed`): a slot still at
        its expected version rolls forward durably (data file rebuilt
        from the embedded payload), a slot already at the desired
        version only has its data file ensured, and a slot superseded
        by a later durable commit is left alone."""
        pool = self.pool
        for op in desc["ops"]:
            for name, exp, des in (tuple(t) for t in op["targets"]):
                cur = self.slot_version(name)
                if cur == exp:
                    pool.write_persist(data_rel(name, des),
                                       _unb64(op["payloads"][name]))
                    pool.write_record(_slot_rel(name), {"version": des})
                elif cur == des and not pool.exists(data_rel(name, des)):
                    pool.write_persist(data_rel(name, des),
                                       _unb64(op["payloads"][name]))

    # -- recovery -----------------------------------------------------------------
    def recover(self) -> Dict[str, int]:
        """Roll every slot forward/back from the persisted descriptors.
        Idempotent; returns the recovered slot->version map.

        Per-op descriptors act through slot references (reserve made the
        pointer durable) and are order-independent; round records carry
        no slot references — a durable round record means DECIDED, and
        its ops replay in commit order (the id embeds the sequence):
        a slot still at the expected version is rolled forward (data
        file rebuilt from the record's embedded payload), a slot already
        at the desired version only has its data file ensured, and a
        slot superseded by a later durable commit is left alone."""
        pool = self.pool
        t0_ns = time.perf_counter_ns()
        with span("wal.recover", committer="wal") as sp, \
                flush_reason("committer", "recover"):
            # phase 1: scan the WAL — drop torn records, split the rest
            # into the per-op and round replay queues
            ops: List[Dict] = []
            rounds: List[Dict] = []
            with span("recover.scan_wal") as scan:
                for fn in pool.listdir("wal"):
                    desc = pool.read_record(f"wal/{fn}")
                    if desc is None:
                        pool.delete(f"wal/{fn}")   # torn/unpersisted
                    elif desc.get("kind") == "round":
                        rounds.append(desc)
                    else:
                        ops.append(desc)
                scan.set(ops=len(ops), rounds=len(rounds))
            # phase 2: per-op descriptors act through slot references
            # (reserve made the pointer durable); order-independent
            with span("recover.replay_ops", ops=len(ops)):
                for desc in ops:
                    t = {s: (e, d) for s, e, d in desc["targets"]}
                    for name, (exp, des) in t.items():
                        rec = pool.read_record(_slot_rel(name))
                        if rec is not None and \
                                rec.get("desc") == desc["id"]:
                            ver = des if desc["state"] == ST_SUCCEEDED \
                                else exp
                            pool.write_record(_slot_rel(name),
                                              {"version": ver})
            # phase 3: rounds replay in commit order (id embeds sequence)
            with span("recover.replay_rounds", rounds=len(rounds)):
                for desc in sorted(rounds, key=lambda d: d["id"]):
                    self._replay_round(desc)
            # phase 4: drop data files no slot references (uncommitted
            # desired versions)
            with span("recover.gc_data") as gc:
                live = set()
                for fn in pool.listdir("slots"):
                    name = fn[:-len(".json")]
                    live.add(data_rel(name, self.slot_version(name)))
                dropped = 0
                for fn in pool.listdir("data"):
                    if f"data/{fn}" not in live:
                        pool.delete(f"data/{fn}")
                        dropped += 1
                gc.set(dropped=dropped)
            recovered = {
                fn[:-len('.json')]: self.slot_version(fn[:-len('.json')])
                for fn in pool.listdir("slots")}
            sp.set(slots=len(recovered))
        get_registry().histogram("recover_us", component="committer") \
            .record((time.perf_counter_ns() - t0_ns) / 1e3)
        return recovered

"""Atomic multi-slot checkpoint commit — the paper's PMwCAS without dirty
flags, at file granularity (DESIGN.md Sec. 2.3).

Slots are named pointers (slots/<name> -> data version); a commit atomically
moves a SET of slots from their expected versions to desired versions.  The
per-op protocol is Fig. 4 *with* the original algorithm's conservative
read barrier (the flush lines 20-22 exist to back) — it is the measured
baseline that :meth:`Committer.commit_round` optimizes away:

  1. prepare: write + persist the desired data files (out-of-place)
  2. WAL:     persist descriptor {state: FAILED, targets: [(slot, exp, des)]}
  2b. read barrier: fence each existing slot line before trusting its read
      (almost always already clean — the provenance ledger flags each of
      these ``redundant_fences``; group commit never pays them)
  3. reserve: flip each slot pointer to reference the descriptor, persist
  4. commit:  persist descriptor state = SUCCEEDED   <- linearization point
  5. finalize: write each slot pointer = desired version, persist
  6. done:    descriptor state = COMPLETED (lazy persist), GC old data

There are NO per-slot commit markers (the dirty-flag analogue; the
baseline committer in marker_committer.py has them for the benchmark).
Recovery reads only descriptors + slot pointers and rolls forward/back.

Round-level group commit (DESIGN.md Sec. 9): :meth:`Committer.commit_round`
coalesces a whole conflict-free batch round into ONE WAL record — the
record embeds every op's targets AND payloads, so its single persist is
the round's only durability fence.  Data files and slot pointers are
written visibly but flushed lazily; recovery replays round records (in
commit order) exactly like per-op descriptors, rebuilding anything the
crash dropped from the record itself.  Descriptors-as-WAL is unchanged —
only flush *placement* moves, from per-op to per-round.

Epoch durability (DESIGN.md Sec. 14): with ``epoch_rounds > 1`` even the
per-round fence amortizes away — rounds buffer into an *epoch* that
shares ONE persist (a coalesced ``wal/epoch-*`` record embedding every
buffered round).  A fence is interposed early only when a round reads a
slot an earlier buffered round wrote (dependency-aware elision, tracked
from the rounds' target sets); :meth:`Committer.sync` is the explicit
barrier for callers needing round-granular durability, and a crash
inside an open epoch loses at most ``epoch_rounds - 1`` committed-but-
unfenced rounds, never a torn one (the bounded-loss window).  Data
files are MOD-style out-of-place on this path: they materialize only at
epoch close and are never individually fenced — the epoch record is the
single line needing ordered persistence.  :meth:`Committer.checkpoint`
persists one out-of-place image of every live slot (version + payload)
and durably drops the round/epoch records it covers, so recovery replay
length is bounded by the checkpoint cadence instead of the run length;
within an epoch the dependency rule makes the rounds mutually
independent, so recovery redoes each surviving epoch as one stacked
batch with no per-round fences.
"""
from __future__ import annotations

import base64
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..obs import flush_reason, get_registry, span
from .pmem import PMemPool

ST_COMPLETED, ST_FAILED, ST_SUCCEEDED = "COMPLETED", "FAILED", "SUCCEEDED"

_ROUND_PREFIX = "round-"
_EPOCH_PREFIX = "epoch-"
_CKPT_PREFIX = "ckpt-"


def _slot_rel(name: str) -> str:
    return f"slots/{name}.json"


def _desc_rel(cid: str) -> str:
    return f"wal/{cid}.json"


def _ckpt_rel(cid: str) -> str:
    return f"ckpt/{cid}.json"


def _rec_seq(rec_id: str) -> int:
    """Commit sequence embedded in a round/epoch/ckpt record id."""
    return int(rec_id.rsplit("-", 1)[1])


def data_rel(name: str, version: int) -> str:
    return f"data/{name}.v{version}.bin"


class CommitError(Exception):
    pass


@dataclasses.dataclass
class DurabilityStats:
    """Flush accounting for the commit paths (the paper's fewer-flushes
    lever, measured): how many persists were actually issued, how many
    the per-op protocol would have issued for the same commits, and how
    many commit fences (round-record persists) were paid."""
    flushes_issued: int = 0    # persists actually issued by commit paths
    flushes_saved: int = 0     # per-op-protocol persists coalesced away
    fences: int = 0            # round/epoch-record commit fences
    round_commits: int = 0     # commit_round calls that committed >= 1 op
    op_commits: int = 0        # per-op commit() calls
    ops_committed: int = 0     # ops that reached their linearization point
    epochs_closed: int = 0     # epoch records persisted (sync barriers)
    checkpoints: int = 0       # checkpoint images persisted
    dep_fences: int = 0        # epoch closes forced by a read-after-write

    def merge(self, other: "DurabilityStats") -> "DurabilityStats":
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self

    def as_row(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @property
    def flushes_per_commit(self) -> float:
        return (self.flushes_issued / self.ops_committed
                if self.ops_committed else 0.0)


def _b64(data: bytes) -> str:
    return base64.b64encode(data).decode("ascii")


def _unb64(text: str) -> bytes:
    return base64.b64decode(text.encode("ascii"))


def _per_op_flush_cost(targets: Sequence[Tuple[str, int, int]]) -> int:
    """Persists the per-op protocol pays for one committed op: k data
    prepares + 1 WAL + k reserves + 1 SUCCEEDED + k finalizes."""
    return 3 * len(targets) + 2


def _account(stats: DurabilityStats, **deltas: int) -> None:
    """Apply deltas to BOTH the dataclass and the global registry with
    the same integers — the two ledgers can never drift, which is what
    lets the durable benchmark assert exact equality between them.
    Registry series carry ``component="committer"`` so live commit
    accounting never collides with the adapter snapshot folds."""
    registry = get_registry()
    for name, delta in deltas.items():
        setattr(stats, name, getattr(stats, name) + delta)
        if delta:
            registry.counter(name, component="committer").inc(delta)


class Committer:
    """The paper's algorithm (no dirty flags)."""

    # round-level group commit is a protocol property of THIS committer;
    # the marker baseline keeps its per-slot dirty flags and opts out
    supports_rounds = True

    def __init__(self, pool: PMemPool, epoch_rounds: int = 1,
                 checkpoint_every: int = 0):
        """``epoch_rounds > 1`` buffers that many rounds per durability
        epoch (ONE fence at close; bounded-loss window of
        ``epoch_rounds - 1`` rounds); ``checkpoint_every = N`` persists
        a checkpoint image after every N epoch closes, bounding recovery
        replay to at most N epochs.  The defaults keep the measured
        group-commit protocol bit-identical."""
        self.pool = pool
        self.stats = DurabilityStats()
        self.epoch_rounds = max(1, int(epoch_rounds))
        self.checkpoint_every = max(0, int(checkpoint_every))
        self._round_seq: Optional[int] = None   # lazily scanned from wal/
        self._ckpt_seq: Optional[int] = None    # lazily scanned from ckpt/
        self._epoch: List[Dict] = []            # buffered round records
        self._epoch_written: Set[str] = set()   # slots those rounds wrote
        self._epochs_since_ckpt = 0

    @property
    def epoch_pending(self) -> int:
        """Rounds committed-but-unfenced in the open epoch (each is
        visible; none is durable until the next close/:meth:`sync`)."""
        return len(self._epoch)

    # -- reads -----------------------------------------------------------------
    def slot_version(self, name: str) -> int:
        """Read procedure (Fig. 5): resolve through in-flight descriptors."""
        rec = self.pool.read_record(_slot_rel(name))
        if rec is None:
            return 0
        if "desc" in rec:
            desc = self.pool.read_record(_desc_rel(rec["desc"]))
            if desc is None:    # descriptor never persisted -> roll back
                return rec["expected"]
            t = {s: (e, d) for s, e, d in desc["targets"]}
            exp, des = t[name]
            return des if desc["state"] == ST_SUCCEEDED else exp
        return rec["version"]

    # -- commit ------------------------------------------------------------------
    def commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
               payloads: Dict[str, bytes]) -> bool:
        """Atomically move every (slot, expected, desired); all-or-nothing.

        payloads: desired data per slot (written out-of-place first).
        """
        if self._epoch:
            # the per-op protocol reads and fences slot lines directly,
            # so an open epoch's rounds must be durable first — a
            # dependency fence in the minimal-ordering sense (the mixed
            # history could otherwise recover this commit without the
            # buffered rounds it read)
            self.sync()
        pool = self.pool
        p0 = pool.persist_count
        with span("wal.commit", slots=len(targets)) as sp:
            try:
                ok = self._commit(cid, targets, payloads)
            finally:
                _account(self.stats, op_commits=1,
                         flushes_issued=pool.persist_count - p0)
            if ok:
                _account(self.stats, ops_committed=1)
            sp.set(ok=ok, flushes=pool.persist_count - p0)
        return ok

    def _commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
                payloads: Dict[str, bytes]) -> bool:
        pool = self.pool
        # 0. versions must advance.  An exp == des "no-op move" would pass
        # every check and then GC its own live data file in step 6
        # (delete of data_rel(name, exp) == data_rel(name, des)).
        for _name, exp, des in targets:
            if des == exp:
                return False
        # 1. prepare desired values (out-of-place).  A desired version that
        # collides with the slot's LIVE version (stale exp) must not
        # clobber its data: refuse before writing anything.  The exists()
        # stat keeps the common path (fresh desired versions) to one cheap
        # check per target.
        for name, _exp, des in targets:
            if pool.exists(data_rel(name, des)) and \
                    des == self.slot_version(name) and \
                    pool.read(data_rel(name, des)) != payloads[name]:
                return False
        with flush_reason("committer", "data_prepare"):
            for name, _exp, des in targets:
                pool.write_persist(data_rel(name, des), payloads[name])
        # 2. the descriptor IS the write-ahead log
        desc = {"id": cid, "state": ST_FAILED,
                "targets": [list(t) for t in targets],
                "ts": time.time()}
        with flush_reason("committer", "descriptor"):
            pool.write_record(_desc_rel(cid), desc)
        # 2b. the original algorithm's conservative read barrier — the
        # flush Fig. 4 lines 20-22 exist to back: before trusting a
        # slot read for the reserve step, fence its line.  In steady
        # state the line is already clean, which is EXACTLY the
        # redundancy the paper's algorithm removes; the per-op protocol
        # keeps it as the measured baseline (the provenance ledger
        # flags each one redundant), and commit_round never pays it.
        with flush_reason("committer", "read_barrier"):
            for name, _exp, _des in targets:
                if pool.exists(_slot_rel(name)):
                    pool.persist(_slot_rel(name))
        # 3. reserve every slot (embed the descriptor address)
        success = True
        reserved: List[str] = []
        for name, exp, _des in targets:
            cur = self.pool.read_record(_slot_rel(name))
            cur_ver = 0 if cur is None else cur.get("version")
            if cur is not None and "desc" in cur:
                # another in-flight commit: resolve it first (help/wait)
                cur_ver = self.slot_version(name)
            if cur_ver != exp:
                success = False
                break
            with flush_reason("committer", "reserve"):
                pool.write_record(_slot_rel(name),
                                  {"desc": cid, "expected": exp})
            reserved.append(name)
        if success:
            # 4. durability linearization point
            desc["state"] = ST_SUCCEEDED
            with flush_reason("committer", "commit_point"):
                pool.write_record(_desc_rel(cid), desc)
        # 5. finalize (commit or roll back the reserved prefix)
        t = {s: (e, d) for s, e, d in targets}
        with flush_reason("committer", "finalize"):
            for name in reserved:
                exp, des = t[name]
                ver = des if success else exp
                pool.write_record(_slot_rel(name), {"version": ver})
        # 6. completed (lazy persist is safe: recovery replays idempotently)
        desc["state"] = ST_COMPLETED if success else desc["state"]
        pool.write_record(_desc_rel(cid), desc, persist=False)
        if success:
            for name, exp, _des in targets:
                if exp:
                    pool.delete(data_rel(name, exp))  # GC old version
        else:
            # GC the desired data files written in step 1: the rolled-back
            # slots never reference them, and leaving them would leak
            # orphaned data/*.bin until the next recover()
            for name, _exp, des in targets:
                if des != self.slot_version(name):
                    pool.delete(data_rel(name, des))
        return success

    # -- round-level group commit --------------------------------------------------
    def _scan_wal_seq(self) -> int:
        """First unused round sequence judging from ``wal/`` filenames
        (an epoch record is named by its LAST embedded round, so the
        scan needs no record reads)."""
        top = 0
        for fn in self.pool.listdir("wal"):
            for prefix in (_ROUND_PREFIX, _EPOCH_PREFIX):
                if fn.startswith(prefix) and fn.endswith(".json"):
                    try:
                        top = max(top, 1 + int(
                            fn[len(prefix):-len(".json")]))
                    except ValueError:
                        pass
        return top

    def _next_round_id(self) -> str:
        """Monotonic round ids; ``wal/`` filename order == commit order
        (recovery replays rounds in that order).  The sequence resumes
        past any surviving round/epoch records after a crash."""
        if self._round_seq is None:
            self._round_seq = self._scan_wal_seq()
        rid = f"{_ROUND_PREFIX}{self._round_seq:010d}"
        self._round_seq += 1
        return rid

    def commit_round(self, entries: Sequence[Tuple[str, Sequence[
            Tuple[str, int, int]]]], payloads: Dict[str, bytes]
            ) -> List[bool]:
        """Commit a conflict-free round of ops under ONE durability fence.

        ``entries`` is ``[(op_id, [(slot, expected, desired), ...]), ...]``
        — ops of one batch round; an op whose slots collide with an
        earlier entry, whose expected versions are stale, or whose
        versions do not advance fails individually (per-entry verdicts
        are returned, mirroring per-op :meth:`commit`).

        Protocol (DESIGN.md Sec. 9) — flush placement, not WAL shape,
        is what changes versus per-op commit:

        1. validate every entry against the live slot versions;
        2. write every winner's desired data files (visible, NOT yet
           flushed);
        3. persist ONE coalesced round record ``{id, kind: round,
           state: SUCCEEDED, ops: [{id, targets, payloads}]}`` — the
           single commit fence and durability linearization point of
           every op in the round;
        4. finalize every slot pointer and GC old data files LAZILY —
           the record (which embeds the payloads) is the durable truth
           until :meth:`prune_completed` flushes the final state and
           drops it.

        A crash before (3) leaves no durable record: the round never
        happened.  A crash after (3) is redone by :meth:`recover`
        (rounds replay in commit order; a slot already at its desired
        version is skipped, a slot superseded by a later durable commit
        is left alone).
        """
        pool = self.pool
        p0 = pool.persist_count
        with span("wal.commit_round", ops=len(entries)) as sp:
            verdicts: List[bool] = []
            winners: List[Tuple[str, List[Tuple[str, int, int]]]] = []
            claimed: Set[str] = set()
            for op_id, targets in entries:
                targets = [tuple(t) for t in targets]
                ok = (all(des != exp for _n, exp, des in targets) and
                      not any(name in claimed
                              for name, _e, _d in targets) and
                      all(self.slot_version(name) == exp
                          for name, exp, _d in targets))
                if ok:
                    claimed.update(name for name, _e, _d in targets)
                    winners.append((op_id, targets))
                verdicts.append(ok)
            sp.set(winners=len(winners))
            if not winners:
                return verdicts
            if self.epoch_rounds > 1:
                # -- epoch path (DESIGN.md Sec. 14) --------------------
                # Dependency-aware fence elision: every target slot is
                # both read (expected check) and written, so a fence is
                # interposed early ONLY when this round's target set
                # intersects what the open epoch already wrote — the
                # minimal ordering the recovered state needs.
                if claimed & self._epoch_written:
                    _account(self.stats, dep_fences=1)
                    self.sync()
                rid = self._next_round_id()
                rec = {"id": rid, "kind": "round", "state": ST_SUCCEEDED,
                       "ops": [{"id": op_id,
                                "targets": [list(t) for t in targets],
                                "payloads": {name: _b64(payloads[name])
                                             for name, _e, _d in targets}}
                               for op_id, targets in winners],
                       "ts": time.time()}
                # lazy finalize: slot pointers move visibly NOW (reads
                # see the round committed); data files do not — they
                # are MOD-style out-of-place and materialize at close
                for _op_id, targets in winners:
                    for name, _exp, des in targets:
                        pool.write_record(_slot_rel(name),
                                          {"version": des}, persist=False)
                self._epoch.append(rec)
                self._epoch_written |= claimed
                # the round's own fence is elided (credited saved here);
                # the shared close fence is debited when it is paid
                _account(self.stats, round_commits=1,
                         ops_committed=len(winners),
                         flushes_saved=sum(_per_op_flush_cost(t)
                                           for _id, t in winners) - 1)
                sp.set(flushes=0, epoch_pending=len(self._epoch))
                if len(self._epoch) >= self.epoch_rounds:
                    # the Nth round rides the closing fence, so at most
                    # epoch_rounds - 1 committed rounds are ever at risk
                    self.sync()
                return verdicts
            # 2. desired data, visible but unflushed (redo rebuilds it
            # from the record, so no per-file fence is needed)
            for _op_id, targets in winners:
                for name, _exp, des in targets:
                    pool.write(data_rel(name, des), payloads[name])
            # 3. the ONE fence: a coalesced WAL record for the round
            rid = self._next_round_id()
            rec = {"id": rid, "kind": "round", "state": ST_SUCCEEDED,
                   "ops": [{"id": op_id,
                            "targets": [list(t) for t in targets],
                            "payloads": {name: _b64(payloads[name])
                                         for name, _e, _d in targets}}
                           for op_id, targets in winners],
                   "ts": time.time()}
            with flush_reason("committer", "group_record"):
                pool.write_record(_desc_rel(rid), rec)
            # 4. lazy finalize + lazy GC (recovery replays the record)
            for _op_id, targets in winners:
                for name, exp, des in targets:
                    pool.write_record(_slot_rel(name), {"version": des},
                                      persist=False)
                    if exp:
                        pool.delete(data_rel(name, exp))
            rec["state"] = ST_COMPLETED
            pool.write_record(_desc_rel(rid), rec, persist=False)
            issued = pool.persist_count - p0
            _account(self.stats, flushes_issued=issued,
                     flushes_saved=sum(_per_op_flush_cost(t)
                                       for _id, t in winners) - issued,
                     fences=1, round_commits=1,
                     ops_committed=len(winners))
            sp.set(flushes=issued)
            return verdicts

    # -- epoch durability ---------------------------------------------------------
    def sync(self) -> int:
        """Close the open epoch under ONE persist fence; returns the
        number of rounds made durable (0 if none were buffered).

        The explicit round-granular durability barrier: the coalesced
        ``wal/epoch-*`` record (named by its LAST embedded round, so
        filename order stays commit order) embeds every buffered round —
        its single persist is the durability linearization point of all
        of them.  Only then do the rounds' data files materialize
        (out-of-place, visible, never fenced) and the superseded
        pre-epoch data files go away: no line but the epoch record ever
        needs ordered persistence."""
        if not self._epoch:
            return 0
        pool = self.pool
        rounds, self._epoch = self._epoch, []
        self._epoch_written = set()
        eid = f"{_EPOCH_PREFIX}{rounds[-1]['id'][len(_ROUND_PREFIX):]}"
        with span("wal.epoch_close", rounds=len(rounds)) as sp:
            rec = {"id": eid, "kind": "epoch",
                   "rounds": rounds, "ts": time.time()}
            with flush_reason("committer", "epoch_close"):
                pool.write_record(_desc_rel(eid), rec)   # THE one fence
            for rnd in rounds:
                for op in rnd["ops"]:
                    for name, exp, des in (tuple(t) for t in op["targets"]):
                        pool.write(data_rel(name, des),
                                   _unb64(op["payloads"][name]))
                        if exp:
                            pool.delete(data_rel(name, exp))
            # group commit would have paid one fence per round; the
            # epoch pays one for all of them
            _account(self.stats, flushes_issued=1, fences=1,
                     epochs_closed=1, flushes_saved=len(rounds) - 1)
            sp.set(flushes=1)
        self._epochs_since_ckpt += 1
        if self.checkpoint_every and \
                self._epochs_since_ckpt >= self.checkpoint_every:
            self.checkpoint()
        return len(rounds)

    def _next_ckpt_id(self) -> str:
        if self._ckpt_seq is None:
            top = 0
            for fn in self.pool.listdir("ckpt"):
                if fn.startswith(_CKPT_PREFIX) and fn.endswith(".json"):
                    try:
                        top = max(top, 1 + int(
                            fn[len(_CKPT_PREFIX):-len(".json")]))
                    except ValueError:
                        pass
            self._ckpt_seq = top
        cid = f"{_CKPT_PREFIX}{self._ckpt_seq:010d}"
        self._ckpt_seq += 1
        return cid

    def checkpoint(self) -> int:
        """Persist one out-of-place image of every live slot and durably
        drop the round/epoch records it covers; returns records dropped.

        The image embeds versions AND payloads, so under the round/epoch
        protocol the slot and data files become pure cache — nothing
        under ``slots/`` or ``data/`` is fenced here (the MOD argument:
        out-of-place shrinks the ordered-persistence set to the single
        checkpoint record).  ``covers`` is the highest round sequence
        reflected in the image; recovery installs the latest image and
        replays only records above it, so replay length is bounded by
        the checkpoint cadence — this supersedes raw ``wal_prune_every``
        scanning.  Crash-safe at every persist: before the image lands
        the old image + records recover; after it, recovery finishes the
        interrupted drops itself.  Per-op descriptors are out of scope
        (they act through durable slot references and keep
        :meth:`prune_completed` as their hygiene path)."""
        self.sync()               # the image reflects a round prefix
        self._epochs_since_ckpt = 0
        pool = self.pool
        p0 = pool.persist_count
        with span("wal.checkpoint") as sp, \
                flush_reason("committer", "checkpoint"):
            slots: Dict[str, int] = {}
            payloads: Dict[str, str] = {}
            for fn in pool.listdir("slots"):
                name = fn[:-len(".json")]
                rec = pool.read_record(_slot_rel(name))
                if rec is not None and "desc" in rec:
                    continue      # in-flight per-op: its descriptor owns it
                ver = self.slot_version(name)
                slots[name] = ver
                if ver and pool.exists(data_rel(name, ver)):
                    payloads[name] = _b64(pool.read(data_rel(name, ver)))
            covers = -1
            covered: List[str] = []
            for fn in pool.listdir("wal"):
                desc = pool.read_record(f"wal/{fn}")
                if desc is not None and \
                        desc.get("kind") in ("round", "epoch"):
                    covers = max(covers, _rec_seq(desc["id"]))
                    covered.append(f"wal/{fn}")
            if covers < 0 and not slots:
                return 0          # nothing to bound
            cid = self._next_ckpt_id()
            pool.write_record(_ckpt_rel(cid), {
                "id": cid, "kind": "checkpoint", "covers": covers,
                "slots": slots, "payloads": payloads,
                "ts": time.time()})              # the image's ONE fence
            for rel in covered:
                pool.delete_persist(rel)         # replay debt retired
            for fn in pool.listdir("ckpt"):
                if fn != f"{cid}.json":
                    pool.delete_persist(f"ckpt/{fn}")   # old image spent
            issued = pool.persist_count - p0
            # honest ledger: pruning the same records would have fenced
            # every slot + live data file before each drop
            _account(self.stats, flushes_issued=issued, checkpoints=1,
                     flushes_saved=max(
                         0, 2 * len(slots) + len(covered) - issued))
            sp.set(covers=covers, dropped=len(covered), flushes=issued)
        return len(covered)

    # -- WAL hygiene --------------------------------------------------------------
    def prune_completed(self) -> int:
        """Remove spent descriptor records from ``wal/``; returns how
        many were pruned.

        Every structure op writes one descriptor, so without pruning the
        WAL grows without bound (ROADMAP: recovery-time GC).  A record is
        *spent* — and safe to drop durably — once no target slot still
        references it: COMPLETED records (the common case, finalize done)
        and FAILED/SUCCEEDED residue that recovery already rolled
        forward/back.  Recovery only ever consults a descriptor through a
        slot's ``desc`` reference, so an unreferenced record cannot
        influence any future recover().

        Round records (group commit) are the ONLY durable copy of their
        round's effects until pruned, so dropping one first flushes the
        final state it guards — each slot pointer and live data file
        exactly once (dedup across rounds touching the same file).
        This is the deferred half of the group-commit bargain: the
        flushes leave the commit hot path and are amortized here.
        """
        pool = self.pool
        pruned = 0
        flushed: Set[str] = set()        # dedup: one persist per file

        def _flush_once(rel: str) -> None:
            if rel not in flushed and pool.exists(rel):
                pool.persist(rel)
                flushed.add(rel)

        with span("wal.prune_completed") as sp, \
                flush_reason("committer", "wal_prune"):
            for fn in pool.listdir("wal"):
                rel = f"wal/{fn}"
                desc = pool.read_record(rel)
                if desc is not None and \
                        desc.get("kind") in ("round", "epoch"):
                    # REDO the round(s) first (idempotent, exactly what
                    # recover() does): prune may legally run on a
                    # reopened pool before any recover, when the visible
                    # slot state still predates the round — flushing
                    # that stale state and dropping the record would
                    # lose the committed ops.  An epoch record is its
                    # rounds' only durable copy, so it prunes the same
                    # way, round by embedded round.
                    p0 = pool.persist_count
                    rounds = (desc["rounds"] if desc["kind"] == "epoch"
                              else [desc])
                    n_ops = 0
                    for rnd in rounds:
                        self._replay_round(rnd)
                        for op in rnd["ops"]:
                            n_ops += 1
                            for name, _exp, des in op["targets"]:
                                _flush_once(_slot_rel(name))
                                _flush_once(data_rel(name, des))
                    pool.delete_persist(rel)
                    issued = pool.persist_count - p0
                    # honest ledger: the per-op protocol would pay one
                    # delete_persist per op record here (its commit-time
                    # flushes were already credited saved in
                    # commit_round, so every persist THIS pass issues
                    # claws savings back)
                    _account(self.stats, flushes_issued=issued,
                             flushes_saved=n_ops - issued)
                    pruned += 1
                    continue
                if desc is not None:
                    referenced = False
                    for name, _exp, _des in desc["targets"]:
                        rec = pool.read_record(_slot_rel(name))
                        if rec is not None and \
                                rec.get("desc") == desc["id"]:
                            referenced = True
                            break
                    if referenced:
                        continue             # still in-flight: keep
                pool.delete_persist(rel)     # torn/spent: durably drop
                _account(self.stats, flushes_issued=1)  # per-op cost too
                pruned += 1
            sp.set(pruned=pruned)
        return pruned

    def _replay_round(self, desc: Dict) -> None:
        """Idempotent redo of one durable round record (shared by
        :meth:`recover` and :meth:`prune_completed`): a slot still at
        its expected version rolls forward durably (data file rebuilt
        from the embedded payload), a slot already at the desired
        version only has its data file ensured, and a slot superseded
        by a later durable commit is left alone."""
        pool = self.pool
        for op in desc["ops"]:
            for name, exp, des in (tuple(t) for t in op["targets"]):
                cur = self.slot_version(name)
                if cur == exp:
                    pool.write_persist(data_rel(name, des),
                                       _unb64(op["payloads"][name]))
                    pool.write_record(_slot_rel(name), {"version": des})
                elif cur == des and not pool.exists(data_rel(name, des)):
                    pool.write_persist(data_rel(name, des),
                                       _unb64(op["payloads"][name]))

    def _replay_epoch(self, desc: Dict) -> None:
        """One stacked redo of an epoch's rounds.  The dependency-
        elision rule guarantees no slot appears in two rounds of the
        same epoch, so the union of their slot moves applies as ONE
        batch — and with NO per-round fences: every write here is lazy
        (visible only), because the epoch record itself stays the
        durable truth until a checkpoint drops it.  Eliminating those
        per-round fsyncs is what collapses ``recover_ms``."""
        pool = self.pool
        for rnd in desc["rounds"]:
            for op in rnd["ops"]:
                for name, exp, des in (tuple(t) for t in op["targets"]):
                    cur = self.slot_version(name)
                    if cur == exp:
                        pool.write(data_rel(name, des),
                                   _unb64(op["payloads"][name]))
                        pool.write_record(_slot_rel(name),
                                          {"version": des}, persist=False)
                        if exp:
                            pool.delete(data_rel(name, exp))
                    elif cur == des and \
                            not pool.exists(data_rel(name, des)):
                        pool.write(data_rel(name, des),
                                   _unb64(op["payloads"][name]))

    # -- recovery -----------------------------------------------------------------
    def recover(self) -> Dict[str, int]:
        """Roll every slot forward/back from the persisted descriptors.
        Idempotent; returns the recovered slot->version map.

        Per-op descriptors act through slot references (reserve made the
        pointer durable) and are order-independent; round records carry
        no slot references — a durable round record means DECIDED, and
        its ops replay in commit order (the id embeds the sequence):
        a slot still at the expected version is rolled forward (data
        file rebuilt from the record's embedded payload), a slot already
        at the desired version only has its data file ensured, and a
        slot superseded by a later durable commit is left alone.

        With checkpoints, replay is bounded and batched: the latest
        checkpoint image installs first (slots + payloads, lazily — the
        image stays the durable truth), records at or below its
        ``covers`` sequence are durably dropped (finishing any
        interrupted checkpoint's job), and each surviving epoch record
        redoes as one stacked batch via :meth:`_replay_epoch` with no
        per-round fences."""
        pool = self.pool
        t0_ns = time.perf_counter_ns()
        with span("wal.recover", committer="wal") as sp, \
                flush_reason("committer", "recover"):
            # phase 0: install the latest checkpoint image (if any) and
            # note the round prefix it covers
            covers = -1
            with span("recover.load_checkpoint") as lc:
                images = []
                for fn in pool.listdir("ckpt"):
                    rec = pool.read_record(f"ckpt/{fn}")
                    if rec is None:
                        pool.delete(f"ckpt/{fn}")          # torn image
                    else:
                        images.append(rec)
                images.sort(key=lambda r: _rec_seq(r["id"]))
                installed = 0
                if images:
                    ck = images[-1]
                    for old in images[:-1]:   # crash mid-supersede:
                        pool.delete_persist(_ckpt_rel(old["id"]))
                    covers = ck["covers"]
                    for name, ver in ck["slots"].items():
                        cur = pool.read_record(_slot_rel(name))
                        if cur is not None and "desc" in cur:
                            continue   # durable per-op reservation wins
                        pool.write_record(_slot_rel(name),
                                          {"version": ver}, persist=False)
                        payload = ck["payloads"].get(name)
                        if ver and payload is not None and \
                                not pool.exists(data_rel(name, ver)):
                            pool.write(data_rel(name, ver),
                                       _unb64(payload))
                        installed += 1
                lc.set(installed=installed, covers=covers)
            # phase 1: scan the WAL — drop torn records and anything the
            # checkpoint already covers, split the rest into the per-op
            # and round/epoch replay queues
            ops: List[Dict] = []
            rounds: List[Dict] = []
            with span("recover.scan_wal") as scan:
                for fn in pool.listdir("wal"):
                    desc = pool.read_record(f"wal/{fn}")
                    if desc is None:
                        pool.delete(f"wal/{fn}")   # torn/unpersisted
                    elif desc.get("kind") in ("round", "epoch"):
                        if _rec_seq(desc["id"]) <= covers:
                            # leftover an interrupted checkpoint meant
                            # to drop: its effects are in the image
                            pool.delete_persist(f"wal/{fn}")
                        else:
                            rounds.append(desc)
                    else:
                        ops.append(desc)
                scan.set(ops=len(ops), rounds=len(rounds))
            # phase 2: per-op descriptors act through slot references
            # (reserve made the pointer durable); order-independent
            with span("recover.replay_ops", ops=len(ops)):
                for desc in ops:
                    t = {s: (e, d) for s, e, d in desc["targets"]}
                    for name, (exp, des) in t.items():
                        rec = pool.read_record(_slot_rel(name))
                        if rec is not None and \
                                rec.get("desc") == desc["id"]:
                            ver = des if desc["state"] == ST_SUCCEEDED \
                                else exp
                            pool.write_record(_slot_rel(name),
                                              {"version": ver})
            # phase 3: rounds replay in commit order (id embeds
            # sequence; an epoch record sorts at its FIRST embedded
            # round — epochs are contiguous sequence ranges, so the
            # merged order is total).  Epochs redo as one stacked batch
            # each, with no per-round fences.
            def _order(d: Dict) -> int:
                if d.get("kind") == "epoch":
                    return _rec_seq(d["rounds"][0]["id"])
                return _rec_seq(d["id"])

            n_epochs = sum(1 for d in rounds if d.get("kind") == "epoch")
            with span("recover.replay_rounds",
                      rounds=len(rounds) - n_epochs, epochs=n_epochs):
                for desc in sorted(rounds, key=_order):
                    if desc.get("kind") == "epoch":
                        self._replay_epoch(desc)
                    else:
                        self._replay_round(desc)
            # phase 4: drop data files no slot references (uncommitted
            # desired versions)
            with span("recover.gc_data") as gc:
                live = set()
                for fn in pool.listdir("slots"):
                    name = fn[:-len(".json")]
                    live.add(data_rel(name, self.slot_version(name)))
                dropped = 0
                for fn in pool.listdir("data"):
                    if f"data/{fn}" not in live:
                        pool.delete(f"data/{fn}")
                        dropped += 1
                gc.set(dropped=dropped)
            recovered = {
                fn[:-len('.json')]: self.slot_version(fn[:-len('.json')])
                for fn in pool.listdir("slots")}
            # the round sequence must clear the checkpoint horizon, or a
            # reused sequence would be mistaken for covered on the NEXT
            # recovery and dropped unreplayed
            self._round_seq = max(self._scan_wal_seq(), covers + 1,
                                  self._round_seq or 0)
            sp.set(slots=len(recovered))
        get_registry().histogram("recover_us", component="committer") \
            .record((time.perf_counter_ns() - t0_ns) / 1e3)
        return recovered

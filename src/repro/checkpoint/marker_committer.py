"""Baseline committer WITH per-slot commit markers — the file-granularity
analogue of the original algorithm's dirty flags (and of naive multi-file
checkpointers): every slot write sets a marker, persists, clears the
marker, persists again.  Functionally equivalent to ``Committer`` but pays
2 extra persists per slot; ``benchmarks/bench_ckpt.py`` quantifies the gap,
mirroring the paper's ours-vs-ours(DF) comparison."""
from __future__ import annotations

import time
from typing import Dict, Sequence, Tuple

from ..obs import flush_reason, get_registry, span
from .committer import (Committer, DurabilityStats, ST_COMPLETED, ST_FAILED,
                        ST_SUCCEEDED, _account, _desc_rel, _slot_rel,
                        data_rel)
from .pmem import PMemPool


def _marker_rel(name: str) -> str:
    return f"markers/{name}.json"


class MarkerCommitter:
    # dirty-flag markers are inherently per-slot; no round-level protocol
    supports_rounds = False

    def __init__(self, pool: PMemPool, epoch_rounds: int = 1,
                 checkpoint_every: int = 0):
        # epoch durability needs round records to buffer and a single
        # coalesced fence to ride; per-slot dirty flags force a fence
        # per slot write, so the baseline cannot defer them — refuse
        # rather than silently measure the wrong protocol
        if int(epoch_rounds) != 1 or int(checkpoint_every):
            raise ValueError(
                "marker committer has no epoch protocol (per-slot dirty "
                "flags cannot defer their fences); use the WAL committer "
                "for epoch_rounds > 1 / checkpoint_every > 0")
        self.pool = pool
        self.stats = DurabilityStats()

    # WAL hygiene is committer-agnostic (it reads only descriptors and
    # slot records, both shared vocabulary) — reuse the primary logic
    prune_completed = Committer.prune_completed

    # surface uniformity with Committer's epoch API: every marker commit
    # is already durable at return, so the barrier has nothing to close
    epoch_pending = 0

    def sync(self) -> int:
        return 0

    def slot_version(self, name: str) -> int:
        rec = self.pool.read_record(_slot_rel(name))
        if rec is None:
            return 0
        if "desc" in rec:
            desc = self.pool.read_record(_desc_rel(rec["desc"]))
            if desc is None:
                return rec["expected"]
            t = {s: (e, d) for s, e, d in desc["targets"]}
            exp, des = t[name]
            return des if desc["state"] == ST_SUCCEEDED else exp
        return rec["version"]

    def commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
               payloads: Dict[str, bytes]) -> bool:
        pool = self.pool
        p0 = pool.persist_count
        with span("wal.commit", slots=len(targets),
                  committer="marker") as sp:
            try:
                ok = self._commit(cid, targets, payloads)
            finally:
                _account(self.stats, op_commits=1,
                         flushes_issued=pool.persist_count - p0)
            if ok:
                _account(self.stats, ops_committed=1)
            sp.set(ok=ok, flushes=pool.persist_count - p0)
        return ok

    def _commit(self, cid: str, targets: Sequence[Tuple[str, int, int]],
                payloads: Dict[str, bytes]) -> bool:
        pool = self.pool
        # versions must advance + never clobber a live version's data
        # (see Committer.commit steps 0/1)
        for _name, exp, des in targets:
            if des == exp:
                return False
        for name, _exp, des in targets:
            if pool.exists(data_rel(name, des)) and \
                    des == self.slot_version(name) and \
                    pool.read(data_rel(name, des)) != payloads[name]:
                return False
        with flush_reason("committer", "data_prepare"):
            for name, _exp, des in targets:
                pool.write_persist(data_rel(name, des), payloads[name])
        desc = {"id": cid, "state": ST_FAILED,
                "targets": [list(t) for t in targets], "ts": time.time()}
        with flush_reason("committer", "descriptor"):
            pool.write_record(_desc_rel(cid), desc)
        # the dirty-flag algorithm's conservative read barrier (same as
        # Committer._commit step 2b): fence each slot line before
        # trusting its read
        with flush_reason("committer", "read_barrier"):
            for name, _exp, _des in targets:
                if pool.exists(_slot_rel(name)):
                    pool.persist(_slot_rel(name))
        success = True
        reserved = []
        for name, exp, _des in targets:
            cur = pool.read_record(_slot_rel(name))
            cur_ver = 0 if cur is None else cur.get("version")
            if cur is not None and "desc" in cur:
                cur_ver = self.slot_version(name)
            if cur_ver != exp:
                success = False
                break
            with flush_reason("committer", "reserve"):
                pool.write_record(_slot_rel(name),
                                  {"desc": cid, "expected": exp})
            reserved.append(name)
        if success:
            desc["state"] = ST_SUCCEEDED
            with flush_reason("committer", "commit_point"):
                pool.write_record(_desc_rel(cid), desc)
        t = {s: (e, d) for s, e, d in targets}
        with flush_reason("committer", "marker_finalize"):
            for name in reserved:
                exp, des = t[name]
                ver = des if success else exp
                # dirty-flag analogue: set marker, persist, write, persist,
                # clear marker, persist  (the double-flush the paper removes)
                pool.write_record(_marker_rel(name),
                                  {"dirty": True, "slot": name})
                pool.write_record(_slot_rel(name), {"version": ver})
                pool.write_record(_marker_rel(name), {"dirty": False,
                                                      "slot": name})
        desc["state"] = ST_COMPLETED if success else desc["state"]
        pool.write_record(_desc_rel(cid), desc, persist=False)
        if success:
            for name, exp, _des in targets:
                if exp:
                    pool.delete(data_rel(name, exp))
        else:
            # GC desired data files from step 1 (same leak as Committer)
            for name, _exp, des in targets:
                if des != self.slot_version(name):
                    pool.delete(data_rel(name, des))
        return success

    def recover(self) -> Dict[str, int]:
        # markers force a scan of every slot (the cost the WAL-only design
        # avoids); afterwards the descriptor logic is identical
        pool = self.pool
        t0_ns = time.perf_counter_ns()
        with span("wal.recover", committer="marker") as sp, \
                flush_reason("committer", "recover"):
            with span("recover.clear_markers") as clear:
                markers = pool.listdir("markers")
                for fn in markers:
                    pool.delete(f"markers/{fn}")
                clear.set(markers=len(markers))
            with span("recover.replay_ops"):
                for fn in pool.listdir("wal"):
                    desc = pool.read_record(f"wal/{fn}")
                    if desc is None:
                        pool.delete(f"wal/{fn}")
                        continue
                    t = {s: (e, d) for s, e, d in desc["targets"]}
                    for name, (exp, des) in t.items():
                        rec = pool.read_record(_slot_rel(name))
                        if rec is not None and \
                                rec.get("desc") == desc["id"]:
                            ver = des if desc["state"] == ST_SUCCEEDED \
                                else exp
                            pool.write_record(_slot_rel(name),
                                              {"version": ver})
            recovered = {
                fn[:-len('.json')]: self.slot_version(fn[:-len('.json')])
                for fn in pool.listdir("slots")}
            sp.set(slots=len(recovered))
        get_registry().histogram("recover_us", component="committer") \
            .record((time.perf_counter_ns() - t0_ns) / 1e3)
        return recovered

"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8, head_dim=256)
d_ff=14336 vocab=256000; alternating local/global attention, logit
softcaps.  [arXiv:2408.00118; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=14336, vocab=256000,
        unit=(LayerSpec(kind="attn", attn_type="local", ffn="dense"),
              LayerSpec(kind="attn", attn_type="global", ffn="dense")),
        attn_softcap=50.0, logit_softcap=30.0, sliding_window=4096,
        scale_embed=True, tie_embeddings=True, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=512, sliding_window=8)

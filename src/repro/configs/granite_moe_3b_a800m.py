"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8), MoE 40
experts top-8, per-expert d_ff=512, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155,
        unit=(LayerSpec(kind="attn", ffn="moe"),),
        moe=MoEConfig(n_experts=40, top_k=8, d_ff=512),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
        d_ff=64, vocab=512, moe=MoEConfig(n_experts=5, top_k=2, d_ff=64))

"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import (LayerSpec, MambaConfig, ModelConfig, MoEConfig,
                   ShapeConfig, SHAPES, XLSTMConfig, shapes_for)

ARCH_IDS = [
    "qwen3_moe_30b_a3b",
    "granite_moe_3b_a800m",
    "qwen15_32b",
    "glm4_9b",
    "llama3_8b",
    "gemma2_9b",
    "xlstm_125m",
    "seamless_m4t_medium",
    "jamba_v01_52b",
    "paligemma_3b",
]

# canonical --arch ids (hyphenated, as in the assignment)
ALIASES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen1.5-32b": "qwen15_32b",
    "glm4-9b": "glm4_9b",
    "llama3-8b": "llama3_8b",
    "gemma2-9b": "gemma2_9b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "paligemma-3b": "paligemma_3b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.config()


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "ALIASES", "get_config", "all_configs", "LayerSpec",
           "MambaConfig", "ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "XLSTMConfig", "shapes_for"]

"""seamless-m4t-medium [audio]: encoder-decoder, 12L each, d_model=1024
16H d_ff=4096 vocab=256206.  The speech frontend is a STUB: input_specs()
provides precomputed frame embeddings.  [arXiv:2308.11596; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=256206,
        unit=(LayerSpec(kind="attn", ffn="dense"),),
        enc_dec=True, n_enc_layers=12,
        frontend="audio", frontend_dim=1024, frontend_len=1024,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, frontend_dim=32, frontend_len=16)

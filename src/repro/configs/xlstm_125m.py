"""xlstm-125m [ssm]: 12L d_model=768 4H, sLSTM + mLSTM blocks, d_ff=0
(capacity in block up-projections), vocab=50304.
[arXiv:2405.04517; unverified]"""
import dataclasses

from .base import LayerSpec, ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        unit=(LayerSpec(kind="mlstm", ffn="none"),
              LayerSpec(kind="slstm", ffn="none")),
        xlstm=XLSTMConfig(proj_factor=2.0, chunk=64),
        tie_embeddings=True, subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, vocab=512)

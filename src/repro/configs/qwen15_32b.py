"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, i.e. MHA) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab=152064,
        unit=(LayerSpec(kind="attn", ffn="dense"),),
        qkv_bias=True, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=512)

"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  [arXiv:2407.21783; unverified]"""
import dataclasses

from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256,
        unit=(LayerSpec(kind="attn", ffn="dense"),),
        rope_theta=500_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab=512)

"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552; RoPE over half the head dim.  [hf:THUDM/glm-4-9b; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552,
        unit=(LayerSpec(kind="attn", ffn="dense"),),
        rotary_fraction=0.5, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512)

"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
Mamba:attention 7:1 interleave, MoE 16 experts top-2 on every other layer,
vocab=65536.  [arXiv:2403.19887; hf]"""
import dataclasses

from .base import LayerSpec, MambaConfig, ModelConfig, MoEConfig


def _unit():
    # 8-layer jamba block: attention at index 4, MoE on odd layers
    specs = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(kind=kind, ffn=ffn))
    return tuple(specs)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536,
        unit=_unit(),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        tie_embeddings=False, subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128))

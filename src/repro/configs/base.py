"""Model/run configuration schema shared by all assigned architectures.

A model is a stack of repeating *units* (tuples of LayerSpec) so that
heterogeneous stacks (jamba's 1:7 attention:mamba interleave, gemma2's
local/global alternation, xlstm's sLSTM/mLSTM mix) all lower through ONE
``lax.scan`` over stacked unit parameters — critical for compile time and
HLO size at 64-layer scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"           # attn | mamba | slstm | mlstm
    attn_type: str = "global"    # global | local (sliding window)
    ffn: str = "dense"           # dense | moe | none


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0     # block up-projection (replaces d_ff)
    chunk: int = 64              # mLSTM chunkwise-parallel chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # moe | dense | ssm | audio | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    unit: Tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0            # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rotary_fraction: float = 1.0   # glm4 rotates half the head dim
    attn_softcap: float = 0.0      # gemma2: 50.0
    logit_softcap: float = 0.0     # gemma2: 30.0
    sliding_window: int = 4096
    attn_impl: str = "chunked"     # ref | chunked | pallas

    # structure
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"         # none | vision | audio (stub embeddings)
    frontend_dim: int = 0          # width of precomputed stub embeddings
    frontend_len: int = 0          # number of prefix embeddings
    tie_embeddings: bool = True
    scale_embed: bool = False      # gemma: embed * sqrt(d_model)
    act: str = "silu"              # silu | gelu
    norm_eps: float = 1e-6

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_dtype: str = "bfloat16"     # bfloat16 | int8 (quantized KV cache)

    # accounting mode: fully unroll every lax.scan so compiled.cost_analysis
    # counts all iterations (XLA prices a while body exactly once; the
    # dry-run extrapolates unit costs from 1- and 2-unit unrolled builds)
    unroll_scans: bool = False
    attn_chunk: int = 1024         # KV chunk for the online-softmax scan
    decode_chunk: int = 2048       # KV chunk when S_q == 1 (peak-temp knob)
    mamba_chunk: int = 256         # selective-scan chunk length

    # capability flags (see DESIGN.md §Arch-applicability)
    subquadratic: bool = False     # may run long_500k
    has_decoder: bool = True       # encoder-only archs skip decode shapes

    def __post_init__(self):
        if self.n_layers % len(self.unit) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"unit length {len(self.unit)}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding table padded to a multiple of 256 so the vocab dim
        shards on any production mesh axis (granite's 49155 and seamless's
        256206 are otherwise unshardable -> logits replicate -> 67+ GiB of
        temp per device).  Pad logits are masked to -inf in unembed()."""
        return -(-self.vocab // 256) * 256

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + stacked units)."""
        D, H, KV, hd = (self.d_model, self.n_heads, self.n_kv_heads,
                        self.resolved_head_dim)
        embed = self.vocab * D  # embed
        if not self.tie_embeddings:
            embed += self.vocab * D
        total = 0
        for spec in self.unit:
            if spec.kind == "attn":
                total += D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
            elif spec.kind == "mamba":
                m = self.mamba or MambaConfig()
                d_in = m.expand * D
                dt_rank = m.dt_rank or -(-D // 16)
                total += (D * 2 * d_in + d_in * m.d_conv
                          + d_in * (dt_rank + 2 * m.d_state)
                          + dt_rank * d_in + d_in * m.d_state + d_in
                          + d_in * D)
            elif spec.kind in ("slstm", "mlstm"):
                x = self.xlstm or XLSTMConfig()
                d_in = int(x.proj_factor * D)
                total += 2 * D * d_in + 4 * d_in * d_in + d_in * D
            if spec.ffn == "dense":
                total += 3 * D * self.d_ff
            elif spec.ffn == "moe":
                assert self.moe is not None
                total += D * self.moe.n_experts  # router
                total += self.moe.n_experts * 3 * D * self.moe.d_ff
        total = total * self.n_units + embed
        if self.enc_dec:
            # encoder layers (self-attn + dense ffn) + decoder cross-attn
            enc = self.n_enc_layers * (4 * D * (H * hd) + 3 * D * self.d_ff)
            cross = self.n_layers * 4 * D * (H * hd)
            total += enc + cross
        return total

    @property
    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.n_params
        moe_layers = sum(1 for s in self.unit if s.ffn == "moe") * self.n_units
        unused = (self.moe.n_experts - self.moe.top_k) * 3 * \
            self.d_model * self.moe.d_ff
        return self.n_params - moe_layers * unused


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: (arch x input shape)."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig):
    """The shape cells an architecture actually runs (skips documented
    in DESIGN.md §Arch-applicability)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.has_decoder:
        out.append(SHAPES["decode_32k"])
        if cfg.subquadratic:
            out.append(SHAPES["long_500k"])
    return out

"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, per-expert d_ff=768.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=768, vocab=151936,
        unit=(LayerSpec(kind="attn", ffn="moe"),),
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
        rope_theta=1e6, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=96, vocab=512, moe=MoEConfig(n_experts=8, top_k=2, d_ff=96))

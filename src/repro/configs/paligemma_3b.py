"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216; SigLIP patch embeddings are a STUB prefix supplied by
input_specs().  [arXiv:2407.07726; hf]"""
import dataclasses

from .base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab=257216,
        unit=(LayerSpec(kind="attn", ffn="dense"),),
        frontend="vision", frontend_dim=1152, frontend_len=256,
        scale_embed=True, tie_embeddings=True, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=512, frontend_dim=32, frontend_len=8)

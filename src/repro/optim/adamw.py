"""AdamW + schedules + gradient clipping/compression, pure JAX.

Optimizer state inherits the parameter sharding (same tree structure), so
FSDP splits m/v with the weights.  Optional gradient compression (bf16 or
int8 with error feedback) reduces reduce-scatter wire bytes — one of the
distributed-optimization levers recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # gradient compression: none | bf16 | int8_ef
    compression: str = "none"


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compression == "int8_ef":
        state["ef"] = jax.tree_util.tree_map(zeros, params)
    return state


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def compress_grads(cfg: AdamWConfig, grads, state):
    """Apply the configured wire-format reduction to gradients."""
    if cfg.compression == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads), state
    if cfg.compression == "int8_ef":
        def q(g, e):
            g = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
            qg = jnp.round(g / scale).astype(jnp.int8)
            deq = qg.astype(jnp.float32) * scale
            return deq, g - deq

        pairs = jax.tree_util.tree_map(q, grads, state["ef"])
        deq = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        ef = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                    is_leaf=lambda x: isinstance(x, tuple))
        state = dict(state)
        state["ef"] = ef
        return deq, state
    return grads, state


def update(cfg: AdamWConfig, grads, state, params):
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        norm = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (norm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    grads, state = compress_grads(cfg, grads, state)

    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(state)
    new_state.update({"m": new_m, "v": new_v, "step": step})
    return new_params, new_state, {"lr": lr, "grad_norm": _global_norm(grads)}

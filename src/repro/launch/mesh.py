"""Production mesh definitions.

Kept as FUNCTIONS so importing this module never touches jax device state
(jax locks the device count on first backend initialization; dryrun.py must
set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests/examples): 1xN 'data','model'."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (roofline targets; the container runs on CPU)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

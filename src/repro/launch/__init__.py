"""Launchers (train / serve / dryrun / report).

Kept import-light: launching modules set XLA flags before jax backend
initialization, so nothing here may touch device state at import time.
"""

"""Assemble the §Dry-run / §Roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

ORDER = ["qwen3_moe_30b_a3b", "granite_moe_3b_a800m", "qwen15_32b",
         "glm4_9b", "llama3_8b", "gemma2_9b", "xlstm_125m",
         "seamless_m4t_medium", "jamba_v01_52b", "paligemma_3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    rows = []
    for arch in ORDER:
        for shape in SHAPES:
            p = DRYRUN / f"{arch}_{shape}_{mesh}.json"
            if p.exists():
                rows.append(json.load(open(p)))
    return rows


def fmt_bytes(x):
    if x is None:
        return "-"
    return f"{x / 2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = load(mesh)
    out = ["| arch | shape | args GiB/dev | temp GiB/dev | fits 16G | "
           "kv | collective ops/step | lower+compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory"]
        total = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)
        fits = "yes" if total <= 16 * 2**30 else "NO"
        nc = sum(r["collectives"]["counts"].values())
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_bytes(m['argument_bytes'])}"
            f" | {fmt_bytes(m['temp_bytes'])} | {fits} | {r['kv_dtype']} |"
            f" {nc} | {r['t_lower_s']}+{r['t_compile_s']} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    rows = load(mesh)
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS/HLO | what would move the bottleneck |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        ratio = r["useful_flops_ratio"]
        hint = _hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['dominant'].replace('_s', '')} | "
            f"{ratio:.3f} | {hint} |")
    return "\n".join(out)


def _hint(r) -> str:
    dom = r["roofline"]["dominant"]
    mode = r["mode"]
    if dom == "memory_s":
        if mode == "train":
            return ("less remat recompute traffic (checkpoint dots policy) "
                    "or fp8/bf16 master weights")
        if mode == "decode":
            return "int8/grouped KV reads; fuse dequant into attention"
        return "larger attention chunks; fuse softcap into the matmul"
    if dom == "collective_s":
        if mode == "train":
            return ("overlap FSDP all-gathers with compute; reduce-scatter "
                    "in bf16; bigger per-axis shards")
        return "shard KV over fewer axes; replicate small weights"
    return "increase arithmetic intensity (larger tiles / batch)"


def main():
    print("# Dry-run + roofline report (auto-generated)\n")
    for mesh, label in (("sp", "single pod 16x16 = 256 chips"),
                        ("mp", "multi-pod 2x16x16 = 512 chips")):
        rows = load(mesh)
        if not rows:
            continue
        print(f"\n## Mesh: {label} — {len(rows)} cells\n")
        print("### Dry-run (memory / collectives)\n")
        print(dryrun_table(mesh))
        print("\n### Roofline terms (per train/prefill/decode step)\n")
        print(roofline_table(mesh))


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Writes one JSON per cell under experiments/dryrun/.
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax  # noqa: initialize jax right after the XLA flags above

from repro.configs import ALIASES, get_config, shapes_for, SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch.steps import build_cell, cell_model_config
from repro.parallel.sharding import ShardingRules

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[sufb]\w?\d+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in an HLO result-type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by each collective kind (output-shape proxy)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLLECTIVES:
            # match e.g. "bf16[8,128]{1,0} all-gather(" but not fusions
            m = re.match(r"^\(?[^()]*\)?\s*" + kind + r"[\.\d]*\(", rhs)
            if m:
                out[kind] += _shape_bytes(rhs[:m.end()])
                counts[kind] += 1
                break
    return out, counts


def roofline(flops_per_device: float, bytes_per_device: float,
             coll_bytes_per_device: float, n_chips: int):
    compute_t = flops_per_device / mesh_lib.PEAK_FLOPS_BF16
    memory_t = bytes_per_device / mesh_lib.HBM_BW
    collective_t = coll_bytes_per_device / mesh_lib.ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = {k: (v / bound if bound else 0.0) for k, v in terms.items()}
    return {**terms, "dominant": dom, "roofline_fraction_of_dominant": frac}


def _compile_and_account(cfg, shape, mesh, rules_overrides):
    """Compile one program; return (compiled, flops, bytes, coll, counts)."""
    rules = None
    if rules_overrides:
        rules = ShardingRules(mesh=mesh, cfg=cell_model_config(cfg, shape),
                              **rules_overrides)
    cell = build_cell(cfg, shape, mesh, rules=rules)
    compiled = cell.lower().compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll, counts = collective_bytes(hlo)
    return (compiled, float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll, counts)


def _accounting_cfgs(cfg, shape):
    """1-unit and 2-unit fully-unrolled builds (+ seq reduction for the
    sequential-scan xlstm blocks, whose cost is strictly linear in S)."""
    import dataclasses as dc
    u = len(cfg.unit)
    seq_scale = 1.0
    shape1 = shape2 = shape
    if cfg.family == "ssm" and shape.mode != "decode":
        from repro.configs.base import ShapeConfig
        s_acc = min(shape.seq_len, 256)
        seq_scale = shape.seq_len / s_acc
        shape1 = shape2 = ShapeConfig(shape.name, s_acc, shape.global_batch,
                                      shape.mode)
    enc1 = min(cfg.n_enc_layers, 1) if cfg.enc_dec else 0
    enc2 = min(cfg.n_enc_layers, 2) if cfg.enc_dec else 0
    # cap inner-scan unroll lengths (mamba chunks) so accounting builds of
    # hybrid stacks compile in minutes, not hours
    mamba_chunk = max(256, shape1.seq_len // 4)
    cfg1 = dc.replace(cfg, n_layers=u, n_enc_layers=enc1, unroll_scans=True,
                      mamba_chunk=mamba_chunk)
    cfg2 = dc.replace(cfg, n_layers=2 * u, n_enc_layers=enc2,
                      unroll_scans=True, mamba_chunk=mamba_chunk)
    return (cfg1, shape1), (cfg2, shape2), seq_scale


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             write: bool = True, rules_overrides=None, tag: str = ""):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = None
    if rules_overrides:
        rules = ShardingRules(mesh=mesh, cfg=cell_model_config(cfg, shape),
                              **rules_overrides)

    # 1. the deployment program: full scan (proves sharding + memory fit)
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, rules=rules)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()

    # 2. accounting programs: XLA prices while bodies once, so derive
    #    per-unit costs from 1- and 2-unit unrolled builds and extrapolate.
    (cfg1, shape1), (cfg2, shape2), seq_scale = _accounting_cfgs(cfg, shape)
    _, f1, b1, c1, n1 = _compile_and_account(cfg1, shape1, mesh,
                                             rules_overrides)
    _, f2, b2, c2, n2 = _compile_and_account(cfg2, shape2, mesh,
                                             rules_overrides)
    reps = cfg.n_units  # unit multiplicity in the deployment program

    def extrap(x1, x2):
        unit = max(0.0, x2 - x1)
        return (x1 + (reps - 1) * unit) * seq_scale

    flops = extrap(f1, f2)
    bytes_accessed = extrap(b1, b2)
    coll = {k: extrap(c1[k], c2[k]) for k in c1}
    coll_counts = {k: int(extrap(n1[k], n2[k])) for k in n1}
    total_coll = float(sum(coll.values()))

    mcfg = cell_model_config(cfg, shape)
    if shape.mode == "train":
        model_flops = 6 * mcfg.n_active_params * shape.global_batch * \
            shape.seq_len
    elif shape.mode == "prefill":
        model_flops = 2 * mcfg.n_active_params * shape.global_batch * \
            shape.seq_len
    else:
        model_flops = 2 * mcfg.n_active_params * shape.global_batch

    rf = roofline(flops, bytes_accessed, total_coll, n_chips)
    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_chips": n_chips,
        "mode": shape.mode,
        "kv_dtype": mcfg.kv_dtype,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem,
                                            "generated_code_size_in_bytes",
                                            None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {"flops_per_device": flops,
                 "bytes_accessed_per_device": bytes_accessed},
        "collectives": {"bytes_per_device": coll, "counts": coll_counts,
                        "total_bytes_per_device": total_coll},
        "roofline": rf,
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flops_ratio":
            (model_flops / n_chips) / flops if flops else None,
        "accounting": {"flops_1unit": f1, "flops_2unit": f2,
                       "bytes_1unit": b1, "bytes_2unit": b2,
                       "seq_scale": seq_scale, "unit_reps": reps},
        "tag": tag,
    }
    if write:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        name = f"{ALIASES.get(arch, arch)}_{shape_name}_" + \
            ("mp" if multi_pod else "sp") + suffix + ".json"
        (OUT_DIR / name).write_text(json.dumps(report, indent=1))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list(ALIASES) if args.all or not args.arch else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shape_names = ([args.shape] if args.shape
                       else [s.name for s in shapes_for(cfg)])
        for sn in shape_names:
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, sn, mp))

    failures = []
    for arch, sn, mp in cells:
        name = f"{ALIASES.get(arch, arch)}_{sn}_" + ("mp" if mp else "sp")
        if args.skip_existing and (OUT_DIR / f"{name}.json").exists():
            print(f"[skip] {name}")
            continue
        print(f"[dryrun] {name} ...", flush=True)
        try:
            rep = run_cell(arch, sn, mp)
            rf = rep["roofline"]
            print(f"  ok: compute={rf['compute_s']:.4f}s "
                  f"memory={rf['memory_s']:.4f}s "
                  f"collective={rf['collective_s']:.4f}s "
                  f"dominant={rf['dominant']} "
                  f"(lower {rep['t_lower_s']}s compile {rep['t_compile_s']}s)",
                  flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures.append((name, repr(e)))
            print(f"  FAIL {name}: {e}", flush=True)
            traceback.print_exc()
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells passed")
    if failures:
        for n, e in failures:
            print(f"  FAILED: {n}: {e[:200]}")
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Step builders + abstract input specs for every (arch x shape) cell.

``train_step`` (train_4k), ``prefill_step`` (prefill_32k) and
``decode_step`` (decode_32k / long_500k) are the three programs the
dry-run lowers and the launcher runs.  All inputs can be
ShapeDtypeStructs (no allocation) — the same pattern the real launcher
uses with concrete arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model, build_model
from repro.optim import adamw
from repro.parallel.sharding import ShardingRules


# ---------------------------------------------------------------------------
# Cell = (arch config, shape config) + numeric policy decisions
# ---------------------------------------------------------------------------

def cell_model_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Per-cell numeric policy: int8 KV where bf16 cannot fit 16 GB/chip
    (see EXPERIMENTS.md §Dry-run for the arithmetic)."""
    if shape.is_decode and cfg.name == "qwen1.5-32b":
        return dataclasses.replace(cfg, kv_dtype="int8")
    return cfg


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.frontend != "none":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    return specs


def abstract_cache(model: Model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                model: Optional[Model] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = cell_model_config(cfg, shape)
    model = model or build_model(cfg)
    if shape.mode == "train":
        return {"batch": abstract_batch(cfg, shape)}
    if shape.mode == "prefill":
        B, S = shape.global_batch, shape.seq_len
        total = S + (cfg.frontend_len if cfg.frontend != "none"
                     and not cfg.enc_dec else 0)  # vision/audio prefix
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
               "cache": abstract_cache(model, B, total)}
        if cfg.frontend != "none":
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        return out
    # decode: one new token against a cache of seq_len
    B, L = shape.global_batch, shape.seq_len
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": abstract_cache(model, B, L)}


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        params, opt_state, info = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **info}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, cache, frontend_embeds=None):
        return model.prefill(params, tokens, cache, frontend_embeds)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, token, cache):
        return model.decode_step(params, token, cache)

    return decode_step


# ---------------------------------------------------------------------------
# Jitted, sharded programs for one cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellProgram:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: ShardingRules
    jitted: Any          # the jit-wrapped step
    args: Tuple          # ShapeDtypeStructs (or concrete arrays) to lower with
    mode: str

    def lower(self):
        with self.mesh:
            return self.jitted.lower(*self.args)


def _sds_with(tree_specs, shardings):
    """Attach shardings to a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_specs, shardings)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opt_cfg: Optional[adamw.AdamWConfig] = None,
               rules: Optional[ShardingRules] = None,
               remat: bool = True) -> CellProgram:
    cfg = cell_model_config(cfg, shape)
    model = build_model(cfg)
    rules = rules or ShardingRules(mesh=mesh, cfg=cfg)
    # sequence-shard the residual stream only when training (decode S=1;
    # prefill activations are transient, batch sharding suffices)
    model.hints = rules.activation_hints(
        shape.global_batch, shape.seq_len,
        use_seq_sharding=(shape.mode == "train"))
    aparams = model.abstract_params()
    pspecs = rules.params_pspecs(aparams)
    pshard = rules.to_named(pspecs)

    if shape.mode == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        aopt = jax.eval_shape(functools.partial(adamw.init_state, opt_cfg),
                              aparams)
        # m/v/ef inherit the param spec; scalars replicated
        ospecs = {
            k: (pspecs if k in ("m", "v", "ef") else P())
            for k in aopt
        }
        oshard = rules.to_named(ospecs)
        abatch = abstract_batch(cfg, shape)
        bshard = rules.to_named(rules.batch_pspecs(abatch))
        step = make_train_step(model, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        args = (_sds_with(aparams, pshard), _sds_with(aopt, oshard),
                _sds_with(abatch, bshard))
        return CellProgram(cfg, shape, mesh, rules, jitted, args, "train")

    if shape.mode == "prefill":
        B, S = shape.global_batch, shape.seq_len
        total = S + (cfg.frontend_len if cfg.frontend != "none"
                     and not cfg.enc_dec else 0)  # vision/audio prefix
        acache = abstract_cache(model, B, total)
        cshard = rules.to_named(rules.cache_pspecs(acache))
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        bspec = rules.batch_spec(B)
        tshard = NamedSharding(mesh, P(
            bspec if bspec and len(bspec) > 1 else
            (bspec[0] if bspec else None), None))
        step = make_prefill_step(model)
        if cfg.frontend != "none":
            fe = jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.frontend_dim),
                                      jnp.float32)
            bax = bspec if bspec and len(bspec) > 1 else (
                bspec[0] if bspec else None)
            fshard = NamedSharding(mesh, P(bax, None, None))
            jitted = jax.jit(step,
                             in_shardings=(pshard, tshard, cshard, fshard),
                             out_shardings=(None, cshard),
                             donate_argnums=(2,))
            args = (_sds_with(aparams, pshard), tok, acache, fe)
        else:
            jitted = jax.jit(step, in_shardings=(pshard, tshard, cshard),
                             out_shardings=(None, cshard),
                             donate_argnums=(2,))
            args = (_sds_with(aparams, pshard), tok, acache)
        return CellProgram(cfg, shape, mesh, rules, jitted, args, "prefill")

    # decode
    B, L = shape.global_batch, shape.seq_len
    acache = abstract_cache(model, B, L)
    cshard = rules.to_named(rules.cache_pspecs(acache))
    bspec = rules.batch_spec(B)
    tshard = NamedSharding(mesh, P(
        bspec if bspec and len(bspec) > 1 else
        (bspec[0] if bspec else None), None))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    step = make_decode_step(model)
    jitted = jax.jit(step, in_shardings=(pshard, tshard, cshard),
                     out_shardings=(None, cshard), donate_argnums=(2,))
    args = (_sds_with(aparams, pshard), tok, acache)
    return CellProgram(cfg, shape, mesh, rules, jitted, args, "decode")

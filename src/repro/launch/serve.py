"""Batched serving launcher with PMwCAS-style KV-slot admission.

Continuous batching: requests arrive with prompt lengths; admission
reserves per-request KV-cache pages through the batched deterministic
MwCAS primitive (repro.pmwcas.reserve_slots) — the TPU-native analogue of
the paper's multi-word reservation (all pages of a request are granted
atomically or not at all, with index order as the linearization).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 12 --steps 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.pmwcas import reserve_slots


class PageAllocator:
    """KV-page table driven by batched MwCAS reservations."""

    def __init__(self, n_pages: int):
        self.free = jnp.ones(n_pages, jnp.uint32)
        self.n_pages = n_pages

    def admit(self, page_requests: np.ndarray):
        """page_requests: int32[B, K] candidate page ids (<0 pad).
        Returns granted: bool[B] — atomically all-or-nothing per request."""
        self.free, granted = reserve_slots(
            self.free, jnp.asarray(page_requests, jnp.int32))
        return np.asarray(granted)

    def release(self, pages):
        self.free = self.free.at[jnp.asarray(pages)].set(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    alloc = PageAllocator(args.n_pages)

    rng = np.random.default_rng(0)
    pages_per_req = -(-(args.prompt_len + args.steps) // args.page_size)
    # all requests propose pages simultaneously; MwCAS admission resolves
    reqs = np.full((args.requests, pages_per_req), -1, np.int32)
    cursor = 0
    for i in range(args.requests):
        reqs[i] = np.arange(cursor, cursor + pages_per_req) % args.n_pages
        cursor += rng.integers(1, pages_per_req + 1)  # contended proposals
    granted = alloc.admit(reqs)
    admitted = np.nonzero(granted)[0]
    print(f"admitted {len(admitted)}/{args.requests} requests "
          f"(atomic page-group grants, zero partial allocations)")
    if len(admitted) == 0:
        return

    B = len(admitted)
    total = args.prompt_len + args.steps
    tokens = rng.integers(0, cfg.vocab, (B, args.prompt_len)).astype(np.int32)
    cache = model.init_cache(B, total + cfg.frontend_len)
    fe = (0.02 * np.ones((B, cfg.frontend_len, cfg.frontend_dim), np.float32)
          if cfg.frontend != "none" else None)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    if fe is not None:
        logits, cache = prefill(params, jnp.asarray(tokens), cache,
                                jnp.asarray(fe))
    else:
        logits, cache = prefill(params, jnp.asarray(tokens), cache)
    out = []
    for _ in range(args.steps):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(np.asarray(nxt))
        logits, cache = decode(params, nxt, cache)
    gen = np.concatenate(out, axis=1)
    print(f"generated {gen.shape} tokens for {B} admitted requests; "
          f"sample row: {gen[0][:8].tolist()}")


if __name__ == "__main__":
    main()

"""Training launcher.

Local run (CPU, smoke config):
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50

Production lowering uses the same builder the dry-run proves
(``repro.launch.steps.build_cell``); on a real cluster this binary runs
once per host with jax.distributed initialized by the pod runtime.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.synthetic import DataConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="inject a crash (fault-tolerance demos)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps, weight_decay=0.0)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_async=args.ckpt_async, ckpt_dir=args.ckpt_dir)
    trainer = Trainer(model, opt_cfg, data_cfg, tcfg)
    params, opt, losses = trainer.run(crash_at_step=args.crash_at_step)
    print(f"steps={len(losses)} first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f} stragglers={trainer.stragglers}")
    return losses


if __name__ == "__main__":
    main()

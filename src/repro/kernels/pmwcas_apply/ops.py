"""Jit'd public op: batched MwCAS apply against a word table.

Gather + scatter stay in XLA (they are memory-layout operations XLA
already emits optimally); the Pallas kernel resolves conflicts.  On this
CPU container the kernel runs in interpret mode; on TPU set
``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import pmwcas_success_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def pmwcas_apply(words, addr, exp, des, *, use_kernel: bool = True,
                 interpret: bool = True):
    """words: uint32[W]; addr int32[B,K] (<0 pad); exp/des uint32[B,K].
    Returns (new_words, success[B])."""
    cur = words[jnp.maximum(addr, 0)]
    if use_kernel:
        success = pmwcas_success_pallas(addr, cur, exp, interpret=interpret)
    else:
        success = ref.pmwcas_success(addr, cur, exp)
    valid = (addr >= 0) & success[:, None]
    flat_addr = jnp.where(valid, addr, words.shape[0]).reshape(-1)
    new = jnp.concatenate([words, jnp.zeros((1,), words.dtype)])
    new = new.at[flat_addr].set(
        jnp.where(valid.reshape(-1), des.reshape(-1), new[flat_addr]))
    return new[:-1], success


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"),
                   donate_argnums=(0,))
def pmwcas_apply_stacked(words, addr, exp, des, *, use_kernel: bool = True,
                         interpret: bool = True):
    """S shard rounds in ONE dispatch: vmap of :func:`pmwcas_apply`.

    words: uint32[S, W] stacked shard word tables; addr int32[S, B, K]
    (<0 pad); exp/des uint32[S, B, K].  Returns
    ``(new_words[S, W], success[S, B])``.

    ``words`` is DONATED: callers pass a freshly stacked temporary (the
    per-shard tables are untouched) and XLA reuses its buffer for the
    output — the stacked service dispatch would otherwise hold two full
    copies of every shard table per wave.  Like every jitted entry
    point this retraces per shape; the service keeps the shapes it
    feeds BUCKETED (``[S, B_cap, K_pow2]``) so steady-state waves hit
    the trace cache instead of recompiling.
    """
    def one_shard(w, a, e, d):
        return pmwcas_apply(w, a, e, d, use_kernel=use_kernel,
                            interpret=interpret)

    return jax.vmap(one_shard)(words, addr, exp, des)


def reserve_slots(free_mask, requests, *, use_kernel: bool = True,
                  interpret: bool = True):
    """KV-cache slot reservation for the serving layer: request i atomically
    claims `requests[i]` slots (a K-word MwCAS on a free-bitmap word table).

    free_mask: uint32[W] (1 = free); requests: int32[B, K] candidate slot ids
    (<0 pad).  Returns (new_mask, granted[B]).

    Semantics corner cases (asserted kernel == ref in tests):
    - duplicate slot ids within one request claim the slot once and still
      grant the request;
    - an all-padded request is vacuously granted (claims nothing);
    - overlapping requests are linearized by batch index (lower wins).
    """
    B, K = requests.shape
    exp = jnp.ones((B, K), jnp.uint32)    # expect free
    des = jnp.zeros((B, K), jnp.uint32)   # claim
    return pmwcas_apply(free_mask, requests, exp, des,
                        use_kernel=use_kernel, interpret=interpret)

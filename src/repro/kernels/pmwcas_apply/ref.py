"""Pure-jnp oracle for the batched deterministic MwCAS primitive.

Semantics ("conservative one-shot", DESIGN.md Sec. 2.2): descriptor i
succeeds iff
  (a) every target's current value equals its expected value, and
  (b) for every target address, no lower-index descriptor that also
      passes (a) targets the same address (index order = linearization,
      the TPU-native replacement for embed-order).
Each address is written at most once per batch; losers retry next round
(the batched analogue of a failed CAS).  Padded slots have address < 0.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pmwcas_success(addr, cur, exp):
    """addr: int32[B,K] (<0 = padding), cur/exp: uint32[B,K] -> bool[B]."""
    B, K = addr.shape
    valid = addr >= 0
    slot_pass = jnp.where(valid, cur == exp, True)
    row_pass = slot_pass.all(axis=1)                          # (a)

    fa = addr.reshape(B * K)
    fvalid = valid.reshape(B * K)
    fpass = jnp.repeat(row_pass, K)                            # row (a) per slot
    idx = jnp.repeat(jnp.arange(B), K)

    same = (fa[:, None] == fa[None, :]) & fvalid[:, None] & fvalid[None, :]
    lower = idx[None, :] < idx[:, None]
    lose = (same & lower & fpass[None, :]).any(axis=1)         # (b)
    row_lose = lose.reshape(B, K).any(axis=1)
    return row_pass & ~row_lose


def pmwcas_apply(words, addr, exp, des):
    """Apply a batch of descriptors against a word table.

    Returns (new_words, success[B]).  Winners' desired values are written;
    by construction no address is written twice.
    """
    success = pmwcas_success(addr, words[jnp.maximum(addr, 0)], exp)
    valid = (addr >= 0) & success[:, None]
    flat_addr = jnp.where(valid, addr, words.shape[0]).reshape(-1)
    flat_des = des.reshape(-1)
    new = jnp.concatenate([words, jnp.zeros((1,), words.dtype)])
    new = new.at[flat_addr].set(jnp.where(valid.reshape(-1), flat_des,
                                          new[flat_addr]))
    return new[:-1], success


def sequential_oracle(words, addr, exp, des):
    """True sequential one-touch application (numpy).  The conservative
    parallel semantics must be a SUBSET of these successes, and must agree
    wherever it succeeds."""
    words = np.asarray(words).copy()
    B, K = addr.shape
    touched = set()
    success = np.zeros(B, bool)
    for i in range(B):
        tgts = [(int(addr[i, k]), int(exp[i, k]), int(des[i, k]))
                for k in range(K) if addr[i, k] >= 0]
        if any(a in touched for a, _, _ in tgts):
            continue
        if all(words[a] == e for a, e, _ in tgts):
            for a, _, d in tgts:
                words[a] = d
                touched.add(a)
            success[i] = True
    return words, success

from . import ops, ref
from .kernel import pmwcas_success_pallas
from .ops import pmwcas_apply, reserve_slots

"""Pallas TPU kernel: batched deterministic MwCAS conflict resolution.

The CPU paper resolves conflicts with CAS retry loops under cache
coherence; the TPU has neither CAS nor coherence, so the adaptation
(DESIGN.md Sec. 2.2) turns one *batch* of descriptors into a wait-free,
deterministic verdict: descriptor i succeeds iff all its expected values
match and no lower-index matching descriptor claims any of its target
addresses.  The O(B^2 K^2) pairwise address comparison is VPU-shaped:
tiles of the (slot x slot) boolean matrix evaluated in VMEM, accumulated
over the j-tile grid dimension.

Layout: addr/cur/exp are [B, K] (K static, small); B tiled by TB rows.
Grid = (B/TB, B/TB); scratch holds the per-row "lose" accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(addr_i, cur_i, exp_i, addr_j, cur_j, exp_j, gi0, gj0,
            success_ref, lose_ref, *, TB: int, K: int, n_j: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        lose_ref[...] = jnp.zeros_like(lose_ref)

    ai = addr_i[...]                       # [TB, K] int32
    aj = addr_j[...]
    valid_i = ai >= 0
    valid_j = aj >= 0
    pass_i = jnp.where(valid_i, cur_i[...] == exp_i[...], True).all(axis=1)
    pass_j = jnp.where(valid_j, cur_j[...] == exp_j[...], True).all(axis=1)

    # pairwise same-address test over slots: [TB*K, TB*K]
    fa_i = ai.reshape(TB * K, 1)
    fa_j = aj.reshape(1, TB * K)
    same = (fa_i == fa_j) & valid_i.reshape(TB * K, 1) \
        & valid_j.reshape(1, TB * K)

    # linearization: only LOWER global row index beats us
    rows_i = gi0[0] + jax.lax.broadcasted_iota(jnp.int32, (TB, K), 0)
    rows_j = gj0[0] + jax.lax.broadcasted_iota(jnp.int32, (TB, K), 0)
    lower = rows_j.reshape(1, TB * K) < rows_i.reshape(TB * K, 1)
    passj_slots = jnp.repeat(pass_j, K).reshape(1, TB * K)

    lose_slots = (same & lower & passj_slots).any(axis=1)       # [TB*K]
    lose_rows = lose_slots.reshape(TB, K).any(axis=1)
    lose_ref[...] = lose_ref[...] | lose_rows

    @pl.when(tj == n_j - 1)
    def _finalize():
        success_ref[...] = pass_i & ~lose_ref[...]


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def pmwcas_success_pallas(addr, cur, exp, *, tb: int = 128,
                          interpret: bool = True):
    """addr: int32[B,K] (<0 pad), cur/exp: uint32[B,K] -> bool[B]."""
    B, K = addr.shape
    TB = min(tb, B)
    pad = (-B) % TB
    if pad:
        addr = jnp.pad(addr, ((0, pad), (0, 0)), constant_values=-1)
        cur = jnp.pad(cur, ((0, pad), (0, 0)))
        exp = jnp.pad(exp, ((0, pad), (0, 0)))
    Bp = B + pad
    n = Bp // TB
    row0 = jnp.arange(n, dtype=jnp.int32) * TB                  # tile bases

    grid = (n, n)
    out = pl.pallas_call(
        functools.partial(_kernel, TB=TB, K=K, n_j=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TB, K), lambda i, j: (i, 0)),   # addr_i
            pl.BlockSpec((TB, K), lambda i, j: (i, 0)),   # cur_i
            pl.BlockSpec((TB, K), lambda i, j: (i, 0)),   # exp_i
            pl.BlockSpec((TB, K), lambda i, j: (j, 0)),   # addr_j
            pl.BlockSpec((TB, K), lambda i, j: (j, 0)),   # cur_j
            pl.BlockSpec((TB, K), lambda i, j: (j, 0)),   # exp_j
            pl.BlockSpec((1,), lambda i, j: (i,)),        # gi0
            pl.BlockSpec((1,), lambda i, j: (j,)),        # gj0
        ],
        out_specs=pl.BlockSpec((TB,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((TB,), jnp.bool_)],
        interpret=interpret,
    )(addr, cur, exp, addr, cur, exp, row0, row0)
    return out[:B]

"""Pallas kernels (TPU-native adaptations; interpret mode on CPU).

- ``pmwcas_apply``   batched deterministic MwCAS conflict resolution
- ``flash_attention``  fused attention for the model stack

Import the public entry points from :mod:`repro.pmwcas` (MwCAS) or
:mod:`repro.models.attention` (attention); these modules are the
implementation layer.
"""

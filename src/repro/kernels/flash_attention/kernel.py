"""Pallas TPU flash-attention forward kernel.

Grid (heads, q_tiles, kv_tiles); online-softmax state (m, l, acc) lives in
VMEM scratch and persists across the kv_tiles (last, sequential) grid
dimension.  Tiles are MXU-aligned (q/kv tile 128-multiples, head_dim is
padded to 128 by ops.py when needed).  Supports causal masks, sliding
windows, gemma-style logit softcap and GQA via an index-map that maps the
flattened q-head index onto its kv head.

The jnp reference (ref.py / models.attention._sdpa_ref) is the oracle; the
kernel is validated in interpret mode across shape sweeps by
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 20


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
            window: int, attn_cap: float, n_k: int):
    tk = pl.program_id(2)

    @pl.when(tk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # [TQ, hd]
    k = k_ref[0]                                  # [TK, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if attn_cap > 0.0:
        s = jnp.tanh(s * (1.0 / attn_cap)) * attn_cap

    qp = qpos_ref[...]                            # [TQ] float32
    kp = kpos_ref[...]                            # [TK]
    ok = jnp.broadcast_to((kp < 2.0 ** 29)[None, :], s.shape)
    if causal:
        ok &= qp[:, None] >= kp[None, :]
    if window > 0:
        ok &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(tk == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "attn_cap", "tq", "tk",
                     "g", "interpret"))
def flash_attention_flat(q, k, v, q_pos, k_pos, *, scale: float,
                         causal: bool, window: int, attn_cap: float,
                         g: int, tq: int = 128, tk: int = 128,
                         interpret: bool = True):
    """q: [H, Sq, hd] (H = B*KV*G flattened), k/v: [HK, Sk, hd] with
    HK = B*KV; q head h reads kv head h // g."""
    H, Sq, hd = q.shape
    HK, Sk, _ = k.shape
    TQ, TK = min(tq, Sq), min(tk, Sk)
    pq, pk = (-Sq) % TQ, (-Sk) % TK
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=2.0 ** 30)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=2.0 ** 30)
    n_q, n_k = (Sq + pq) // TQ, (Sk + pk) // TK

    grid = (H, n_q, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          attn_cap=attn_cap, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TQ,), lambda h, i, j: (i,)),          # q_pos
            pl.BlockSpec((TK,), lambda h, i, j: (j,)),          # k_pos
            pl.BlockSpec((1, TQ, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, TK, hd), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, TK, hd), lambda h, i, j: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, TQ, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((TQ,), jnp.float32),       # m
            pltpu.VMEM((TQ,), jnp.float32),       # l
            pltpu.VMEM((TQ, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.float32), k_pos.astype(jnp.float32), q, k, v)
    return out[:, :Sq]

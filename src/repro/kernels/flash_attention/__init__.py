from . import ops, ref
from .kernel import flash_attention_flat
from .ops import flash_attention

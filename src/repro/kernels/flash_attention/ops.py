"""Jit wrapper exposing the kernel in the model's [B,KV,G,S,hd] layout
(the `attn_impl="pallas"` path of repro.models.attention)."""
from __future__ import annotations

from .kernel import flash_attention_flat

# interpret mode on this CPU container; flip to False on real TPU
INTERPRET = True


def flash_attention(q, k, v, q_pos, k_pos, *, causal, window, attn_cap,
                    scale, tq: int = 128, tk: int = 128):
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[2]
    qf = q.reshape(B * KV * G, Sq, hd)
    kf = k.reshape(B * KV, Sk, hd)
    vf = v.reshape(B * KV, Sk, hd)
    out = flash_attention_flat(
        qf, kf, vf, q_pos, k_pos, scale=float(scale), causal=bool(causal),
        window=int(window), attn_cap=float(attn_cap), g=G, tq=tq, tk=tk,
        interpret=INTERPRET)
    return out.reshape(B, KV, G, Sq, hd)

"""Pure-jnp oracle for the flash attention kernel: the materialized-scores
reference from the model layer (single source of truth)."""
from repro.models.attention import _sdpa_ref as sdpa_ref  # noqa: F401

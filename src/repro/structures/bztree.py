"""BzTree-style sorted-array node on the unified PMwCAS API.

One node = a metadata word plus a fixed array of key slots::

    word base           meta  = count | (FROZEN_BIT if frozen)
    word base + 1 + i   slot i (0 = unused; keys are appended in arrival
                        order, sorted on read — the BzTree recipe)

Mutations are single MwCAS ops, exactly the PMwCAS-mediated protocol of
Wang et al.'s BzTree transferred onto this repo's batch semantics:

- **insert**: one 2-word op ``[(meta, m, m+1), (slot[count], 0, key)]``.
  The meta word is simultaneously the reservation (the op claims slot
  ``count`` by incrementing the count) and the visibility switch (the
  key is only in-bounds once the count moved) — a torn insert is
  impossible because both words move atomically.  Note the meta target
  is literally increment-shaped, so node inserts shadow directly onto
  the simulator's benchmark workload.
- **freeze**: one 1-word op setting FROZEN_BIT; any in-flight insert
  compiled against the unfrozen meta loses its CAS (meta changed).
- **split**: freeze, then write BOTH half nodes with ONE wide MwCAS
  (all-or-nothing: no crash can leave one half visible), then the caller
  atomically swings a parent pointer with :func:`swap_pointer`.

A frozen node is immutable forever — readers passing through a stale
pointer still see a consistent (frozen) array, the BzTree argument for
why pointer installation can be a separate, later CAS.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.pmwcas import Backend, MwCASOp

FROZEN_BIT = 1 << 31
COUNT_MASK = FROZEN_BIT - 1

# insert statuses (strings shared in spirit with hashmap)
NODE_OK = "ok"
NODE_FULL = "full"
NODE_FROZEN = "frozen"
NODE_EXISTS = "exists"
NODE_EXHAUSTED = "exhausted"


class SplitError(RuntimeError):
    """The target region for a split half was not zeroed / got claimed."""


class SortedNode:
    """Fixed-capacity sorted-array node; all state lives in the backend."""

    def __init__(self, backend: Backend, base: int, capacity: int):
        if capacity < 2:
            raise ValueError("capacity must be >= 2 (split needs two halves)")
        self.backend = backend
        self.base = base
        self.capacity = capacity

    # -- layout ----------------------------------------------------------------
    @property
    def meta_addr(self) -> int:
        return self.base

    def slot_addr(self, i: int) -> int:
        return self.base + 1 + i

    @property
    def n_words(self) -> int:
        return 1 + self.capacity

    # -- reads -----------------------------------------------------------------
    def meta(self) -> int:
        return int(self.backend.read(self.meta_addr))

    @property
    def count(self) -> int:
        return self.meta() & COUNT_MASK

    @property
    def frozen(self) -> bool:
        return bool(self.meta() & FROZEN_BIT)

    def _slots_upto(self, n: int) -> List[int]:
        return [int(self.backend.read(self.slot_addr(i))) for i in range(n)]

    def raw_slots(self) -> List[int]:
        """Slots 0..count-1 in arrival (append) order."""
        return self._slots_upto(self.count)

    def keys(self) -> List[int]:
        """The sorted view (BzTree sorts the append area on read)."""
        return sorted(self.raw_slots())

    def search(self, key: int) -> bool:
        return key in self.raw_slots()

    # -- mutations -------------------------------------------------------------
    def compile_insert(self, key: int, meta: Optional[int] = None,
                       slots: Optional[List[int]] = None):
        """One insert -> one 2-word MwCASOp against the current meta.

        ``meta``/``slots`` let a round compile many inserts against one
        node snapshot (the HashMap.apply pattern — no per-op re-reads).
        Returns a status string instead when no op is needed/possible.
        """
        if not 0 < key < (1 << 31):
            raise ValueError(f"key {key} outside (0, 2^31)")
        m = self.meta() if meta is None else meta
        if m & FROZEN_BIT:
            return NODE_FROZEN
        count = m & COUNT_MASK
        if count >= self.capacity:
            return NODE_FULL
        if key in (self._slots_upto(count) if slots is None else slots):
            return NODE_EXISTS
        return MwCASOp([(self.meta_addr, m, m + 1),
                        (self.slot_addr(count), 0, key)])

    def insert(self, key: int, max_attempts: int = 8) -> str:
        """Lock-free insert: retry the 2-word CAS until a verdict."""
        for _ in range(max_attempts):
            compiled = self.compile_insert(key)
            if isinstance(compiled, str):
                return compiled
            (res,) = self.backend.execute([compiled])
            if res.success:
                return NODE_OK
        return NODE_EXHAUSTED

    def insert_batch(self, keys: List[int],
                     max_rounds: Optional[int] = None) -> List[str]:
        """Concurrent inserts into ONE node serialize: every round all
        pending ops target the same (meta, next-slot) pair, so exactly
        one wins per round — multi-node workloads are where node inserts
        parallelize.  Returns one status per key."""
        max_rounds = len(keys) + 1 if max_rounds is None else max_rounds
        status: List[Optional[str]] = [None] * len(keys)
        pending = list(range(len(keys)))
        for _ in range(max_rounds):
            if not pending:
                break
            m = self.meta()
            slots = self._slots_upto(m & COUNT_MASK)   # one read per round
            batch, owners, still = [], [], []
            for idx in pending:
                compiled = self.compile_insert(keys[idx], meta=m,
                                               slots=slots)
                if isinstance(compiled, str):
                    status[idx] = compiled
                else:
                    batch.append(compiled)
                    owners.append(idx)
            if not batch:
                pending = []
                break
            verdicts = self.backend.execute(batch)
            for pos, idx in enumerate(owners):
                if verdicts[pos].success:
                    status[idx] = NODE_OK
                else:
                    still.append(idx)
            pending = still
        for idx in pending:
            status[idx] = NODE_EXHAUSTED
        return status                      # type: ignore[return-value]

    def freeze(self, max_attempts: int = 8) -> int:
        """Set FROZEN_BIT (idempotent); returns the frozen meta word."""
        for _ in range(max_attempts):
            m = self.meta()
            if m & FROZEN_BIT:
                return m
            (res,) = self.backend.execute(
                [MwCASOp([(self.meta_addr, m, m | FROZEN_BIT)])])
            if res.success:
                return m | FROZEN_BIT
        raise RuntimeError("freeze lost its CAS repeatedly")

    def _node_image(self, base: int, keys: List[int]) -> List:
        targets = [(base, 0, len(keys))]
        targets += [(base + 1 + i, 0, k) for i, k in enumerate(keys)]
        return targets

    def split(self, left_base: int, right_base: int, *,
              extra_targets: Sequence[Tuple[int, int, int]] = ()
              ) -> Tuple["SortedNode", "SortedNode", int]:
        """Freeze, then materialize both halves with ONE wide MwCAS.

        The target regions must be zeroed, unclaimed words (use an
        allocator).  Returns (left, right, separator) where every key in
        ``right`` is >= separator.  The single wide op is the crash
        argument: either both halves exist completely or neither does,
        and the frozen original stays valid throughout.

        ``extra_targets`` are folded into the same wide MwCAS — the
        multi-node tree uses this to pre-publish its parent entry
        (separator + right-child words at an invisible append position)
        atomically with the half images (DESIGN.md Sec. 7).
        """
        self.freeze()
        ks = self.keys()
        if len(ks) < 2:
            raise SplitError("need >= 2 keys to split")
        mid = len(ks) // 2
        left_keys, right_keys = ks[:mid], ks[mid:]
        targets = (self._node_image(left_base, left_keys)
                   + self._node_image(right_base, right_keys)
                   + [tuple(t) for t in extra_targets])
        # canonical (address-sorted) embedding order: extra_targets may
        # sit below the half regions, and the simulator shadow replays
        # growth rounds verbatim
        (res,) = self.backend.execute([MwCASOp(targets).sorted()])
        if not res.success:
            raise SplitError(
                "split target region was not zeroed or is contended")
        return (type(self)(self.backend, left_base, self.capacity),
                type(self)(self.backend, right_base, self.capacity),
                right_keys[0])


def swap_pointer(backend: Backend, ptr_addr: int,
                 old_base: int, new_base: int) -> bool:
    """Atomically swing a node pointer word (split/consolidate install)."""
    (res,) = backend.execute([MwCASOp([(ptr_addr, old_base, new_base)])])
    return res.success


def read_pointer(backend: Backend, ptr_addr: int) -> int:
    return int(backend.read(ptr_addr))

"""YCSB-style workload compiler for the structures layer.

Turns an abstract (mix, skew, size) spec into the hash map's logical-op
vocabulary (:class:`repro.structures.KVOp`), batches it into rounds, and
exposes the kernel wire form (``ops_to_arrays``) of any round — the same
Zipfian-popularity machinery the simulator benchmark uses
(``generate_ops`` / paper Eq. 1), applied to keys instead of raw words.

Standard mixes are provided as :data:`YCSB_A` (50/50 read/update),
:data:`YCSB_B` (95/5), :data:`YCSB_C` (read-only), :data:`YCSB_E`
(scan-heavy — the range-index workload the multi-node tree exists for)
and an insert-heavy :data:`LOAD` phase, each a :class:`WorkloadSpec`
template to fork with ``dataclasses.replace``.

The compiled stream is structure-agnostic: the same :class:`KVOp` list
drives :class:`repro.structures.HashMap` and
:class:`repro.structures.BzTreeIndex` (``run_workload`` accepts either —
anything with the ``apply``/counter surface).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.pmwcas import MwCASOp, ops_to_arrays, zipf_probs

from .hashmap import DELETE, INSERT, KVOp, READ, SCAN, UPDATE


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Operation mix + skew + size; fractions must sum to 1."""
    n_ops: int = 256
    n_keys: int = 64               # key universe (keys are 1..n_keys)
    read: float = 0.5
    update: float = 0.25
    insert: float = 0.2
    delete: float = 0.05
    scan: float = 0.0
    alpha: float = 0.0             # Zipf skew of key popularity (Eq. 1)
    seed: int = 0
    batch: int = 16                # logical ops submitted per apply()

    def __post_init__(self):
        total = self.read + self.update + self.insert + self.delete \
            + self.scan
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"mix fractions sum to {total}, need 1.0")
        if self.batch < 1 or self.n_ops < 1 or self.n_keys < 1:
            raise ValueError("n_ops, n_keys and batch must be positive")


# The classic YCSB templates (fork with dataclasses.replace)
YCSB_A = WorkloadSpec(read=0.5, update=0.5, insert=0.0, delete=0.0)
YCSB_B = WorkloadSpec(read=0.95, update=0.05, insert=0.0, delete=0.0)
YCSB_C = WorkloadSpec(read=1.0, update=0.0, insert=0.0, delete=0.0)
YCSB_E = WorkloadSpec(read=0.0, update=0.0, insert=0.05, delete=0.0,
                      scan=0.95)
LOAD = WorkloadSpec(read=0.0, update=0.0, insert=1.0, delete=0.0)


def compile_workload(spec: WorkloadSpec) -> List[KVOp]:
    """Deterministic logical-op stream: kinds by mix, keys by Zipf rank
    (rank -> key through a seeded permutation, as in ``generate_ops``)."""
    rng = np.random.default_rng(spec.seed)
    p = zipf_probs(spec.n_keys, spec.alpha)
    perm = rng.permutation(spec.n_keys)
    kinds = rng.choice(
        [READ, UPDATE, INSERT, DELETE, SCAN], size=spec.n_ops,
        p=[spec.read, spec.update, spec.insert, spec.delete, spec.scan])
    ranks = rng.choice(spec.n_keys, size=spec.n_ops, p=p)
    values = rng.integers(1, 1 << 20, size=spec.n_ops)
    return [KVOp(kind=str(kind), key=int(perm[rank]) + 1, value=int(val))
            for kind, rank, val in zip(kinds, ranks, values)]


def load_phase(spec: WorkloadSpec, fraction: float = 0.5) -> List[KVOp]:
    """Pre-populate ops: insert a deterministic ``fraction`` of the key
    universe (so read/update/delete mixes have something to hit)."""
    rng = np.random.default_rng(spec.seed + 0xB00)
    n = max(1, int(spec.n_keys * fraction))
    keys = rng.permutation(spec.n_keys)[:n]
    vals = rng.integers(1, 1 << 20, size=n)
    return [KVOp(INSERT, int(k) + 1, int(v)) for k, v in zip(keys, vals)]


def batches(ops: Sequence[KVOp], batch: int) -> Iterator[List[KVOp]]:
    for i in range(0, len(ops), batch):
        yield list(ops[i:i + batch])


def client_streams(spec: WorkloadSpec, n_clients: int) -> List[List[KVOp]]:
    """Split one workload spec into ``n_clients`` deterministic per-client
    op streams (client i draws from the same mix/skew with seed
    ``spec.seed + i`` and ``n_ops // n_clients`` ops) — the many-client
    arrival shape the sharded service layer multiplexes.  All clients
    share one key universe, so Zipf-hot keys contend across clients."""
    if n_clients < 1:
        raise ValueError("need at least one client")
    per = max(1, spec.n_ops // n_clients)
    return [compile_workload(dataclasses.replace(spec, n_ops=per,
                                                 seed=spec.seed + i))
            for i in range(n_clients)]


def interleave(streams: Sequence[Sequence[KVOp]]) -> List[KVOp]:
    """Round-robin merge of per-client streams into one arrival order."""
    out: List[KVOp] = []
    for i in range(max((len(s) for s in streams), default=0)):
        for s in streams:
            if i < len(s):
                out.append(s[i])
    return out


def key_shard(key: int, n_parts: int) -> int:
    """Multiplicative-hash (Knuth) key partition — the ONE definition
    shared by :func:`partition_ops` and the service's
    ``ShardRouter.shard_of_key``, so a partitioned workload provably
    lands on the shards the service would route it to."""
    return (key * 2654435761 % (1 << 32)) % n_parts


def partition_ops(ops: Sequence[KVOp], n_parts: int,
                  part_of=None) -> List[List[KVOp]]:
    """Partition a logical op stream (order-preserving within a part).
    ``part_of(op) -> int`` defaults to :func:`key_shard`, the service
    router's key hash."""
    if part_of is None:
        def part_of(op):
            return key_shard(op.key, n_parts)
    parts: List[List[KVOp]] = [[] for _ in range(n_parts)]
    for op in ops:
        parts[part_of(op)].append(op)
    return parts


@dataclasses.dataclass
class WorkloadStats:
    """Aggregate outcome of a workload run against one HashMap."""
    n_ops: int = 0
    rounds: int = 0                # backend batches executed
    mwcas_submitted: int = 0
    mwcas_won: int = 0
    by_status: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def retries_per_op(self) -> float:
        extra = self.mwcas_submitted - self.mwcas_won
        return extra / self.n_ops if self.n_ops else 0.0

    @property
    def cas_ops_per_op(self) -> float:
        return self.mwcas_submitted / self.n_ops if self.n_ops else 0.0


def run_workload(struct, spec: WorkloadSpec,
                 ops: Optional[Sequence[KVOp]] = None) -> WorkloadStats:
    """Drive a compiled workload through a structure in ``spec.batch``-
    sized rounds of the lock-free retry loop.  ``struct`` is any
    structure with the HashMap execution surface (``apply`` +
    ``rounds_run``/``mwcas_*`` counters) — :class:`HashMap` or
    :class:`BzTreeIndex`."""
    ops = compile_workload(spec) if ops is None else list(ops)
    stats = WorkloadStats(n_ops=len(ops))
    r0, s0, w0 = struct.rounds_run, struct.mwcas_submitted, struct.mwcas_won
    for chunk in batches(ops, spec.batch):
        for res in struct.apply(chunk):
            stats.by_status[res.status] = \
                stats.by_status.get(res.status, 0) + 1
    stats.rounds = struct.rounds_run - r0
    stats.mwcas_submitted = struct.mwcas_submitted - s0
    stats.mwcas_won = struct.mwcas_won - w0
    return stats


def kernel_round_arrays(struct, ops: Sequence[KVOp]
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   List[MwCASOp]]:
    """Compile one round against the current snapshot and return its
    Pallas wire form ``(addr int32[B,K] with -1 padding, exp, des)`` —
    the hand-off point between the structure layer and the batched
    kernel.  Works for any snapshot-compiling structure (``HashMap``,
    ``BzTreeIndex``); immediate results and split requests compile to no
    CAS and are dropped from the wire form."""
    snap = struct.snapshot()
    compiled = [struct.compile_op(op, snap) for op in ops]
    mwcas = [c for c in compiled if isinstance(c, MwCASOp)]
    if not mwcas:
        raise ValueError("round compiles to no CAS work (all reads?)")
    addr, exp, des = ops_to_arrays(mwcas)
    return addr, exp, des, mwcas

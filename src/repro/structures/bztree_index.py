r"""Multi-level BzTree index on the unified PMwCAS API (DESIGN.md Sec. 7, 12).

The first true multi-node structure in the repo, grown from two fixed
levels to unbounded height: inner nodes routing by separator keys over
a row of KV leaves, every building block taken from the existing
structures layer —

- leaves are :class:`LeafNode`, a :class:`~repro.structures.SortedNode`
  with a parallel value array (insert is one 3-word MwCAS, update/delete
  one 2-word meta-guarded MwCAS);
- inner nodes are SortedNode-shaped: separator/child entries are
  appended in arrival order and sorted on read, so publishing an entry
  is a count bump — the same visibility switch the leaf insert uses;
- node regions are carved out of :class:`FreeListAllocator`;
- EVERY split — leaf, inner, and root — is the same two-round protocol:
  freeze, ONE wide MwCAS materializing the replacement out-of-place,
  ONE small MwCAS swinging a routing word.

Word layout (all state lives in the backend, as with every structure)::

    base          super   = base of the current root node (0 = empty)
    base + 1      pending = new-root base of an in-flight root split
    base + 2 ...  node regions (FreeListAllocator), region_words each

    leaf:  L             meta  = arrival count | FROZEN_BIT
           L + 1 + i     key slot i
           L + 1 + C + i value slot i   (LEAF_DEAD = deleted)
    inner: N             meta  = entry count | INNER_BIT | FROZEN_BIT
           N + 1         ptr0  = leftmost child (keys < every separator)
           N + 2 + 2i    sep[i]   \  appended in arrival order,
           N + 3 + 2i    child[i] /  sorted by separator on read

**Split = exactly two MwCAS rounds** (the DESIGN Sec. 7 argument, now
uniform across levels):

1. freeze the node (1-word), then ONE wide MwCAS materializes both half
   images out-of-place AND pre-publishes the install handle — for a
   non-root split the (separator, right child) pre-entry at the parent's
   *append position* ``n`` (invisible: parent count still ``n``); for a
   ROOT split the entire new 1-entry root image plus the ``pending``
   word (invisible: ``super`` still points at the frozen old root).
2. ONE small MwCAS swings routing: non-root, a 2-word op bumps the
   parent count ``n -> n+1`` while the old child's routing pointer
   swings to the left half; root, a 2-word op swings ``super`` to the
   new root while clearing ``pending``.  This is the linearization
   point of the split.

A crash between the rounds leaves a frozen node whose routing is
unchanged — the pre-split tree, fully readable.  The next mutation that
lands under the frozen node *completes* the pending split from
persisted state alone (the parent pre-entry or the ``pending`` word;
the left half base is derivable because halves are materialized
adjacently inside one allocator region), which is why no split ever
needs a third round or an auxiliary log.  When a full node's parent is
itself full, growth recurses upward one region at a time — each
``ensure_room`` call performs exactly one two-round growth step, so
every crash window is one of the two windows argued above.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from repro.pmwcas import Backend, MwCASOp

from .bztree import FROZEN_BIT, SortedNode, SplitError
from .freelist import FreeListAllocator, OutOfRegions
from .hashmap import (EXHAUSTED, EXISTS, FULL, INSERT, KVOp, NOT_FOUND, OK,
                      READ, RoundTrace, SCAN, StructResult, TornStructure,
                      UPDATE)

LEAF_DEAD = (1 << 32) - 1        # value word of a deleted key (uint32 max)
MAX_KEY = FROZEN_BIT             # keys live in (0, 2^31), as in SortedNode
INNER_BIT = 1 << 30              # meta bit: this node routes (no KV slots)
NODE_CMASK = INNER_BIT - 1       # count bits below INNER_BIT / FROZEN_BIT


class LeafNode(SortedNode):
    """A SortedNode plus a parallel value array — the KV leaf.

    The meta/key protocol (count-as-visibility-switch, FROZEN_BIT,
    append order, sorted reads) is inherited unchanged; values ride in
    the slots ``base + 1 + capacity + i``.  Deletion never shrinks the
    append area: it CASes the value word to :data:`LEAF_DEAD`, and the
    next split compacts dead entries away (``keys()`` is live-only, so
    the inherited one-wide-MwCAS split is also the consolidation).
    """

    # -- layout ----------------------------------------------------------------
    def value_addr(self, i: int) -> int:
        return self.base + 1 + self.capacity + i

    @property
    def n_words(self) -> int:
        return 1 + 2 * self.capacity

    # -- reads -----------------------------------------------------------------
    def raw_values(self) -> List[int]:
        return [int(self.backend.read(self.value_addr(i)))
                for i in range(self.count)]

    def items(self) -> Dict[int, int]:
        """Live (key, value) pairs (dead entries filtered)."""
        return {k: v for k, v in zip(self.raw_slots(), self.raw_values())
                if v != LEAF_DEAD}

    def keys(self) -> List[int]:
        """Sorted LIVE keys — what the inherited split materializes."""
        return sorted(self.items())

    def search(self, key: int) -> bool:
        return key in self.items()

    # -- mutations -------------------------------------------------------------
    def compile_insert(self, key, meta=None, slots=None):
        raise NotImplementedError(
            "LeafNode inserts carry values; compile through BzTreeIndex")

    def _node_image(self, base: int, keys: List[int]) -> List:
        """Meta + keys (the SortedNode image) + their values: the half
        image the inherited ``split`` writes with its one wide MwCAS."""
        kv = self.items()
        return super()._node_image(base, keys) + \
            [(base + 1 + self.capacity + i, 0, kv[k])
             for i, k in enumerate(keys)]


@dataclasses.dataclass(frozen=True)
class NeedsSplit:
    """Compile verdict: this op cannot proceed until its leaf splits
    (full) or a pending split completes (frozen).  The sharded service
    layer dispatches on this type (`repro.service`), so it is public:
    a round compiler that receives one should call
    :meth:`BzTreeIndex.ensure_room` and recompile."""
    leaf_base: int


_NeedsSplit = NeedsSplit         # original (private) spelling


class BzTreeIndex:
    """Multi-level BzTree over any PMwCAS backend.

    Holds no authoritative state: the word table IS the tree, so a
    crash/recover cycle on the durable backend is transparent —
    construct a fresh index over the recovered backend and it attaches
    to the existing root (rebuilding only the in-memory allocator mask
    from the words it can see).

    The client surface mirrors :class:`~repro.structures.HashMap`:
    ``apply(ops)`` executes a batch of :class:`KVOp` in snapshot-
    compiled rounds (losers recompile next round), recording each round
    as a :class:`RoundTrace` for the simulator shadow differential, and
    ``check_integrity`` asserts the multi-node invariants (no torn node
    image, no half-written inner entry, every live key routed to the
    leaf that holds it).

    Capacity is bounded only by the region budget: when a node fills,
    the tree grows — sideways by splitting into a fresh region, or
    upward by a root split that swings the ``super`` word to a new
    1-entry inner root.  ``root_cap`` is the per-inner-node fanout, not
    a tree-wide ceiling.
    """

    def __init__(self, backend: Backend, *, leaf_cap: int = 4,
                 root_cap: int = 8, n_regions: int = 8, base: int = 0):
        if leaf_cap < 2:
            raise ValueError("leaf_cap must be >= 2 (split needs halves)")
        if root_cap < 1 or n_regions < 1:
            raise ValueError("root_cap and n_regions must be positive")
        self.backend = backend
        self.leaf_cap = leaf_cap
        self.root_cap = root_cap
        self.base = base
        self.leaf_words = 1 + 2 * leaf_cap
        self.inner_words = 2 + 2 * root_cap
        # one region must fit the largest materialization: a root split
        # writes two half images plus the new root image in one region
        self.region_words = max(2 * self.leaf_words + self.inner_words,
                                3 * self.inner_words)
        self.pair_words = self.region_words         # compat alias
        self.super_addr = base
        self.pending_addr = base + 1
        self.region_base = base + 2
        self.n_regions = n_regions
        self.allocator = FreeListAllocator(
            n_regions, region_base=self.region_base,
            region_words=self.region_words)
        self.n_words = 2 + n_regions * self.region_words
        self.last_history: List[RoundTrace] = []
        # cumulative instrumentation (HashMap vocabulary + split counters)
        self.rounds_run = 0
        self.mwcas_submitted = 0
        self.mwcas_won = 0
        self.splits = 0
        self.root_splits = 0
        self.consolidations = 0
        self._attach_or_bootstrap()

    @staticmethod
    def words_needed(leaf_cap: int = 4, root_cap: int = 8,
                     n_regions: int = 8, base: int = 0) -> int:
        """Word-table size a backend must provide for these parameters."""
        lw, iw = 1 + 2 * leaf_cap, 2 + 2 * root_cap
        return base + 2 + n_regions * max(2 * lw + iw, 3 * iw)

    # -- layout ----------------------------------------------------------------
    def sep_addr(self, i: int, node: Optional[int] = None) -> int:
        """Separator word ``i`` of ``node`` (default: the current root,
        which must be an inner node)."""
        return self._inner_or_raise(node) + 2 + 2 * i

    def child_addr(self, i: int, node: Optional[int] = None) -> int:
        return self._inner_or_raise(node) + 3 + 2 * i

    def _inner_or_raise(self, node: Optional[int]) -> int:
        if node is not None:
            return node
        root = self.root_base()
        if not root or not self._read(root) & INNER_BIT:
            raise ValueError("root is not an inner node")
        return root

    def _slot_of(self, node_base: int) -> int:
        return (node_base - self.region_base) // self.region_words

    # -- reads -----------------------------------------------------------------
    def _read(self, addr: int) -> int:
        return int(self.backend.read(addr))

    def snapshot(self) -> np.ndarray:
        """One consistent-enough read of the whole tree region."""
        values = getattr(self.backend, "values", None)
        if callable(values):
            table = np.asarray(values(), np.int64)
            return table[self.base:self.base + self.n_words]
        return np.asarray([self._read(self.base + i)
                           for i in range(self.n_words)], np.int64)

    def _w(self, snap: Optional[np.ndarray], addr: int) -> int:
        return self._read(addr) if snap is None else int(snap[addr - self.base])

    def root_base(self, snap: Optional[np.ndarray] = None) -> int:
        return self._w(snap, self.super_addr)

    def height(self, snap: Optional[np.ndarray] = None) -> int:
        """Levels from root to leaf (1 = single-leaf tree, 0 = empty)."""
        node, h = self.root_base(snap), 0
        while node:
            h += 1
            m = self._w(snap, node)
            node = self._w(snap, node + 1) if m & INNER_BIT else 0
        return h

    def root_count(self, snap: Optional[np.ndarray] = None) -> int:
        """Visible entries of the root when it is an inner node (0 for
        a single-leaf or empty tree) — the old two-level meaning."""
        root = self.root_base(snap)
        if not root:
            return 0
        m = self._w(snap, root)
        return (m & NODE_CMASK) if m & INNER_BIT else 0

    def _node_entries(self, snap: Optional[np.ndarray], node: int
                      ) -> List[Tuple[int, int, int]]:
        """Visible (separator, child base, child word addr) of one inner
        node, sorted by separator — the sorted-on-read view."""
        cnt = self._w(snap, node) & NODE_CMASK
        out = [(self._w(snap, self.sep_addr(i, node)),
                self._w(snap, self.child_addr(i, node)),
                self.child_addr(i, node))
               for i in range(cnt)]
        out.sort()
        return out

    def _route(self, key: int, snap: Optional[np.ndarray] = None
               ) -> Tuple[int, int]:
        """(routing pointer word address, leaf base) for ``key``."""
        addr, node = self.super_addr, self.root_base(snap)
        depth = 0
        while node and self._w(snap, node) & INNER_BIT:
            depth += 1
            if depth > self.n_regions + 2:
                raise TornStructure("routing cycle")
            naddr, nnode = node + 1, self._w(snap, node + 1)
            for sep, child, caddr in self._node_entries(snap, node):
                if key >= sep:
                    naddr, nnode = caddr, child
            addr, node = naddr, nnode
        return addr, node

    def _leaves_under(self, snap: Optional[np.ndarray], node: int,
                      out: List[int], depth: int = 0) -> None:
        if depth > self.n_regions + 2:
            raise TornStructure("routing cycle")
        if self._w(snap, node) & INNER_BIT:
            self._leaves_under(snap, self._w(snap, node + 1), out, depth + 1)
            for _sep, child, _a in self._node_entries(snap, node):
                self._leaves_under(snap, child, out, depth + 1)
        else:
            out.append(node)

    def leaf_bases(self, snap: Optional[np.ndarray] = None) -> List[int]:
        """Reachable leaf bases in key order (leftmost first)."""
        root = self.root_base(snap)
        if not root:
            return []
        out: List[int] = []
        self._leaves_under(snap, root, out)
        return out

    def leaves(self) -> List[LeafNode]:
        return [LeafNode(self.backend, b, self.leaf_cap)
                for b in self.leaf_bases()]

    def lookup(self, key: int) -> Optional[int]:
        _, base = self._route(key)
        if not base:
            return None
        return LeafNode(self.backend, base, self.leaf_cap).items().get(key)

    def items(self, snap: Optional[np.ndarray] = None) -> Dict[int, int]:
        """All live (key, value) pairs across the reachable leaves."""
        snap = self.snapshot() if snap is None else snap
        out: Dict[int, int] = {}
        for lb in self.leaf_bases(snap):
            cnt = self._w(snap, lb) & NODE_CMASK
            for i in range(cnt):
                k = self._w(snap, lb + 1 + i)
                v = self._w(snap, lb + 1 + self.leaf_cap + i)
                if v != LEAF_DEAD:
                    out[k] = v
        return out

    # -- bootstrap / attach ----------------------------------------------------
    def _attach_or_bootstrap(self) -> None:
        snap = self.snapshot()
        if self._w(snap, self.super_addr) == 0:
            # empty pool: an empty unfrozen leaf is all-zero words, so
            # bootstrap is nothing but the super install (one CAS) — the
            # tree starts life as a single leaf
            (grant,) = self.allocator.alloc([1])
            if grant is None:
                raise RuntimeError("no region for the bootstrap leaf")
            leaf_base = self.allocator.region(grant[0])
            (res,) = self.backend.execute(
                [MwCASOp([(self.super_addr, 0, leaf_base)])])
            if not res.success:
                raise RuntimeError("bootstrap super install lost its CAS")
            return
        # attach to an existing tree: rebuild the allocator mask from
        # what the words show — reachable nodes plus any non-zero region
        # (frozen originals and crash-orphaned halves stay claimed)
        used = {self._slot_of(b) for b in self._reachable_nodes(snap)}
        for slot in range(self.n_regions):
            lo = self.allocator.region(slot) - self.base
            if snap[lo:lo + self.region_words].any():
                used.add(slot)
        if used:
            granted = self.allocator.reserve([[s] for s in sorted(used)])
            if not all(granted):
                raise RuntimeError("attach could not reclaim region slots")

    def _node_words_of(self, snap: Optional[np.ndarray], node: int) -> int:
        return self.inner_words if self._w(snap, node) & INNER_BIT \
            else self.leaf_words

    def _collect(self, snap: Optional[np.ndarray], node: int,
                 out: Set[int], depth: int = 0) -> None:
        if not node or node in out or depth > self.n_regions + 2:
            return
        out.add(node)
        m = self._w(snap, node)
        if not m & INNER_BIT:
            return
        self._collect(snap, self._w(snap, node + 1), out, depth + 1)
        cnt = m & NODE_CMASK
        for i in range(cnt):
            self._collect(snap, self._w(snap, self.child_addr(i, node)),
                          out, depth + 1)
        if cnt < self.root_cap:
            # invisible pre-entry at the append position: protect the
            # half-materialized pair of a pending child split
            pre = self._w(snap, self.child_addr(cnt, node))
            if pre:
                self._collect(snap, pre, out, depth + 1)
                self._collect(snap, pre - self._node_words_of(snap, pre),
                              out, depth + 1)

    def _reachable_nodes(self, snap: Optional[np.ndarray]) -> Set[int]:
        """Node bases a GC/attach pass must keep: the visible tree, the
        pending new root of an in-flight root split (its halves live in
        the same region), and every invisible parent pre-entry pair."""
        out: Set[int] = set()
        self._collect(snap, self.root_base(snap), out)
        pend = self._w(snap, self.pending_addr)
        if pend:
            self._collect(snap, pend, out)
        return out

    # -- operation compilation -------------------------------------------------
    def compile_op(self, op: KVOp, snap: np.ndarray
                   ) -> Union[MwCASOp, StructResult, _NeedsSplit]:
        """One logical op -> one MwCASOp (or an immediate result, or a
        split request).  Expected values come from ``snap``, so condition
        (a) of the batch semantics passes by construction — the
        HashMap.compile_op contract, lifted to routing."""
        if not 0 < op.key < MAX_KEY:
            raise ValueError(f"key {op.key} outside (0, 2^31)")
        if op.kind == SCAN:
            total = 0
            for lb in self.leaf_bases(snap):
                cnt = self._w(snap, lb) & NODE_CMASK
                for i in range(cnt):
                    if (self._w(snap, lb + 1 + self.leaf_cap + i) != LEAF_DEAD
                            and self._w(snap, lb + 1 + i) >= op.key):
                        total += 1
            return StructResult(op, OK, value=total)
        _, leaf = self._route(op.key, snap)
        cap = self.leaf_cap
        meta = self._w(snap, leaf)
        cnt = meta & NODE_CMASK
        keys = [self._w(snap, leaf + 1 + i) for i in range(cnt)]
        vals = [self._w(snap, leaf + 1 + cap + i) for i in range(cnt)]
        live = {k: (i, v) for i, (k, v) in enumerate(zip(keys, vals))
                if v != LEAF_DEAD}
        if op.kind == READ:
            if op.key in live:
                return StructResult(op, OK, value=live[op.key][1])
            return StructResult(op, NOT_FOUND)
        frozen = bool(meta & FROZEN_BIT)
        if op.kind == INSERT:
            if op.key in live:
                return StructResult(op, EXISTS, value=live[op.key][1])
            if frozen:                       # pending split must complete
                return _NeedsSplit(leaf)
            for i, (k, v) in enumerate(zip(keys, vals)):
                if k == op.key and v == LEAF_DEAD:
                    # revive the dead slot in place (meta guard pins the
                    # leaf against a concurrent freeze/split)
                    return MwCASOp([(leaf, meta, meta),
                                    (leaf + 1 + cap + i, LEAF_DEAD,
                                     op.value)])
            if cnt >= cap:
                return _NeedsSplit(leaf)
            return MwCASOp([(leaf, meta, meta + 1),
                            (leaf + 1 + cnt, 0, op.key),
                            (leaf + 1 + cap + cnt, 0, op.value)])
        # UPDATE / DELETE
        if op.key not in live:
            return StructResult(op, NOT_FOUND)
        if frozen:
            return _NeedsSplit(leaf)
        idx, cur = live[op.key]
        desired = op.value if op.kind == UPDATE else LEAF_DEAD
        return MwCASOp([(leaf, meta, meta),
                        (leaf + 1 + cap + idx, cur, desired)])

    # -- the growth protocol (DESIGN Sec. 7 & 12) ------------------------------
    def ensure_room(self, node_base: int) -> bool:
        """Public growth entry point for external round compilers (the
        sharded service layer): perform ONE two-round growth step toward
        making room under the node a :class:`NeedsSplit` verdict named —
        complete a pending root swing or parent pre-entry, split the
        node, or split an ancestor that is itself full.  Returns True
        when the tree changed (recompile and retry), False when it
        cannot grow; raises :class:`~repro.structures.OutOfRegions`
        when the allocator is exhausted even after a GC pass — the
        typed FULL-vs-conflict distinction the service records."""
        pend = self._read(self.pending_addr)
        if pend:
            return self._swing_root(pend)
        path = self._path_to(node_base)
        if path is None:
            return True          # no longer routed: a helper replaced it
        try:
            return self._grow(path)
        except OutOfRegions:
            if not self.gc_regions():
                raise
            path = self._path_to(node_base)
            if path is None:
                return True
            return self._grow(path)

    def _path_to(self, target: int, snap: Optional[np.ndarray] = None
                 ) -> Optional[List[Tuple[int, int]]]:
        """Routing path root -> ``target`` as (ptr word addr, node base)
        pairs, or None when the node is no longer reachable."""
        root = self.root_base(snap)
        if not root:
            return None

        def rec(ptr_addr: int, node: int, path: List[Tuple[int, int]],
                depth: int) -> Optional[List[Tuple[int, int]]]:
            path = path + [(ptr_addr, node)]
            if node == target:
                return path
            if depth > self.n_regions + 2:
                return None
            m = self._w(snap, node)
            if not m & INNER_BIT:
                return None
            caddrs = [node + 1] + [self.child_addr(i, node)
                                   for i in range(m & NODE_CMASK)]
            for ca in caddrs:
                hit = rec(ca, self._w(snap, ca), path, depth + 1)
                if hit:
                    return hit
            return None

        return rec(self.super_addr, root, [], 0)

    def _grow(self, path: List[Tuple[int, int]]) -> bool:
        """One growth step along ``path`` (root -> the node that needs
        room).  When the parent has no free entry slot — or is frozen
        mid-split itself — the parent grows first; the caller recompiles
        and comes back, so each call stays a single two-round window."""
        ptr_addr, node = path[-1]
        if len(path) >= 2:
            parent = path[-2][1]
            pm = self._read(parent)
            n = pm & NODE_CMASK
            if pm & FROZEN_BIT or n >= self.root_cap:
                return self._grow(path[:-1])
            sep_w = self._read(self.sep_addr(n, parent))
            child_w = self._read(self.child_addr(n, parent))
            if sep_w and child_w:
                # round 1 already committed (this node's split or a
                # sibling's): complete its install, then let the caller
                # recompile and retry
                return self._install(parent, n, sep_w, child_w)
            return self._split_child(parent, n, ptr_addr, node)
        return self._split_root(node)

    def _freeze_inner(self, node: int) -> None:
        """Idempotent 1-word freeze of an inner node (SortedNode.freeze
        for the INNER_BIT-tagged meta encoding)."""
        for _ in range(8):
            m = self._read(node)
            if m & FROZEN_BIT:
                return
            (res,) = self.backend.execute(
                [MwCASOp([(node, m, m | FROZEN_BIT)])])
            self.mwcas_submitted += 1
            if res.success:
                self.mwcas_won += 1
                return
        raise TornStructure(f"could not freeze inner@{node}")

    def _inner_halves(self, node: int, region: int
                      ) -> Tuple[List, List, int, int, int]:
        """Half images of a frozen inner node: promote the middle
        separator up, left half keeps entries below it, right half's
        ptr0 takes its child.  Returns (left image, right image,
        promoted separator, left base, right base)."""
        entries = [(s, c) for s, c, _a in self._node_entries(None, node)]
        ptr0 = self._read(node + 1)
        mid = len(entries) // 2
        sep_up, mid_child = entries[mid]
        left, right = region, region + self.inner_words

        def image(b: int, p0: int, ents: List[Tuple[int, int]]) -> List:
            t = [(b, 0, len(ents) | INNER_BIT), (b + 1, 0, p0)]
            for i, (s, c) in enumerate(ents):
                t += [(b + 2 + 2 * i, 0, s), (b + 3 + 2 * i, 0, c)]
            return t

        return (image(left, ptr0, entries[:mid]),
                image(right, mid_child, entries[mid + 1:]),
                sep_up, left, right)

    def _split_child(self, parent: int, n: int, ptr_addr: int,
                     node: int) -> bool:
        """Non-root split of ``node`` under ``parent`` (append slot
        ``n`` is free): rounds 1+2 of the uniform protocol."""
        m = self._read(node)
        (grant,) = self.allocator.alloc([1])
        if grant is None:
            return False
        region = self.allocator.region(grant[0])
        if m & INNER_BIT:
            if (m & NODE_CMASK) < 1:
                self.allocator.free(grant)
                return False
            self._freeze_inner(node)
            left_img, right_img, sep, _left, right = \
                self._inner_halves(node, region)
            targets = left_img + right_img + [
                (self.sep_addr(n, parent), 0, sep),
                (self.child_addr(n, parent), 0, right)]
            (res,) = self.backend.execute([MwCASOp(targets).sorted()])
            self.mwcas_submitted += 1
            if not res.success:
                self.allocator.free(grant)
                return False
            self.mwcas_won += 1
            return self._install(parent, n, sep, right)
        leaf = LeafNode(self.backend, node, self.leaf_cap)
        leaf.freeze()
        ks = leaf.keys()
        if len(ks) < 2:
            return self._consolidate(leaf, grant, ptr_addr)
        left_base, right_base = region, region + self.leaf_words
        sep = ks[len(ks) // 2]
        try:
            # round 1: the existing one-wide-MwCAS split, with the parent
            # pre-entry folded into the same atomic op (invisible until
            # round 2 bumps the count)
            leaf.split(left_base, right_base,
                       extra_targets=[(self.sep_addr(n, parent), 0, sep),
                                      (self.child_addr(n, parent), 0,
                                       right_base)])
        except SplitError:
            self.allocator.free(grant)       # nothing was written (atomic)
            return False
        self.mwcas_submitted += 2            # freeze + wide materialize
        self.mwcas_won += 2
        return self._install(parent, n, sep, right_base)

    def _route_in(self, parent: int, key: int) -> Tuple[int, int]:
        """(child word addr, child base) ``key`` routes to inside one
        inner node (live reads)."""
        addr, node = parent + 1, self._read(parent + 1)
        for sep, child, caddr in self._node_entries(None, parent):
            if key >= sep:
                addr, node = caddr, child
        return addr, node

    def _install(self, parent: int, n: int, sep: int,
                 right_base: int) -> bool:
        """Round 2 of a non-root split: ONE 2-word MwCAS — swing the old
        child's routing pointer to the left half while bumping the
        parent count, making the pre-published (separator, right child)
        entry visible.  The linearization point of the whole split."""
        left_base = right_base - self._node_words_of(None, right_base)
        ptr_addr, old_base = self._route_in(parent, sep)
        if old_base in (left_base, right_base):
            return True                      # already installed (helper)
        pm = self._read(parent)
        if pm & FROZEN_BIT:
            return False                     # parent mid-split; recompile
        if (pm & NODE_CMASK) != n:
            return (pm & NODE_CMASK) > n
        (res,) = self.backend.execute(
            [MwCASOp([(parent, pm, pm + 1),
                      (ptr_addr, old_base, left_base)])])
        self.mwcas_submitted += 1
        if res.success:
            self.mwcas_won += 1
            self.splits += 1
            return True
        return (self._read(parent) & NODE_CMASK) > n

    def _split_root(self, root: int) -> bool:
        """Root split: round 1 materializes BOTH halves AND the new
        1-entry root in one region with ONE wide MwCAS that also sets
        the ``pending`` word; round 2 (:meth:`_swing_root`) swings
        ``super`` while clearing ``pending``.  Grows the tree one
        level."""
        m = self._read(root)
        (grant,) = self.allocator.alloc([1])
        if grant is None:
            return False
        region = self.allocator.region(grant[0])
        if m & INNER_BIT:
            if (m & NODE_CMASK) < 1:
                self.allocator.free(grant)
                return False
            self._freeze_inner(root)
            left_img, right_img, sep, left, right = \
                self._inner_halves(root, region)
            new_root = region + 2 * self.inner_words
            targets = left_img + right_img + [
                (new_root, 0, 1 | INNER_BIT), (new_root + 1, 0, left),
                (new_root + 2, 0, sep), (new_root + 3, 0, right),
                (self.pending_addr, 0, new_root)]
            (res,) = self.backend.execute([MwCASOp(targets).sorted()])
            self.mwcas_submitted += 1
            if not res.success:
                self.allocator.free(grant)
                return False
            self.mwcas_won += 1
            return self._swing_root(new_root)
        leaf = LeafNode(self.backend, root, self.leaf_cap)
        leaf.freeze()
        ks = leaf.keys()
        if len(ks) < 2:
            return self._consolidate(leaf, grant, self.super_addr)
        left, right = region, region + self.leaf_words
        sep = ks[len(ks) // 2]
        new_root = region + 2 * self.leaf_words
        try:
            # the inherited wide split op, with the new root image and
            # the pending word folded into the same atomic round
            leaf.split(left, right, extra_targets=[
                (new_root, 0, 1 | INNER_BIT), (new_root + 1, 0, left),
                (new_root + 2, 0, sep), (new_root + 3, 0, right),
                (self.pending_addr, 0, new_root)])
        except SplitError:
            self.allocator.free(grant)
            return False
        self.mwcas_submitted += 2            # freeze + wide materialize
        self.mwcas_won += 2
        return self._swing_root(new_root)

    def _swing_root(self, new_root: int) -> bool:
        """Round 2 of a root split (also the crash-completion helper):
        ONE 2-word MwCAS swings ``super`` to the materialized new root
        while clearing ``pending``.  Idempotent: a helper that lost the
        race confirms the swing happened."""
        old = self._read(self.super_addr)
        if old == new_root:
            return True
        (res,) = self.backend.execute(
            [MwCASOp([(self.super_addr, old, new_root),
                      (self.pending_addr, new_root, 0)])])
        self.mwcas_submitted += 1
        if res.success:
            self.mwcas_won += 1
            self.splits += 1
            self.root_splits += 1
            return True
        return self._read(self.super_addr) == new_root

    def _consolidate(self, leaf: LeafNode, grant: List[int],
                     ptr_addr: int) -> bool:
        """A full leaf with < 2 live keys cannot split; materialize one
        compacted node (same one-wide-MwCAS image) and swing its routing
        word — ``ptr_addr`` from the caller's path — to it (1-word
        install, no parent entry needed)."""
        new_base = self.allocator.region(grant[0])
        ks = leaf.keys()
        (res,) = self.backend.execute(
            [MwCASOp(leaf._node_image(new_base, ks))])
        self.mwcas_submitted += 1
        if not res.success:
            self.allocator.free(grant)
            return False
        self.mwcas_won += 1
        old = self._read(ptr_addr)
        if old != leaf.base:
            return True                      # raced: already swung
        (res2,) = self.backend.execute(
            [MwCASOp([(ptr_addr, old, new_base)])])
        self.mwcas_submitted += 1
        if res2.success:
            self.mwcas_won += 1
            self.consolidations += 1
        return bool(res2.success)

    # -- round-based execution -------------------------------------------------
    def apply(self, ops: Sequence[KVOp],
              max_rounds: Optional[int] = None) -> List[StructResult]:
        """Execute one batch of logical ops; losers retry next round.

        Ops that hit a full (or frozen mid-split) leaf trigger the
        growth protocol between rounds and recompile against the grown
        tree.
        """
        max_rounds = 2 * len(ops) + 4 if max_rounds is None else max_rounds
        results: List[Optional[StructResult]] = [None] * len(ops)
        pending = list(range(len(ops)))
        self.last_history = []
        rounds = 0
        split_budget = 4 * self.n_regions + 8
        while pending and rounds < max_rounds:
            snap = self.snapshot()
            batch_ops: List[MwCASOp] = []
            owners: List[int] = []
            needs: Dict[int, List[int]] = {}
            for idx in pending:
                compiled = self.compile_op(ops[idx], snap)
                if isinstance(compiled, StructResult):
                    compiled.rounds = rounds
                    results[idx] = compiled
                elif isinstance(compiled, _NeedsSplit):
                    needs.setdefault(compiled.leaf_base, []).append(idx)
                else:
                    batch_ops.append(compiled)
                    owners.append(idx)
            if needs:
                # grow first, then recompile EVERYone against the new
                # tree shape (ops compiled above would mostly lose their
                # round anyway: the split freezes their leaf's meta)
                for leaf_base, idxs in needs.items():
                    try:
                        grew = split_budget > 0 and \
                            self.ensure_room(leaf_base)
                    except OutOfRegions:
                        grew = False         # region-exhausted == FULL here
                    if grew:
                        split_budget -= 1
                    else:
                        for idx in idxs:
                            results[idx] = StructResult(ops[idx], FULL,
                                                        rounds=rounds)
                pending = [i for i in pending if results[i] is None]
                continue
            if not batch_ops:
                pending = []
                break
            rounds += 1
            self.rounds_run += 1
            verdicts = self.backend.execute(batch_ops)
            success = np.asarray([r.success for r in verdicts])
            self.last_history.append(
                RoundTrace(ops=batch_ops, owners=owners, success=success))
            self.mwcas_submitted += len(batch_ops)
            self.mwcas_won += int(success.sum())
            still: List[int] = []
            for pos, idx in enumerate(owners):
                if success[pos]:
                    results[idx] = StructResult(ops[idx], OK, rounds=rounds)
                else:
                    still.append(idx)
            pending = still
        for idx in pending:
            results[idx] = StructResult(ops[idx], EXHAUSTED, rounds=rounds)
        assert all(r is not None for r in results)
        return results               # type: ignore[return-value]

    # -- region GC (frozen split originals stay claimed) -----------------------
    def gc_regions(self) -> int:
        """Recovery-time region GC: free regions no routing state
        references — the frozen originals of completed splits,
        consolidated-away leaves and crash-abandoned halves.  Without
        this, a long-running workload leaks one region per growth step
        until the allocator reports :class:`OutOfRegions` (the WAL side
        is pruned by ``prune_completed``; this is the word side).

        A region is live iff it holds a node reachable from ``super``,
        from the ``pending`` new root of an in-flight root split, or
        from an invisible parent pre-entry (a pending split's right
        half — its left sibling shares the region, so both stay claimed
        until the install completes).  Everything else holding non-zero
        words is residue: it is zeroed with ONE wide MwCAS (atomic — a
        crash mid-GC leaves the region whole and still unreferenced, so
        the next pass retakes it) and returned to the free list.
        Returns the number of regions freed.
        """
        snap = self.snapshot()
        live_slots = {self._slot_of(b) for b in self._reachable_nodes(snap)}
        freed = 0
        for slot in range(self.n_regions):
            lo = self.allocator.region(slot) - self.base
            words = snap[lo:lo + self.region_words]
            if slot in live_slots or not words.any():
                continue
            base_addr = self.base + lo
            targets = [(base_addr + j, int(w), 0)
                       for j, w in enumerate(words) if w]
            (res,) = self.backend.execute([MwCASOp(targets)])
            self.mwcas_submitted += 1
            if not res.success:
                continue                 # raced: next GC pass retakes it
            self.mwcas_won += 1
            self.allocator.free([slot])
            freed += 1
        return freed

    # -- integrity -------------------------------------------------------------
    def check_integrity(self, snap: Optional[np.ndarray] = None
                        ) -> Dict[int, int]:
        """Assert the multi-node invariants; return the live items.

        Checked (each is an atomicity consequence of the protocol —
        violating any means a torn MwCAS, which must never happen):

        - a non-zero ``pending`` word names a complete 1-entry inner
          image over a frozen old root (root-split round 1 is one wide
          MwCAS, so it is all-or-nothing);
        - no half-written inner entry: entries below the count are
          fully populated, the append position is all-zero or a
          complete pre-entry, and nothing exists beyond it;
        - no torn leaf image: key and value words below the arrival
          count are populated together, words beyond it are zero;
        - routing: every separator respects its ancestors' bounds,
          every live key sits in the exact leaf the separators route it
          to, and no key is live in two leaves.
        """
        snap = self.snapshot() if snap is None else snap
        root = self.root_base(snap)
        pend = self._w(snap, self.pending_addr)
        if pend:
            pm = self._w(snap, pend)
            if not pm & INNER_BIT or (pm & NODE_CMASK) != 1:
                raise TornStructure("pending root is not a 1-entry inner")
            if not (self._w(snap, pend + 1) and self._w(snap, pend + 2)
                    and self._w(snap, pend + 3)):
                raise TornStructure("pending root image is torn")
            if not root:
                raise TornStructure("pending root split on an empty tree")
            if not self._w(snap, root) & FROZEN_BIT:
                raise TornStructure("pending root split over unfrozen root")
        if not root:
            return {}                        # pre-bootstrap empty tree
        items: Dict[int, int] = {}
        self._check_node(snap, root, None, None, items, 0)
        return items

    def _check_node(self, snap: Optional[np.ndarray], node: int,
                    lo: Optional[int], hi: Optional[int],
                    items: Dict[int, int], depth: int) -> None:
        if depth > self.n_regions + 2:
            raise TornStructure("routing cycle")
        m = self._w(snap, node)
        cnt = m & NODE_CMASK
        if m & INNER_BIT:
            if cnt > self.root_cap:
                raise TornStructure(
                    f"inner@{node} count {cnt} > capacity {self.root_cap}")
            if not self._w(snap, node + 1):
                raise TornStructure(f"inner@{node} has no leftmost child")
            for i in range(cnt):
                if not self._w(snap, self.sep_addr(i, node)) or \
                        not self._w(snap, self.child_addr(i, node)):
                    raise TornStructure(
                        f"inner@{node} entry {i} below count is torn")
            for i in range(cnt, self.root_cap):
                s = self._w(snap, self.sep_addr(i, node))
                c = self._w(snap, self.child_addr(i, node))
                if i == cnt:
                    if bool(s) != bool(c):
                        raise TornStructure(
                            f"half-written pre-entry at append position "
                            f"{cnt} of inner@{node}: sep={s} child={c}")
                elif s or c:
                    raise TornStructure(
                        f"inner@{node} entry {i} beyond append position "
                        f"{cnt} is claimed")
            entries = self._node_entries(snap, node)
            seps = [sep for sep, _c, _a in entries]
            if len(set(seps)) != len(seps):
                raise TornStructure(f"duplicate separators {seps}")
            for sep in seps:
                if (lo is not None and sep < lo) or \
                        (hi is not None and sep >= hi):
                    raise TornStructure(
                        f"inner@{node} separator {sep} outside "
                        f"bounds [{lo}, {hi})")
            children = [self._w(snap, node + 1)] + [c for _s, c, _a in entries]
            lows = [lo] + seps
            highs = seps + [hi]
            for child, clo, chi in zip(children, lows, highs):
                self._check_node(snap, child, clo, chi, items, depth + 1)
            return
        if cnt > self.leaf_cap:
            raise TornStructure(f"leaf@{node} count {cnt} > capacity")
        for i in range(self.leaf_cap):
            k = self._w(snap, node + 1 + i)
            v = self._w(snap, node + 1 + self.leaf_cap + i)
            if i < cnt:
                if k == 0 or v == 0:
                    raise TornStructure(
                        f"leaf@{node} slot {i}: torn pair key={k} val={v}")
                if v != LEAF_DEAD:
                    if k in items:
                        raise TornStructure(f"key {k} live in two leaves")
                    if (lo is not None and k < lo) or \
                            (hi is not None and k >= hi):
                        raise TornStructure(
                            f"leaf@{node} holds misrouted key {k} "
                            f"(range [{lo}, {hi}))")
                    items[k] = v
            elif k or v:
                raise TornStructure(
                    f"leaf@{node} ghost words beyond count {cnt}")

r"""Two-level BzTree index on the unified PMwCAS API (DESIGN.md Sec. 7).

The first true multi-node structure in the repo: a root inner node
routing by separator keys over a row of KV leaves, every building block
taken from the existing structures layer —

- leaves are :class:`LeafNode`, a :class:`~repro.structures.SortedNode`
  with a parallel value array (insert is one 3-word MwCAS, update/delete
  one 2-word meta-guarded MwCAS);
- the root is itself SortedNode-shaped: separator/child entries are
  appended in arrival order and sorted on read, so publishing an entry
  is a count bump — the same visibility switch the leaf insert uses;
- node regions are carved out of :class:`FreeListAllocator`;
- a leaf split is the existing one-wide-MwCAS ``SortedNode.split``
  followed by a 2-word parent install.

Word layout (all state lives in the backend, as with every structure)::

    root:  base          meta  = entry count (separators installed)
           base + 1      ptr0  = leftmost child (keys < every separator)
           base + 2 + 2i sep[i]   \  appended in arrival order,
           base + 3 + 2i child[i] /  sorted by separator on read
    leaf:  L             meta  = arrival count | FROZEN_BIT
           L + 1 + i     key slot i
           L + 1 + C + i value slot i   (LEAF_DEAD = deleted)

**Split = exactly two MwCAS rounds** (the DESIGN Sec. 7 argument):

1. freeze the leaf (1-word), then ONE wide MwCAS materializes both
   half images AND pre-publishes the parent entry — separator and
   right-child words at the *append position* ``n`` (``extra_targets``
   of ``SortedNode.split``).  The entry is invisible (root count still
   ``n``), so readers and the crash checker see the pre-split tree.
2. ONE 2-word MwCAS installs the split: the routing pointer of the old
   leaf swings to the left half and the root count bumps ``n -> n+1``,
   making the (separator, right child) entry visible.  This is the
   linearization point of the split.

A crash between the rounds leaves a frozen leaf whose routing is
unchanged — the pre-split tree, fully readable.  The next mutation that
lands on the frozen leaf *completes* the pending split from the
persisted pre-entry alone (the left half base is derivable: halves are
materialized adjacently inside one allocator pair region), which is why
no split ever needs a third round or an auxiliary log.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.pmwcas import Backend, MwCASOp

from .bztree import COUNT_MASK, FROZEN_BIT, SortedNode, SplitError
from .freelist import FreeListAllocator, OutOfRegions
from .hashmap import (EXHAUSTED, EXISTS, FULL, INSERT, KVOp, NOT_FOUND, OK,
                      READ, RoundTrace, SCAN, StructResult, TornStructure,
                      UPDATE)

LEAF_DEAD = (1 << 32) - 1        # value word of a deleted key (uint32 max)
MAX_KEY = FROZEN_BIT             # keys live in (0, 2^31), as in SortedNode


class LeafNode(SortedNode):
    """A SortedNode plus a parallel value array — the KV leaf.

    The meta/key protocol (count-as-visibility-switch, FROZEN_BIT,
    append order, sorted reads) is inherited unchanged; values ride in
    the slots ``base + 1 + capacity + i``.  Deletion never shrinks the
    append area: it CASes the value word to :data:`LEAF_DEAD`, and the
    next split compacts dead entries away (``keys()`` is live-only, so
    the inherited one-wide-MwCAS split is also the consolidation).
    """

    # -- layout ----------------------------------------------------------------
    def value_addr(self, i: int) -> int:
        return self.base + 1 + self.capacity + i

    @property
    def n_words(self) -> int:
        return 1 + 2 * self.capacity

    # -- reads -----------------------------------------------------------------
    def raw_values(self) -> List[int]:
        return [int(self.backend.read(self.value_addr(i)))
                for i in range(self.count)]

    def items(self) -> Dict[int, int]:
        """Live (key, value) pairs (dead entries filtered)."""
        return {k: v for k, v in zip(self.raw_slots(), self.raw_values())
                if v != LEAF_DEAD}

    def keys(self) -> List[int]:
        """Sorted LIVE keys — what the inherited split materializes."""
        return sorted(self.items())

    def search(self, key: int) -> bool:
        return key in self.items()

    # -- mutations -------------------------------------------------------------
    def compile_insert(self, key, meta=None, slots=None):
        raise NotImplementedError(
            "LeafNode inserts carry values; compile through BzTreeIndex")

    def _node_image(self, base: int, keys: List[int]) -> List:
        """Meta + keys (the SortedNode image) + their values: the half
        image the inherited ``split`` writes with its one wide MwCAS."""
        kv = self.items()
        return super()._node_image(base, keys) + \
            [(base + 1 + self.capacity + i, 0, kv[k])
             for i, k in enumerate(keys)]


@dataclasses.dataclass(frozen=True)
class NeedsSplit:
    """Compile verdict: this op cannot proceed until its leaf splits
    (full) or a pending split completes (frozen).  The sharded service
    layer dispatches on this type (`repro.service`), so it is public:
    a round compiler that receives one should call
    :meth:`BzTreeIndex.ensure_room` and recompile."""
    leaf_base: int


_NeedsSplit = NeedsSplit         # original (private) spelling


class BzTreeIndex:
    """Two-level (root + leaves) BzTree over any PMwCAS backend.

    Holds no authoritative state: the word table IS the tree, so a
    crash/recover cycle on the durable backend is transparent —
    construct a fresh index over the recovered backend and it attaches
    to the existing root (rebuilding only the in-memory allocator mask
    from the words it can see).

    The client surface mirrors :class:`~repro.structures.HashMap`:
    ``apply(ops)`` executes a batch of :class:`KVOp` in snapshot-
    compiled rounds (losers recompile next round), recording each round
    as a :class:`RoundTrace` for the simulator shadow differential, and
    ``check_integrity`` asserts the multi-node invariants (no torn node
    image, no half-written root entry, every live key routed to the
    leaf that holds it).
    """

    def __init__(self, backend: Backend, *, leaf_cap: int = 4,
                 root_cap: int = 8, n_regions: int = 8, base: int = 0):
        if leaf_cap < 2:
            raise ValueError("leaf_cap must be >= 2 (split needs halves)")
        if root_cap < 1 or n_regions < 1:
            raise ValueError("root_cap and n_regions must be positive")
        self.backend = backend
        self.leaf_cap = leaf_cap
        self.root_cap = root_cap
        self.base = base
        self.leaf_words = 1 + 2 * leaf_cap
        self.pair_words = 2 * self.leaf_words       # one split = one pair
        self.root_words = 2 + 2 * root_cap
        self.region_base = base + self.root_words
        self.n_regions = n_regions
        self.allocator = FreeListAllocator(
            n_regions, region_base=self.region_base,
            region_words=self.pair_words)
        self.n_words = self.root_words + n_regions * self.pair_words
        self.last_history: List[RoundTrace] = []
        # cumulative instrumentation (HashMap vocabulary + split counters)
        self.rounds_run = 0
        self.mwcas_submitted = 0
        self.mwcas_won = 0
        self.splits = 0
        self.consolidations = 0
        self._attach_or_bootstrap()

    @staticmethod
    def words_needed(leaf_cap: int = 4, root_cap: int = 8,
                     n_regions: int = 8, base: int = 0) -> int:
        """Word-table size a backend must provide for these parameters."""
        return base + 2 + 2 * root_cap + n_regions * 2 * (1 + 2 * leaf_cap)

    # -- layout ----------------------------------------------------------------
    @property
    def meta_addr(self) -> int:
        return self.base

    @property
    def ptr0_addr(self) -> int:
        return self.base + 1

    def sep_addr(self, i: int) -> int:
        return self.base + 2 + 2 * i

    def child_addr(self, i: int) -> int:
        return self.base + 3 + 2 * i

    def _slot_of(self, node_base: int) -> int:
        return (node_base - self.region_base) // self.pair_words

    # -- reads -----------------------------------------------------------------
    def _read(self, addr: int) -> int:
        return int(self.backend.read(addr))

    def snapshot(self) -> np.ndarray:
        """One consistent-enough read of the whole tree region."""
        values = getattr(self.backend, "values", None)
        if callable(values):
            table = np.asarray(values(), np.int64)
            return table[self.base:self.base + self.n_words]
        return np.asarray([self._read(self.base + i)
                           for i in range(self.n_words)], np.int64)

    def _w(self, snap: Optional[np.ndarray], addr: int) -> int:
        return self._read(addr) if snap is None else int(snap[addr - self.base])

    def root_count(self, snap: Optional[np.ndarray] = None) -> int:
        return self._w(snap, self.meta_addr) & COUNT_MASK

    def _entries(self, snap: Optional[np.ndarray] = None
                 ) -> List[Tuple[int, int, int]]:
        """Visible (separator, child base, child word addr), sorted by
        separator — the root's sorted-on-read view."""
        out = [(self._w(snap, self.sep_addr(i)),
                self._w(snap, self.child_addr(i)), self.child_addr(i))
               for i in range(self.root_count(snap))]
        out.sort()
        return out

    def _route(self, key: int, snap: Optional[np.ndarray] = None
               ) -> Tuple[int, int]:
        """(routing pointer word address, leaf base) for ``key``."""
        addr, node = self.ptr0_addr, self._w(snap, self.ptr0_addr)
        for sep, child, caddr in self._entries(snap):
            if key >= sep:
                addr, node = caddr, child
        return addr, node

    def leaf_bases(self, snap: Optional[np.ndarray] = None) -> List[int]:
        """Reachable leaf bases in key order (ptr0 first)."""
        return [self._w(snap, self.ptr0_addr)] + \
            [child for _sep, child, _a in self._entries(snap)]

    def leaves(self) -> List[LeafNode]:
        return [LeafNode(self.backend, b, self.leaf_cap)
                for b in self.leaf_bases()]

    def lookup(self, key: int) -> Optional[int]:
        _, base = self._route(key)
        return LeafNode(self.backend, base, self.leaf_cap).items().get(key)

    def items(self, snap: Optional[np.ndarray] = None) -> Dict[int, int]:
        """All live (key, value) pairs across the reachable leaves."""
        snap = self.snapshot() if snap is None else snap
        out: Dict[int, int] = {}
        for lb in self.leaf_bases(snap):
            cnt = self._w(snap, lb) & COUNT_MASK
            for i in range(cnt):
                k = self._w(snap, lb + 1 + i)
                v = self._w(snap, lb + 1 + self.leaf_cap + i)
                if v != LEAF_DEAD:
                    out[k] = v
        return out

    # -- bootstrap / attach ----------------------------------------------------
    def _attach_or_bootstrap(self) -> None:
        snap = self.snapshot()
        if int(snap[self.ptr0_addr - self.base]) == 0:
            # empty pool: an empty unfrozen leaf is all-zero words, so
            # bootstrap is nothing but the ptr0 install (one CAS)
            (grant,) = self.allocator.alloc([1])
            if grant is None:
                raise RuntimeError("no region for the bootstrap leaf")
            leaf_base = self.allocator.region(grant[0])
            (res,) = self.backend.execute(
                [MwCASOp([(self.ptr0_addr, 0, leaf_base)])])
            if not res.success:
                raise RuntimeError("bootstrap ptr0 install lost its CAS")
            return
        # attach to an existing tree: rebuild the allocator mask from
        # what the words show — reachable nodes plus any non-zero region
        # (frozen originals and crash-orphaned halves stay claimed)
        used = set()
        for b in self.leaf_bases(snap):
            used.add(self._slot_of(b))
        for slot in range(self.n_regions):
            lo = self.allocator.region(slot) - self.base
            if snap[lo:lo + self.pair_words].any():
                used.add(slot)
        if used:
            granted = self.allocator.reserve([[s] for s in sorted(used)])
            if not all(granted):
                raise RuntimeError("attach could not reclaim region slots")

    # -- operation compilation -------------------------------------------------
    def compile_op(self, op: KVOp, snap: np.ndarray
                   ) -> Union[MwCASOp, StructResult, _NeedsSplit]:
        """One logical op -> one MwCASOp (or an immediate result, or a
        split request).  Expected values come from ``snap``, so condition
        (a) of the batch semantics passes by construction — the
        HashMap.compile_op contract, lifted to routing."""
        if not 0 < op.key < MAX_KEY:
            raise ValueError(f"key {op.key} outside (0, 2^31)")
        if op.kind == SCAN:
            total = 0
            for lb in self.leaf_bases(snap):
                cnt = self._w(snap, lb) & COUNT_MASK
                for i in range(cnt):
                    if (self._w(snap, lb + 1 + self.leaf_cap + i) != LEAF_DEAD
                            and self._w(snap, lb + 1 + i) >= op.key):
                        total += 1
            return StructResult(op, OK, value=total)
        _, leaf = self._route(op.key, snap)
        cap = self.leaf_cap
        meta = self._w(snap, leaf)
        cnt = meta & COUNT_MASK
        keys = [self._w(snap, leaf + 1 + i) for i in range(cnt)]
        vals = [self._w(snap, leaf + 1 + cap + i) for i in range(cnt)]
        live = {k: (i, v) for i, (k, v) in enumerate(zip(keys, vals))
                if v != LEAF_DEAD}
        if op.kind == READ:
            if op.key in live:
                return StructResult(op, OK, value=live[op.key][1])
            return StructResult(op, NOT_FOUND)
        frozen = bool(meta & FROZEN_BIT)
        if op.kind == INSERT:
            if op.key in live:
                return StructResult(op, EXISTS, value=live[op.key][1])
            if frozen:                       # pending split must complete
                return _NeedsSplit(leaf)
            for i, (k, v) in enumerate(zip(keys, vals)):
                if k == op.key and v == LEAF_DEAD:
                    # revive the dead slot in place (meta guard pins the
                    # leaf against a concurrent freeze/split)
                    return MwCASOp([(leaf, meta, meta),
                                    (leaf + 1 + cap + i, LEAF_DEAD,
                                     op.value)])
            if cnt >= cap:
                return _NeedsSplit(leaf)
            return MwCASOp([(leaf, meta, meta + 1),
                            (leaf + 1 + cnt, 0, op.key),
                            (leaf + 1 + cap + cnt, 0, op.value)])
        # UPDATE / DELETE
        if op.key not in live:
            return StructResult(op, NOT_FOUND)
        if frozen:
            return _NeedsSplit(leaf)
        idx, cur = live[op.key]
        desired = op.value if op.kind == UPDATE else LEAF_DEAD
        return MwCASOp([(leaf, meta, meta),
                        (leaf + 1 + cap + idx, cur, desired)])

    # -- the split protocol (DESIGN Sec. 7) ------------------------------------
    def _install(self, n: int, sep: int, right_base: int) -> bool:
        """Round 2: ONE 2-word MwCAS — swing the old leaf's routing
        pointer to the left half and bump the root count, making the
        pre-published (separator, right child) entry visible.  The
        linearization point of the whole split."""
        left_base = right_base - self.leaf_words
        ptr_addr, old_base = self._route(sep)
        if old_base in (left_base, right_base):
            return True                      # already installed (helper)
        m = self._read(self.meta_addr)
        if (m & COUNT_MASK) != n:
            return self.root_count() > n
        (res,) = self.backend.execute(
            [MwCASOp([(self.meta_addr, m, m + 1),
                      (ptr_addr, old_base, left_base)])])
        self.mwcas_submitted += 1
        if res.success:
            self.mwcas_won += 1
            self.splits += 1
            return True
        return self.root_count() > n         # a helper completed it

    def ensure_room(self, leaf_base: int) -> bool:
        """Public split entry point for external round compilers (the
        sharded service layer): split — or complete the pending split
        of — the leaf a :class:`NeedsSplit` verdict named.  Returns
        False when the root is full; raises
        :class:`~repro.structures.OutOfRegions` when the allocator is
        exhausted — the typed FULL-vs-conflict distinction the service
        records.  Either way the caller should report FULL for the
        blocked ops."""
        return self._split_leaf(leaf_base)

    def _split_leaf(self, leaf_base: int) -> bool:
        """Split (or complete the pending split of) one leaf.

        Returns False only when the tree cannot grow: the root entry
        array is full or no free region remains.  Idempotent under
        crash/retry — each stage either finds its work already done or
        redoes it from persisted state alone.
        """
        leaf = LeafNode(self.backend, leaf_base, self.leaf_cap)
        n = self.root_count()
        if n < self.root_cap:
            sep_w = self._read(self.sep_addr(n))
            child_w = self._read(self.child_addr(n))
            if sep_w and child_w:
                # round 1 already committed (this leaf's split or another
                # pending one): complete its install, then let the caller
                # recompile and retry
                return self._install(n, sep_w, child_w)
        if n >= self.root_cap and len(leaf.keys()) >= 2:
            return False            # cannot grow — don't freeze the leaf
        # claim the target region BEFORE freezing: a leaf frozen with no
        # region to split into would be wedged forever (update/delete on
        # its live keys could never complete).  OutOfRegions propagates:
        # the leaf is untouched, and apply()/the service map it to FULL
        (grant,) = self.allocator.alloc([1])
        if grant is None:
            return False
        leaf.freeze()
        ks = leaf.keys()
        if len(ks) < 2:
            return self._consolidate(leaf, grant)
        if n >= self.root_cap:
            self.allocator.free(grant)
            return False
        pair = self.allocator.region(grant[0])
        left_base, right_base = pair, pair + self.leaf_words
        sep = ks[len(ks) // 2]
        try:
            # round 1: the existing one-wide-MwCAS split, with the parent
            # pre-entry folded into the same atomic op (invisible until
            # round 2 bumps the count)
            leaf.split(left_base, right_base,
                       extra_targets=[(self.sep_addr(n), 0, sep),
                                      (self.child_addr(n), 0, right_base)])
        except SplitError:
            self.allocator.free(grant)       # nothing was written (atomic)
            return False
        self.mwcas_submitted += 2            # freeze + wide materialize
        self.mwcas_won += 2
        return self._install(n, sep, right_base)

    def _consolidate(self, leaf: LeafNode, grant: List[int]) -> bool:
        """A full leaf with < 2 live keys cannot split; materialize one
        compacted node (same one-wide-MwCAS image) and swing the routing
        pointer to it (1-word install, no root entry needed).  ``grant``
        is the region the caller (``_split_leaf``) already claimed."""
        new_base = self.allocator.region(grant[0])
        ks = leaf.keys()
        (res,) = self.backend.execute(
            [MwCASOp(leaf._node_image(new_base, ks))])
        self.mwcas_submitted += 1
        if not res.success:
            self.allocator.free(grant)
            return False
        self.mwcas_won += 1
        ptr_addr, old = self._ptr_word_of(leaf.base)
        (res2,) = self.backend.execute(
            [MwCASOp([(ptr_addr, old, new_base)])])
        self.mwcas_submitted += 1
        if res2.success:
            self.mwcas_won += 1
            self.consolidations += 1
        return bool(res2.success)

    def _ptr_word_of(self, node_base: int) -> Tuple[int, int]:
        """The routing word currently holding ``node_base``."""
        if self._read(self.ptr0_addr) == node_base:
            return self.ptr0_addr, node_base
        for i in range(self.root_count()):
            if self._read(self.child_addr(i)) == node_base:
                return self.child_addr(i), node_base
        raise TornStructure(f"node@{node_base} is not routed by the root")

    # -- round-based execution -------------------------------------------------
    def apply(self, ops: Sequence[KVOp],
              max_rounds: Optional[int] = None) -> List[StructResult]:
        """Execute one batch of logical ops; losers retry next round.

        Ops that hit a full (or frozen mid-split) leaf trigger the split
        protocol between rounds and recompile against the grown tree.
        """
        max_rounds = 2 * len(ops) + 4 if max_rounds is None else max_rounds
        results: List[Optional[StructResult]] = [None] * len(ops)
        pending = list(range(len(ops)))
        self.last_history = []
        rounds = 0
        split_budget = 2 * self.n_regions + 4
        while pending and rounds < max_rounds:
            snap = self.snapshot()
            batch_ops: List[MwCASOp] = []
            owners: List[int] = []
            needs: Dict[int, List[int]] = {}
            for idx in pending:
                compiled = self.compile_op(ops[idx], snap)
                if isinstance(compiled, StructResult):
                    compiled.rounds = rounds
                    results[idx] = compiled
                elif isinstance(compiled, _NeedsSplit):
                    needs.setdefault(compiled.leaf_base, []).append(idx)
                else:
                    batch_ops.append(compiled)
                    owners.append(idx)
            if needs:
                # grow first, then recompile EVERYone against the new
                # tree shape (ops compiled above would mostly lose their
                # round anyway: the split freezes their leaf's meta)
                for leaf_base, idxs in needs.items():
                    try:
                        grew = split_budget > 0 and \
                            self._split_leaf(leaf_base)
                    except OutOfRegions:
                        grew = False         # region-exhausted == FULL here
                    if grew:
                        split_budget -= 1
                    else:
                        for idx in idxs:
                            results[idx] = StructResult(ops[idx], FULL,
                                                        rounds=rounds)
                pending = [i for i in pending if results[i] is None]
                continue
            if not batch_ops:
                pending = []
                break
            rounds += 1
            self.rounds_run += 1
            verdicts = self.backend.execute(batch_ops)
            success = np.asarray([r.success for r in verdicts])
            self.last_history.append(
                RoundTrace(ops=batch_ops, owners=owners, success=success))
            self.mwcas_submitted += len(batch_ops)
            self.mwcas_won += int(success.sum())
            still: List[int] = []
            for pos, idx in enumerate(owners):
                if success[pos]:
                    results[idx] = StructResult(ops[idx], OK, rounds=rounds)
                else:
                    still.append(idx)
            pending = still
        for idx in pending:
            results[idx] = StructResult(ops[idx], EXHAUSTED, rounds=rounds)
        assert all(r is not None for r in results)
        return results               # type: ignore[return-value]

    # -- region GC (ROADMAP: frozen split originals stay claimed) --------------
    def gc_regions(self) -> int:
        """Recovery-time region GC: free pair regions that no routing
        word references — the frozen originals of completed splits,
        consolidated-away leaves and crash-abandoned halves.  Without
        this, a long-running service workload leaks one region per
        split/consolidation until the allocator reports
        :class:`OutOfRegions` (the WAL side is pruned by
        ``prune_completed``; this is the word side).

        A region is live iff one of its two node bases is referenced by
        ``ptr0``, a visible child entry, or the *invisible pre-entry* at
        the root's append position (a pending split's right half — its
        left sibling shares the pair, so the pair stays claimed until
        the install completes).  Everything else holding non-zero words
        is residue: it is zeroed with ONE wide MwCAS (atomic — a crash
        mid-GC leaves the region whole and still unreferenced, so the
        next pass retakes it) and returned to the free list.  Returns
        the number of regions freed.
        """
        snap = self.snapshot()
        referenced = set(self.leaf_bases(snap))
        n = self.root_count(snap)
        if n < self.root_cap:
            pre_child = self._w(snap, self.child_addr(n))
            if pre_child:
                # pending split: protect the half-materialized pair
                referenced.add(pre_child)
                referenced.add(pre_child - self.leaf_words)
        live_slots = {self._slot_of(b) for b in referenced}
        freed = 0
        for slot in range(self.n_regions):
            lo = self.allocator.region(slot) - self.base
            words = snap[lo:lo + self.pair_words]
            if slot in live_slots or not words.any():
                continue
            base_addr = self.base + lo
            targets = [(base_addr + j, int(w), 0)
                       for j, w in enumerate(words) if w]
            (res,) = self.backend.execute([MwCASOp(targets)])
            self.mwcas_submitted += 1
            if not res.success:
                continue                 # raced: next GC pass retakes it
            self.mwcas_won += 1
            self.allocator.free([slot])
            freed += 1
        return freed

    # -- integrity -------------------------------------------------------------
    def check_integrity(self, snap: Optional[np.ndarray] = None
                        ) -> Dict[int, int]:
        """Assert the multi-node invariants; return the live items.

        Checked (each is an atomicity consequence of the protocol —
        violating any means a torn MwCAS, which must never happen):

        - no half-written root entry: entries below the count are fully
          populated, the append position is all-zero or a complete
          pre-entry, and nothing exists beyond it;
        - no torn leaf image: key and value words below the arrival
          count are populated together, words beyond it are zero;
        - routing: every live key sits in the exact leaf the separators
          route it to, and no key is live in two leaves.
        """
        snap = self.snapshot() if snap is None else snap
        m = int(snap[self.meta_addr - self.base])
        n = m & COUNT_MASK
        if m & FROZEN_BIT:
            raise TornStructure("root meta has FROZEN_BIT set")
        if n > self.root_cap:
            raise TornStructure(f"root count {n} > capacity {self.root_cap}")
        if int(snap[self.ptr0_addr - self.base]) == 0:
            if n:
                raise TornStructure("root entries without a leftmost child")
            return {}                        # pre-bootstrap empty tree
        for i in range(n):
            if not self._w(snap, self.sep_addr(i)) or \
                    not self._w(snap, self.child_addr(i)):
                raise TornStructure(f"root entry {i} below count is torn")
        for i in range(n, self.root_cap):
            s = self._w(snap, self.sep_addr(i))
            c = self._w(snap, self.child_addr(i))
            if i == n:
                if bool(s) != bool(c):
                    raise TornStructure(
                        f"half-written pre-entry at append position {n}: "
                        f"sep={s} child={c}")
            elif s or c:
                raise TornStructure(
                    f"root entry {i} beyond append position {n} is claimed")
        entries = self._entries(snap)
        seps = [sep for sep, _c, _a in entries]
        if len(set(seps)) != len(seps):
            raise TornStructure(f"duplicate separators {seps}")
        bases = [int(snap[self.ptr0_addr - self.base])] + \
            [child for _s, child, _a in entries]
        lows = [None] + seps
        highs = seps + [None]
        items: Dict[int, int] = {}
        for lb, lo, hi in zip(bases, lows, highs):
            lm = self._w(snap, lb)
            cnt = lm & COUNT_MASK
            if cnt > self.leaf_cap:
                raise TornStructure(f"leaf@{lb} count {cnt} > capacity")
            for i in range(self.leaf_cap):
                k = self._w(snap, lb + 1 + i)
                v = self._w(snap, lb + 1 + self.leaf_cap + i)
                if i < cnt:
                    if k == 0 or v == 0:
                        raise TornStructure(
                            f"leaf@{lb} slot {i}: torn pair key={k} val={v}")
                    if v != LEAF_DEAD:
                        if k in items:
                            raise TornStructure(
                                f"key {k} live in two leaves")
                        if (lo is not None and k < lo) or \
                                (hi is not None and k >= hi):
                            raise TornStructure(
                                f"leaf@{lb} holds misrouted key {k} "
                                f"(range [{lo}, {hi}))")
                        items[k] = v
                elif k or v:
                    raise TornStructure(
                        f"leaf@{lb} ghost words beyond count {cnt}")
        return items

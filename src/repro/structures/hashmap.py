"""Fixed-capacity open-addressing hash map on the unified PMwCAS API.

The paper's closing claim is that a fast PMwCAS unlocks lock-free
persistent data structures; this is the first one in the repo.  Every
mutation compiles to exactly ONE 2-word :class:`repro.pmwcas.MwCASOp`
over the bucket's pair of words, so the structure runs unchanged on any
:class:`repro.pmwcas.Backend` (simulator shadow, Pallas kernel, durable
committer):

======== =========================================== =====================
op       MwCAS targets                               crash invariant
======== =========================================== =====================
insert   (key word: EMPTY/TOMB -> key,               key never visible
          value word: 0 -> value)                    without its value
update   (key word: key -> key  [guard],             value moves only
          value word: old -> new)                    while key unchanged
delete   (key word: key -> TOMBSTONE,                chain stays probe-
          value word: old -> 0)                      able; pair atomic
======== =========================================== =====================

Bucket ``b`` owns words ``base + 2b`` (key) and ``base + 2b + 1``
(value) — addresses are adjacent and ascending, i.e. already in the
paper's canonical sorted embedding order.

**Directory doubling** (opt-in via ``max_doublings > 0``) makes the
capacity elastic with the same decide -> materialize -> swing protocol
the tree uses for its root split (DESIGN.md Sec. 12):

- a 2-word header precedes the arrays: word ``base`` is the
  *generation word* ``g | MIG_BIT`` (MIG_BIT set while a doubling is
  in flight), word ``base + 1`` is reserved (always 0);
- generation ``g``'s array lives at ``base + 2 + 2*n0*(2^g - 1)`` with
  ``n0 * 2^g`` buckets — every generation has a fixed home, so no
  address is ever reused across generations;
- **decide**: a full insert verdict becomes :class:`NeedsResize`;
  ``begin_resize`` publishes the decision with ONE 1-word CAS
  ``g -> g | MIG_BIT`` (the persisted decision record);
- **materialize**: ``resize_step`` pumps live keys old -> new with
  4-word *move* ops (old pair dies, new pair is born, atomically; no
  generation guard — moves are pairwise disjoint) while client ops
  proceed against the split-brain table under a generation-word guard
  ``(gen, G, G)``: insert goes to the new array (3 words), update of a
  not-yet-moved key is *move-on-write* (5 words), delete hits
  whichever array holds the key (3 words).  A finalize racing any
  guarded op changes the generation word, so the guard converts the
  race into a normal CAS retry — never a lost update;
- **swing**: once the old array holds no live key, ONE 1-word CAS
  ``g | MIG_BIT -> g + 1`` retires the old generation.

A crash at any persist lands in one of three self-describing states —
MIG unset (pre-growth), MIG set (the split-brain table, valid for
reads/writes indefinitely; any later op resumes the pump), or the next
generation (post-growth) — which is exactly what
:func:`repro.structures.check_hashmap_resize_sweep` sweeps.

Execution is round-based (the batched analogue of the lock-free retry
loop): every logical op is compiled against one snapshot of the table,
the whole round executes as one backend batch under the deterministic
one-shot semantics, and losers are recompiled against the next snapshot.
All compiled ops carry pre-batch expected values, so condition (a) of
the batch semantics always passes and the lowest-index op of every
conflict component wins — each round commits at least one op and the
retry loop terminates in at most ``len(ops)`` rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.pmwcas import Backend, MwCASOp

EMPTY = 0
TOMBSTONE = (1 << 32) - 1          # uint32 max; keys/values must stay below

MIG_BIT = 1 << 30                  # generation word: doubling in flight
GEN_MASK = MIG_BIT - 1

# logical operation kinds
READ, INSERT, UPDATE, DELETE, SCAN = ("read", "insert", "update", "delete",
                                      "scan")
_KINDS = (READ, INSERT, UPDATE, DELETE, SCAN)

# result statuses
OK = "ok"                  # committed (mutations) / answered (reads)
EXISTS = "exists"          # insert found the key already live
NOT_FOUND = "not_found"    # update/delete/read missed
FULL = "full"              # insert found no writable bucket
EXHAUSTED = "exhausted"    # still losing conflicts after max_rounds


class TornStructure(AssertionError):
    """A bucket pair violates the crash invariant — must never happen."""


@dataclasses.dataclass(frozen=True)
class NeedsResize:
    """Insert verdict: generation ``gen`` is full and a doubling is both
    allowed (``gen < max_doublings``) and required to make room.
    :meth:`HashMap.apply` answers it by publishing the resize decision
    (``begin_resize``) and retrying the op against the split-brain
    table; standalone compilers hand it to :meth:`HashMap.ensure_room`.
    """
    gen: int


@dataclasses.dataclass(frozen=True)
class KVOp:
    """One logical hash-map operation (the workload vocabulary)."""
    kind: str
    key: int
    value: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}")
        if not 0 < self.key < TOMBSTONE:
            raise ValueError(f"key {self.key} outside (0, 2^32-1)")
        if self.kind in (INSERT, UPDATE) and not 0 < self.value < TOMBSTONE:
            raise ValueError(f"{self.kind} needs a value in (0, 2^32-1)")


@dataclasses.dataclass
class StructResult:
    """Per-logical-op outcome of :meth:`HashMap.apply`."""
    op: KVOp
    status: str
    value: Optional[int] = None    # reads: the value found (None on miss)
    rounds: int = 0                # CAS rounds this op participated in

    def __bool__(self) -> bool:
        return self.status == OK


@dataclasses.dataclass
class RoundTrace:
    """One executed round: the compiled batch and its verdicts.

    Recorded by :meth:`HashMap.apply` so the structure differential can
    replay every round through a shadow simulator batch.
    """
    ops: List[MwCASOp]
    owners: List[int]              # batch position -> logical op index
    success: np.ndarray            # bool[B]


class HashMap:
    """Open-addressing (linear probing, tombstone) map over a Backend.

    The map holds no authoritative state of its own: keys and values
    live in the backend's word table, read back via ``backend.read`` —
    which is what makes a crash/recover cycle on the durable backend
    transparent (attach a fresh ``HashMap`` to the recovered backend).
    """

    def __init__(self, backend: Backend, n_buckets: int, base: int = 0, *,
                 max_doublings: int = 0):
        if n_buckets < 1:
            raise ValueError("need at least one bucket")
        if max_doublings < 0:
            raise ValueError("max_doublings must be >= 0")
        self.backend = backend
        self.n_buckets = n_buckets           # generation-0 bucket count
        self.base = base
        self.max_doublings = max_doublings
        self.hdr = 2 if max_doublings else 0  # generation word + reserved
        self.last_history: List[RoundTrace] = []
        # cumulative instrumentation across apply() calls
        self.rounds_run = 0
        self.mwcas_submitted = 0
        self.mwcas_won = 0
        self.resizes = 0                     # doublings finalized
        self.keys_migrated = 0               # pump moves committed

    # -- layout ----------------------------------------------------------------
    @property
    def gen_addr(self) -> int:
        return self.base

    def gen_state(self, snap: Optional[np.ndarray] = None
                  ) -> Tuple[int, bool]:
        """(current generation, doubling in flight?)."""
        if not self.hdr:
            return 0, False
        w = (int(self.backend.read(self.gen_addr)) if snap is None
             else int(snap[0]))
        return w & GEN_MASK, bool(w & MIG_BIT)

    @property
    def gen(self) -> int:
        return self.gen_state()[0]

    @property
    def migrating(self) -> bool:
        return self.gen_state()[1]

    def cap(self, g: int = 0) -> int:
        return self.n_buckets << g

    def arr_off(self, g: int = 0) -> int:
        """Generation ``g``'s array offset within the map's region."""
        return self.hdr + 2 * self.n_buckets * ((1 << g) - 1)

    def key_addr(self, bucket: int, g: int = 0) -> int:
        return self.base + self.arr_off(g) + 2 * bucket

    def value_addr(self, bucket: int, g: int = 0) -> int:
        return self.key_addr(bucket, g) + 1

    @property
    def n_words(self) -> int:
        return self.words_needed(self.n_buckets, self.max_doublings)

    @staticmethod
    def words_needed(n_buckets: int, max_doublings: int = 0,
                     base: int = 0) -> int:
        """Word footprint: every generation has a fixed, disjoint home."""
        if max_doublings == 0:
            return base + 2 * n_buckets
        return base + 2 + 2 * n_buckets * ((1 << (max_doublings + 1)) - 1)

    def _home(self, key: int, g: int = 0) -> int:
        return (key * 2654435761) % self.cap(g)    # Knuth multiplicative

    # -- reads -----------------------------------------------------------------
    def snapshot(self) -> np.ndarray:
        """One consistent-enough read of the whole region (int64[2N]).

        Array-shaped backends expose the full word table in one call;
        the durable backend resolves slots one at a time.
        """
        values = getattr(self.backend, "values", None)
        if callable(values):
            table = np.asarray(values(), np.int64)
            return table[self.base:self.base + self.n_words]
        return np.asarray([self.backend.read(self.base + i)
                           for i in range(self.n_words)], np.int64)

    def _locate(self, key: int, snap: np.ndarray, g: int = 0,
                claimed: Optional[set] = None
                ) -> Tuple[Optional[int], Optional[int]]:
        """(bucket holding key or None, first writable bucket or None)
        within generation ``g``'s array.  ``claimed`` buckets (reserved
        by another move compiled against the same snapshot) probe as
        occupied — the chain stays walkable once the claims commit."""
        off, cap = self.arr_off(g), self.cap(g)
        writable = None
        b = self._home(key, g)
        for _ in range(cap):
            if claimed is not None and b in claimed:
                b = (b + 1) % cap
                continue
            kw = int(snap[off + 2 * b])
            if kw == key:
                return b, writable
            if kw == TOMBSTONE:
                if writable is None:
                    writable = b
            elif kw == EMPTY:
                return None, b if writable is None else writable
            b = (b + 1) % cap
        return None, writable

    def lookup(self, key: int,
               snap: Optional[np.ndarray] = None) -> Optional[int]:
        snap = self.snapshot() if snap is None else snap
        g, mig = self.gen_state(snap)
        for gi in ((g + 1, g) if mig else (g,)):
            b, _ = self._locate(key, snap, gi)
            if b is not None:
                return int(snap[self.arr_off(gi) + 2 * b + 1])
        return None

    def _gen_items(self, snap: np.ndarray, g: int) -> Dict[int, int]:
        off = self.arr_off(g)
        out = {}
        for b in range(self.cap(g)):
            kw = int(snap[off + 2 * b])
            if kw not in (EMPTY, TOMBSTONE):
                out[kw] = int(snap[off + 2 * b + 1])
        return out

    def items(self, snap: Optional[np.ndarray] = None) -> Dict[int, int]:
        """All live (key, value) pairs (union of both generations while
        a doubling is in flight — a key is live in exactly one)."""
        snap = self.snapshot() if snap is None else snap
        g, mig = self.gen_state(snap)
        out = self._gen_items(snap, g)
        if mig:
            out.update(self._gen_items(snap, g + 1))
        return out

    def check_integrity(self, snap: Optional[np.ndarray] = None
                        ) -> Dict[int, int]:
        """Assert no bucket pair is torn; return the live items.

        Invariant (each mutation moves both words in ONE MwCAS):
        key EMPTY or TOMBSTONE  <=>  value == 0 — in every generation.
        Additionally for elastic maps: retired generations are drained,
        no key is live in two generations at once, future generations
        are untouched (all-zero), and the generation word is in range.
        """
        snap = self.snapshot() if snap is None else snap
        g, mig = self.gen_state(snap)
        if self.hdr:
            if g > self.max_doublings or (mig and g >= self.max_doublings):
                raise TornStructure(
                    f"generation word {g}{'|MIG' if mig else ''} out of "
                    f"range (max_doublings={self.max_doublings})")
            if int(snap[1]) != 0:
                raise TornStructure(
                    f"reserved header word is {int(snap[1])}, not 0")

        def check_pairs(gi: int, drained: bool) -> None:
            off = self.arr_off(gi)
            for b in range(self.cap(gi)):
                kw, vw = int(snap[off + 2 * b]), int(snap[off + 2 * b + 1])
                if kw in (EMPTY, TOMBSTONE):
                    if vw != 0:
                        raise TornStructure(f"gen {gi} bucket {b}: key "
                                            f"word {kw} but value {vw} != 0")
                elif vw == 0:
                    raise TornStructure(f"gen {gi} bucket {b}: live key "
                                        f"{kw} with value 0 (torn insert)")
                elif drained:
                    raise TornStructure(f"gen {gi} bucket {b}: key {kw} "
                                        "still live in a retired generation")

        for gi in range(g):
            check_pairs(gi, drained=True)
        check_pairs(g, drained=False)
        if mig:
            check_pairs(g + 1, drained=False)
            both = set(self._gen_items(snap, g)) & set(
                self._gen_items(snap, g + 1))
            if both:
                raise TornStructure(
                    f"keys live in two generations at once: {sorted(both)}")
        future = self.arr_off(g + 2 if mig else g + 1)
        if future < self.n_words and np.asarray(snap[future:]).any():
            raise TornStructure("future generation array is not all-zero")
        return self.items(snap)

    # -- operation compilation -------------------------------------------------
    def compile_op(self, op: KVOp, snap: np.ndarray
                   ) -> Union[MwCASOp, StructResult, NeedsResize]:
        """One logical op -> one MwCASOp (or an immediate verdict).

        Expected values come from ``snap``; executing the compiled op in
        the same round as its snapshot guarantees condition (a) passes.
        Steady state compiles the classic 2-word shapes; while a
        doubling is in flight every mutation carries the generation-word
        guard and may span both generations (3/5-word shapes).
        """
        g, mig = self.gen_state(snap)
        if mig:
            return self._compile_migrating(op, snap, g)
        found, writable = self._locate(op.key, snap, g)
        off = self.arr_off(g)
        if op.kind == READ:
            val = None if found is None else int(snap[off + 2 * found + 1])
            return StructResult(op, OK if found is not None else NOT_FOUND,
                                value=val)
        if op.kind == SCAN:
            items = self.items(snap)
            return StructResult(op, OK, value=len(
                [k for k in items if k >= op.key]))
        if op.kind == INSERT:
            if found is not None:
                return StructResult(op, EXISTS,
                                    value=int(snap[off + 2 * found + 1]))
            if writable is None:
                if g < self.max_doublings:
                    return NeedsResize(g)
                return StructResult(op, FULL)
            kw_cur = int(snap[off + 2 * writable])   # EMPTY or TOMBSTONE
            return MwCASOp([(self.key_addr(writable, g), kw_cur, op.key),
                            (self.value_addr(writable, g), 0, op.value)])
        if found is None:                            # UPDATE / DELETE miss
            return StructResult(op, NOT_FOUND)
        v_cur = int(snap[off + 2 * found + 1])
        if op.kind == UPDATE:
            # key word is a guard (expected == desired): it pins the key
            # in place and claims the bucket against concurrent deletes
            return MwCASOp([(self.key_addr(found, g), op.key, op.key),
                            (self.value_addr(found, g), v_cur, op.value)])
        return MwCASOp([(self.key_addr(found, g), op.key, TOMBSTONE),
                        (self.value_addr(found, g), v_cur, 0)])

    def _compile_migrating(self, op: KVOp, snap: np.ndarray, g: int
                           ) -> Union[MwCASOp, StructResult]:
        """Compile against the split-brain table (doubling g -> g+1).

        Every mutation is guarded by ``(gen_addr, G, G)`` where
        ``G = g | MIG_BIT``: if the doubling finalizes (or the snapshot
        was stale) the guard fails and the op retries — a generation
        conflict is a normal CAS retry, never a lost update.  Target
        lists are naturally address-sorted: guard < old array < new.
        """
        G = g | MIG_BIT
        guard = (self.gen_addr, G, G)
        fo, _ = self._locate(op.key, snap, g)        # old generation
        fn, wn = self._locate(op.key, snap, g + 1)   # new generation
        off_o, off_n = self.arr_off(g), self.arr_off(g + 1)
        if op.kind == READ:
            if fn is not None:
                return StructResult(op, OK, value=int(snap[off_n + 2*fn + 1]))
            if fo is not None:
                return StructResult(op, OK, value=int(snap[off_o + 2*fo + 1]))
            return StructResult(op, NOT_FOUND)
        if op.kind == SCAN:
            items = self.items(snap)
            return StructResult(op, OK, value=len(
                [k for k in items if k >= op.key]))
        if op.kind == INSERT:
            if fn is not None:
                return StructResult(op, EXISTS,
                                    value=int(snap[off_n + 2 * fn + 1]))
            if fo is not None:
                return StructResult(op, EXISTS,
                                    value=int(snap[off_o + 2 * fo + 1]))
            if wn is None:
                return StructResult(op, FULL)
            kw_cur = int(snap[off_n + 2 * wn])
            return MwCASOp([guard,
                            (self.key_addr(wn, g + 1), kw_cur, op.key),
                            (self.value_addr(wn, g + 1), 0, op.value)])
        if fn is None and fo is None:                # UPDATE / DELETE miss
            return StructResult(op, NOT_FOUND)
        if op.kind == UPDATE:
            if fn is not None:
                v_cur = int(snap[off_n + 2 * fn + 1])
                return MwCASOp([guard,
                                (self.key_addr(fn, g + 1), op.key, op.key),
                                (self.value_addr(fn, g + 1), v_cur,
                                 op.value)])
            v_cur = int(snap[off_o + 2 * fo + 1])
            if wn is not None:
                # move-on-write: retire the old pair and write the fresh
                # value into the new generation in ONE 5-word op
                kw_cur = int(snap[off_n + 2 * wn])
                return MwCASOp([guard,
                                (self.key_addr(fo, g), op.key, TOMBSTONE),
                                (self.value_addr(fo, g), v_cur, 0),
                                (self.key_addr(wn, g + 1), kw_cur, op.key),
                                (self.value_addr(wn, g + 1), 0, op.value)])
            return MwCASOp([guard,                   # new array full:
                            (self.key_addr(fo, g), op.key, op.key),
                            (self.value_addr(fo, g), v_cur, op.value)])
        if fn is not None:                           # DELETE
            v_cur = int(snap[off_n + 2 * fn + 1])
            return MwCASOp([guard,
                            (self.key_addr(fn, g + 1), op.key, TOMBSTONE),
                            (self.value_addr(fn, g + 1), v_cur, 0)])
        v_cur = int(snap[off_o + 2 * fo + 1])
        return MwCASOp([guard,
                        (self.key_addr(fo, g), op.key, TOMBSTONE),
                        (self.value_addr(fo, g), v_cur, 0)])

    # -- directory doubling ----------------------------------------------------
    def _record_round(self, batch: List[MwCASOp], owners: List[int],
                      success: np.ndarray) -> None:
        self.last_history.append(
            RoundTrace(ops=batch, owners=owners, success=success))
        self.rounds_run += 1
        self.mwcas_submitted += len(batch)
        self.mwcas_won += int(success.sum())

    def begin_resize(self, max_attempts: int = 8) -> bool:
        """Publish the doubling decision: ONE 1-word CAS sets MIG_BIT.

        Idempotent (already migrating -> True); False when the map is
        not elastic or the generation budget is spent.
        """
        if not self.hdr:
            return False
        for _ in range(max_attempts):
            g, mig = self.gen_state()
            if mig:
                return True
            if g >= self.max_doublings:
                return False
            op = MwCASOp([(self.gen_addr, g, g | MIG_BIT)])
            (res,) = self.backend.execute([op])
            self._record_round([op], [], np.asarray([res.success]))
            if res.success:
                return True
        return False

    def resize_step(self, max_moves: Optional[int] = None) -> int:
        """Pump up to ``max_moves`` live keys old -> new generation.

        Every move is ONE 4-word op (old pair dies, new pair is born);
        moves in a round are pairwise disjoint — no generation guard
        needed, they all commit.  Finalizes (1-word CAS ``G -> g+1``)
        once the old array holds no live key.  Returns keys moved.
        """
        g, mig = self.gen_state()
        if not mig:
            return 0
        snap = self.snapshot()
        off_o = self.arr_off(g)
        claimed: set = set()
        batch: List[MwCASOp] = []
        for b in range(self.cap(g)):
            if max_moves is not None and len(batch) >= max_moves:
                break
            kw = int(snap[off_o + 2 * b])
            if kw in (EMPTY, TOMBSTONE):
                continue
            vw = int(snap[off_o + 2 * b + 1])
            fn, wn = self._locate(kw, snap, g + 1, claimed=claimed)
            if fn is not None or wn is None:
                continue       # already moved under our feet / new full
            claimed.add(wn)
            kw_cur = int(snap[self.arr_off(g + 1) + 2 * wn])
            batch.append(MwCASOp([(self.key_addr(b, g), kw, TOMBSTONE),
                                  (self.value_addr(b, g), vw, 0),
                                  (self.key_addr(wn, g + 1), kw_cur, kw),
                                  (self.value_addr(wn, g + 1), 0, vw)]))
        moved = 0
        if batch:
            verdicts = self.backend.execute(batch)
            success = np.asarray([r.success for r in verdicts])
            self._record_round(batch, [], success)
            moved = int(success.sum())
            self.keys_migrated += moved
        # swing: retire the old generation once it is drained
        if not self._gen_items(self.snapshot(), g):
            G = g | MIG_BIT
            op = MwCASOp([(self.gen_addr, G, g + 1)])
            (res,) = self.backend.execute([op])
            self._record_round([op], [], np.asarray([res.success]))
            if res.success:
                self.resizes += 1
        return moved

    def ensure_room(self, max_steps: int = 8) -> bool:
        """Synchronously drive one full doubling to completion (the
        incremental path is :meth:`apply`'s per-round pump)."""
        if not self.begin_resize():
            return False
        for _ in range(max_steps):
            if not self.migrating:
                return True
            self.resize_step()
        return not self.migrating

    # -- round-based execution -------------------------------------------------
    def apply(self, ops: Sequence[KVOp],
              max_rounds: Optional[int] = None) -> List[StructResult]:
        """Execute one batch of logical ops; losers retry next round.

        Elastic maps interleave growth with the client rounds: an
        in-flight doubling pumps a chunk of moves before each round, and
        a :class:`NeedsResize` verdict publishes the decision and
        retries the op against the doubled table.
        """
        max_rounds = len(ops) + 1 if max_rounds is None else max_rounds
        results: List[Optional[StructResult]] = [None] * len(ops)
        pending = list(range(len(ops)))
        self.last_history = []
        rounds = 0
        while pending and rounds < max_rounds:
            if self.hdr and self.migrating:
                self.resize_step(max_moves=max(len(pending), 2))
            snap = self.snapshot()
            batch_ops: List[MwCASOp] = []
            owners: List[int] = []
            still_pending: List[int] = []
            need_resize: List[int] = []
            guard_used = False
            for idx in pending:
                compiled = self.compile_op(ops[idx], snap)
                if isinstance(compiled, NeedsResize):
                    need_resize.append(idx)
                elif isinstance(compiled, StructResult):
                    compiled.rounds = rounds
                    results[idx] = compiled
                elif any(t.addr == self.gen_addr and self.hdr
                         for t in compiled.targets):
                    # generation-guarded mutations serialize: one per
                    # round (the guard word is shared, so all but the
                    # first would lose the CAS anyway — resolve the
                    # conflict at compile time to keep rounds in
                    # lockstep across every substrate)
                    if guard_used:
                        still_pending.append(idx)
                    else:
                        guard_used = True
                        batch_ops.append(compiled)
                        owners.append(idx)
                else:
                    batch_ops.append(compiled)
                    owners.append(idx)
            if batch_ops:
                rounds += 1
                verdicts = self.backend.execute(batch_ops)
                success = np.asarray([r.success for r in verdicts])
                self._record_round(batch_ops, owners, success)
                for pos, idx in enumerate(owners):
                    if success[pos]:
                        results[idx] = StructResult(ops[idx], OK,
                                                    rounds=rounds)
                    else:
                        still_pending.append(idx)
            if need_resize:
                if self.begin_resize():
                    still_pending.extend(need_resize)
                else:
                    for idx in need_resize:
                        results[idx] = StructResult(ops[idx], FULL,
                                                    rounds=rounds)
            pending = still_pending
        for idx in pending:
            results[idx] = StructResult(ops[idx], EXHAUSTED, rounds=rounds)
        assert all(r is not None for r in results)
        return results               # type: ignore[return-value]

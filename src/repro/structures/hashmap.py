"""Fixed-capacity open-addressing hash map on the unified PMwCAS API.

The paper's closing claim is that a fast PMwCAS unlocks lock-free
persistent data structures; this is the first one in the repo.  Every
mutation compiles to exactly ONE 2-word :class:`repro.pmwcas.MwCASOp`
over the bucket's pair of words, so the structure runs unchanged on any
:class:`repro.pmwcas.Backend` (simulator shadow, Pallas kernel, durable
committer):

======== =========================================== =====================
op       MwCAS targets                               crash invariant
======== =========================================== =====================
insert   (key word: EMPTY/TOMB -> key,               key never visible
          value word: 0 -> value)                    without its value
update   (key word: key -> key  [guard],             value moves only
          value word: old -> new)                    while key unchanged
delete   (key word: key -> TOMBSTONE,                chain stays probe-
          value word: old -> 0)                      able; pair atomic
======== =========================================== =====================

Bucket ``b`` owns words ``base + 2b`` (key) and ``base + 2b + 1``
(value) — addresses are adjacent and ascending, i.e. already in the
paper's canonical sorted embedding order.

Execution is round-based (the batched analogue of the lock-free retry
loop): every logical op is compiled against one snapshot of the table,
the whole round executes as one backend batch under the deterministic
one-shot semantics, and losers are recompiled against the next snapshot.
All compiled ops carry pre-batch expected values, so condition (a) of
the batch semantics always passes and the lowest-index op of every
conflict component wins — each round commits at least one op and the
retry loop terminates in at most ``len(ops)`` rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.pmwcas import Backend, MwCASOp

EMPTY = 0
TOMBSTONE = (1 << 32) - 1          # uint32 max; keys/values must stay below

# logical operation kinds
READ, INSERT, UPDATE, DELETE, SCAN = ("read", "insert", "update", "delete",
                                      "scan")
_KINDS = (READ, INSERT, UPDATE, DELETE, SCAN)

# result statuses
OK = "ok"                  # committed (mutations) / answered (reads)
EXISTS = "exists"          # insert found the key already live
NOT_FOUND = "not_found"    # update/delete/read missed
FULL = "full"              # insert found no writable bucket
EXHAUSTED = "exhausted"    # still losing conflicts after max_rounds


class TornStructure(AssertionError):
    """A bucket pair violates the crash invariant — must never happen."""


@dataclasses.dataclass(frozen=True)
class KVOp:
    """One logical hash-map operation (the workload vocabulary)."""
    kind: str
    key: int
    value: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}")
        if not 0 < self.key < TOMBSTONE:
            raise ValueError(f"key {self.key} outside (0, 2^32-1)")
        if self.kind in (INSERT, UPDATE) and not 0 < self.value < TOMBSTONE:
            raise ValueError(f"{self.kind} needs a value in (0, 2^32-1)")


@dataclasses.dataclass
class StructResult:
    """Per-logical-op outcome of :meth:`HashMap.apply`."""
    op: KVOp
    status: str
    value: Optional[int] = None    # reads: the value found (None on miss)
    rounds: int = 0                # CAS rounds this op participated in

    def __bool__(self) -> bool:
        return self.status == OK


@dataclasses.dataclass
class RoundTrace:
    """One executed round: the compiled batch and its verdicts.

    Recorded by :meth:`HashMap.apply` so the structure differential can
    replay every round through a shadow simulator batch.
    """
    ops: List[MwCASOp]
    owners: List[int]              # batch position -> logical op index
    success: np.ndarray            # bool[B]


class HashMap:
    """Open-addressing (linear probing, tombstone) map over a Backend.

    The map holds no authoritative state of its own: keys and values
    live in the backend's word table, read back via ``backend.read`` —
    which is what makes a crash/recover cycle on the durable backend
    transparent (attach a fresh ``HashMap`` to the recovered backend).
    """

    def __init__(self, backend: Backend, n_buckets: int, base: int = 0):
        if n_buckets < 1:
            raise ValueError("need at least one bucket")
        self.backend = backend
        self.n_buckets = n_buckets
        self.base = base
        self.last_history: List[RoundTrace] = []
        # cumulative instrumentation across apply() calls
        self.rounds_run = 0
        self.mwcas_submitted = 0
        self.mwcas_won = 0

    # -- layout ----------------------------------------------------------------
    def key_addr(self, bucket: int) -> int:
        return self.base + 2 * bucket

    def value_addr(self, bucket: int) -> int:
        return self.base + 2 * bucket + 1

    @property
    def n_words(self) -> int:
        return 2 * self.n_buckets

    def _home(self, key: int) -> int:
        return (key * 2654435761) % self.n_buckets     # Knuth multiplicative

    # -- reads -----------------------------------------------------------------
    def snapshot(self) -> np.ndarray:
        """One consistent-enough read of the whole region (int64[2N]).

        Array-shaped backends expose the full word table in one call;
        the durable backend resolves slots one at a time.
        """
        values = getattr(self.backend, "values", None)
        if callable(values):
            table = np.asarray(values(), np.int64)
            return table[self.base:self.base + self.n_words]
        return np.asarray([self.backend.read(self.base + i)
                           for i in range(self.n_words)], np.int64)

    def _locate(self, key: int, snap: np.ndarray
                ) -> Tuple[Optional[int], Optional[int]]:
        """(bucket holding key or None, first writable bucket or None)."""
        writable = None
        b = self._home(key)
        for _ in range(self.n_buckets):
            kw = int(snap[2 * b])
            if kw == key:
                return b, writable
            if kw == TOMBSTONE:
                if writable is None:
                    writable = b
            elif kw == EMPTY:
                return None, b if writable is None else writable
            b = (b + 1) % self.n_buckets
        return None, writable

    def lookup(self, key: int,
               snap: Optional[np.ndarray] = None) -> Optional[int]:
        snap = self.snapshot() if snap is None else snap
        b, _ = self._locate(key, snap)
        return None if b is None else int(snap[2 * b + 1])

    def items(self, snap: Optional[np.ndarray] = None) -> Dict[int, int]:
        """All live (key, value) pairs."""
        snap = self.snapshot() if snap is None else snap
        out = {}
        for b in range(self.n_buckets):
            kw = int(snap[2 * b])
            if kw not in (EMPTY, TOMBSTONE):
                out[kw] = int(snap[2 * b + 1])
        return out

    def check_integrity(self, snap: Optional[np.ndarray] = None
                        ) -> Dict[int, int]:
        """Assert no bucket pair is torn; return the live items.

        Invariant (each mutation moves both words in ONE MwCAS):
        key EMPTY or TOMBSTONE  <=>  value == 0.
        """
        snap = self.snapshot() if snap is None else snap
        for b in range(self.n_buckets):
            kw, vw = int(snap[2 * b]), int(snap[2 * b + 1])
            if kw in (EMPTY, TOMBSTONE):
                if vw != 0:
                    raise TornStructure(
                        f"bucket {b}: key word {kw} but value {vw} != 0")
            elif vw == 0:
                raise TornStructure(
                    f"bucket {b}: live key {kw} with value 0 (torn insert)")
        return self.items(snap)

    # -- operation compilation -------------------------------------------------
    def compile_op(self, op: KVOp, snap: np.ndarray
                   ) -> Union[MwCASOp, StructResult]:
        """One logical op -> one 2-word MwCASOp (or an immediate result).

        Expected values come from ``snap``; executing the compiled op in
        the same round as its snapshot guarantees condition (a) passes.
        """
        found, writable = self._locate(op.key, snap)
        if op.kind == READ:
            val = None if found is None else int(snap[2 * found + 1])
            return StructResult(op, OK if found is not None else NOT_FOUND,
                                value=val)
        if op.kind == SCAN:
            items = self.items(snap)
            return StructResult(op, OK, value=len(
                [k for k in items if k >= op.key]))
        if op.kind == INSERT:
            if found is not None:
                return StructResult(op, EXISTS,
                                    value=int(snap[2 * found + 1]))
            if writable is None:
                return StructResult(op, FULL)
            kw_cur = int(snap[2 * writable])         # EMPTY or TOMBSTONE
            return MwCASOp([(self.key_addr(writable), kw_cur, op.key),
                            (self.value_addr(writable), 0, op.value)])
        if found is None:                            # UPDATE / DELETE miss
            return StructResult(op, NOT_FOUND)
        v_cur = int(snap[2 * found + 1])
        if op.kind == UPDATE:
            # key word is a guard (expected == desired): it pins the key
            # in place and claims the bucket against concurrent deletes
            return MwCASOp([(self.key_addr(found), op.key, op.key),
                            (self.value_addr(found), v_cur, op.value)])
        return MwCASOp([(self.key_addr(found), op.key, TOMBSTONE),
                        (self.value_addr(found), v_cur, 0)])

    # -- round-based execution -------------------------------------------------
    def apply(self, ops: Sequence[KVOp],
              max_rounds: Optional[int] = None) -> List[StructResult]:
        """Execute one batch of logical ops; losers retry next round."""
        max_rounds = len(ops) + 1 if max_rounds is None else max_rounds
        results: List[Optional[StructResult]] = [None] * len(ops)
        pending = list(range(len(ops)))
        self.last_history = []
        rounds = 0
        while pending and rounds < max_rounds:
            snap = self.snapshot()
            batch_ops: List[MwCASOp] = []
            owners: List[int] = []
            still_pending: List[int] = []
            for idx in pending:
                compiled = self.compile_op(ops[idx], snap)
                if isinstance(compiled, StructResult):
                    compiled.rounds = rounds
                    results[idx] = compiled
                else:
                    batch_ops.append(compiled)
                    owners.append(idx)
            if not batch_ops:
                pending = []
                break
            rounds += 1
            self.rounds_run += 1
            verdicts = self.backend.execute(batch_ops)
            success = np.asarray([r.success for r in verdicts])
            self.last_history.append(
                RoundTrace(ops=batch_ops, owners=owners, success=success))
            self.mwcas_submitted += len(batch_ops)
            self.mwcas_won += int(success.sum())
            for pos, idx in enumerate(owners):
                if success[pos]:
                    results[idx] = StructResult(ops[idx], OK, rounds=rounds)
                else:
                    still_pending.append(idx)
            pending = still_pending
        for idx in pending:
            results[idx] = StructResult(ops[idx], EXHAUSTED, rounds=rounds)
        assert all(r is not None for r in results)
        return results               # type: ignore[return-value]

"""Cross-backend differential execution for structure workloads.

``repro.pmwcas.run_differential`` checks one hand-built increment batch;
this module raises the stakes: an entire *logical* hash-map workload runs
to completion on the kernel backend and the durable backend, and every
executed CAS round is additionally replayed NATIVELY through the
cycle-accurate simulator — the real ops, real expected/desired payloads
(keys, values, TOMBSTONEs), mixed widths and all.  The simulator takes
explicit desired values (``SimBackend``'s per-batch value codec +
internal padding), so no shadow translation is needed: each round seeds
a fresh sim from the round's pre-state and must reproduce both the
verdicts and the post-round word values.

Verdicts are compared whenever the conservative and winner-blocking
semantics provably coincide for that round's sharing graph (computed
combinatorially below); rounds where they diverge are counted but not
asserted — that divergence is a documented property of the substrates
(DESIGN.md Sec. 3.2), not a bug.

:func:`shadow_batch` — the older increment-over-fresh-words translation —
remains for the simulator *crash* sweep, which runs rounds through
``SimSession.crash_at``'s recovery invariant (an increment-counting
check).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.pmwcas import (Algorithm, DurableBackend, KernelBackend, MwCASOp,
                          OURS, SimBackend)

from .hashmap import HashMap, KVOp, RoundTrace


def conservative_verdicts(ops: Sequence[MwCASOp]) -> np.ndarray:
    """Kernel/durable semantics for an all-(a)-passing batch: op i loses
    iff a lower-index op (passing (a), i.e. any op here) shares an
    address — every (a)-passer claims its addresses."""
    claimed: set = set()
    out = []
    for op in ops:
        blocked = any(a in claimed for a in op.addrs)
        claimed.update(op.addrs)
        out.append(not blocked)
    return np.asarray(out)


def winner_blocking_verdicts(ops: Sequence[MwCASOp]) -> np.ndarray:
    """Simulator semantics: only actual winners keep their claims (a
    loser's reservations roll back before the next attempt starts)."""
    claimed: set = set()
    out = []
    for op in ops:
        ok = not any(a in claimed for a in op.addrs)
        if ok:
            claimed.update(op.addrs)
        out.append(ok)
    return np.asarray(out)


def shadow_batch(ops: Sequence[MwCASOp]) -> tuple:
    """Map a round onto the simulator's vocabulary: compress the round's
    addresses to 0..n-1 and turn every op into an increment (0 -> 1)
    over its compressed address set.  Returns (n_shadow_words, ops).

    Mixed-width rounds (the tree batches 3-word inserts next to 2-word
    updates) are padded to one uniform width with FRESH private words —
    the simulator requires a uniform k per batch, and a private word is
    invisible to the conflict graph, so verdicts are unchanged.  Padding
    words are appended above the compressed range, preserving each op's
    canonical sorted address order."""
    addrs = sorted({a for op in ops for a in op.addrs})
    index = {a: i for i, a in enumerate(addrs)}
    k_max = max(op.k for op in ops)
    shadow = []
    next_pad = len(addrs)
    for op in ops:
        compressed = sorted(index[a] for a in op.addrs)
        pad = list(range(next_pad, next_pad + k_max - op.k))
        next_pad += len(pad)
        shadow.append(MwCASOp.increment(compressed + pad, [0] * k_max))
    return next_pad, shadow


@dataclasses.dataclass
class StructDifferentialReport:
    kvops: List[KVOp]
    statuses: Dict[str, List[str]]        # backend -> per-logical-op status
    items: Dict[str, Dict[int, int]]      # backend -> final live k/v pairs
    rounds: Dict[str, int]                # backend -> CAS rounds executed
    sim_rounds_checked: int               # shadow rounds asserted against sim
    sim_rounds_skipped: int               # rounds where semantics diverge
    agree: bool

    def summary(self) -> str:
        lines = [f"struct differential over {len(self.kvops)} logical ops: "
                 f"{'AGREE' if self.agree else 'DISAGREE'}"]
        for name, st in self.statuses.items():
            ok = sum(1 for s in st if s == "ok")
            lines.append(f"  {name:8s} ok={ok}/{len(st)} "
                         f"rounds={self.rounds.get(name)}")
        lines.append(f"  sim shadow: {self.sim_rounds_checked} rounds "
                     f"checked, {self.sim_rounds_skipped} skipped "
                     "(winner-blocking != conservative)")
        return "\n".join(lines)


def _replay_rounds_on_sim(history: List[RoundTrace],
                          algorithm: Union[str, Algorithm]) -> tuple:
    """Natively replay every executed round through SimBackend; returns
    (checked, skipped, all_matched).

    Each round's pre-state is reconstructed from the ops' expected
    values (every round op passed condition (a), so expecteds are
    mutually consistent) and the REAL ops run on the micro-op machines —
    actual desired payloads, mixed widths, guard words.  A checked round
    must reproduce the verdicts *and* the post-round values at every
    touched word."""
    checked = skipped = 0
    matched = True
    for trace in history:
        cons = conservative_verdicts(trace.ops)
        wb = winner_blocking_verdicts(trace.ops)
        if not np.array_equal(cons, wb):
            skipped += 1
            continue
        pre: Dict[int, int] = {}
        for op in trace.ops:
            for t in op.targets:
                pre[t.addr] = t.expected
        n_words = max(pre) + 1
        values = np.zeros(n_words, np.uint32)
        for a, v in pre.items():
            values[a] = v
        sim = SimBackend(n_words, algorithm=algorithm, values=values)
        verdicts = np.asarray([r.success for r in sim.execute(trace.ops)])
        checked += 1
        if not np.array_equal(verdicts, np.asarray(trace.success)):
            matched = False
            continue
        # post-round values: a winner's targets moved to desired, every
        # other touched word still holds its pre-round value
        post = dict(pre)
        for ok, op in zip(trace.success, trace.ops):
            if ok:
                for t in op.targets:
                    post[t.addr] = t.desired
        if any(sim.read(a) != v for a, v in post.items()):
            matched = False
    return checked, skipped, matched


def run_struct_differential(kvops: Sequence[KVOp], n_buckets: int = 0, *,
                            structure: str = "hashmap",
                            algorithm: Union[str, Algorithm] = OURS,
                            durable_root=None, use_kernel: bool = False,
                            interpret: bool = True,
                            max_rounds: Optional[int] = None,
                            max_doublings: int = 0,
                            leaf_cap: int = 4, root_cap: int = 8,
                            n_regions: int = 8
                            ) -> StructDifferentialReport:
    """One logical workload on kernel + durable backends, with every
    kernel round shadow-verified on the simulator.  Agreement means:
    identical per-op statuses, identical final live items, identical
    round counts, and every shadow-checked round's verdicts match.

    ``structure`` selects the structure under test: ``"hashmap"`` (size
    by ``n_buckets``; ``max_doublings > 0`` makes it elastic, so growth
    rounds — generation CASes, 4-word pump moves, guarded split-brain
    ops — run in kernel+durable lockstep and shadow-verify on the
    simulator like any other round) or ``"bztree"`` (the multi-node
    tree, sized by ``leaf_cap`` / ``root_cap`` / ``n_regions``; its
    splits, root splits included, are already part of the history)."""
    kvops = list(kvops)
    if structure == "hashmap":
        if n_buckets < 1:
            raise ValueError("hashmap differential needs n_buckets >= 1")
        n_words = HashMap.words_needed(n_buckets, max_doublings)

        def make(backend):
            return HashMap(backend, n_buckets, max_doublings=max_doublings)
    elif structure == "bztree":
        from .bztree_index import BzTreeIndex
        n_words = BzTreeIndex.words_needed(leaf_cap, root_cap, n_regions)

        def make(backend):
            return BzTreeIndex(backend, leaf_cap=leaf_cap,
                               root_cap=root_cap, n_regions=n_regions)
    else:
        raise ValueError(f"unknown structure {structure!r}; "
                         "expected 'hashmap' or 'bztree'")
    kernel = KernelBackend(n_words=n_words, use_kernel=use_kernel,
                           interpret=interpret)
    durable = DurableBackend(durable_root)
    maps = {"kernel": make(kernel), "durable": make(durable)}

    statuses: Dict[str, List[str]] = {}
    items: Dict[str, Dict[int, int]] = {}
    rounds: Dict[str, int] = {}
    histories: Dict[str, List[RoundTrace]] = {}
    for name, hmap in maps.items():
        results = hmap.apply(kvops, max_rounds=max_rounds)
        statuses[name] = [r.status for r in results]
        items[name] = hmap.check_integrity()
        rounds[name] = hmap.rounds_run
        histories[name] = hmap.last_history

    checked, skipped, sim_ok = _replay_rounds_on_sim(
        histories["kernel"], algorithm)

    agree = (statuses["kernel"] == statuses["durable"]
             and items["kernel"] == items["durable"]
             and rounds["kernel"] == rounds["durable"]
             and sim_ok)
    return StructDifferentialReport(
        kvops=kvops, statuses=statuses, items=items, rounds=rounds,
        sim_rounds_checked=checked, sim_rounds_skipped=skipped, agree=agree)

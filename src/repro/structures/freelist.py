"""Free-list allocator layered on the batched ``reserve_slots`` primitive.

The serving layer already had atomic K-slot reservation on a free-bitmap
(``repro.pmwcas.reserve_slots``); this wraps it into an allocator object
the other structures can compose with (e.g. a BzTree split asking for
two fresh node regions).  Allocation requests are themselves MwCAS ops —
request ``i`` atomically claims all of its candidate slots or none —
so concurrent requests linearize by batch index exactly like every
other op in this repo.

The allocator state is the free bitmap (uint32[n_slots], 1 = free); a
slot id maps to a word *region* ``region_base + slot * region_words``
when ``region_words`` is set, which is how callers turn slot grants
into fresh zeroed address ranges for node construction.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.pmwcas import pmwcas_apply, reserve_slots


class DoubleFree(ValueError):
    """A freed slot was already free — allocator misuse."""


class OutOfRegions(RuntimeError):
    """Allocation failed because the free list is EXHAUSTED — there are
    fewer free slots than the request needs — as opposed to a transient
    contention loss (which retries, or surfaces as a ``None`` grant).

    The sharded service layer relies on this distinction: an exhausted
    shard is FULL (reject / grow / re-route), a contended shard just
    retries next round.  ``requests`` holds the indices of the
    unservable requests; ``grants`` holds whatever the same ``alloc``
    call already claimed for other requests — the caller owns those
    slots and must ``free`` them if it no longer wants them.
    """

    def __init__(self, msg: str, requests: Sequence[int] = (),
                 grants: Optional[List[Optional[List[int]]]] = None):
        super().__init__(msg)
        self.requests = tuple(requests)
        self.grants = grants


class FreeListAllocator:
    def __init__(self, n_slots: int, *, region_base: int = 0,
                 region_words: int = 0, use_kernel: bool = False,
                 interpret: bool = True):
        import jax.numpy as jnp
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.region_base = region_base
        self.region_words = region_words
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._mask = jnp.ones((n_slots,), jnp.uint32)

    # -- views -----------------------------------------------------------------
    def mask(self) -> np.ndarray:
        return np.asarray(self._mask)

    @property
    def n_free(self) -> int:
        return int(self.mask().sum())

    def region(self, slot: int) -> int:
        """First word of the region owned by ``slot``."""
        if not self.region_words:
            raise ValueError("allocator built without region mapping")
        return self.region_base + slot * self.region_words

    # -- allocation ------------------------------------------------------------
    def reserve(self, candidates: Sequence[Sequence[int]]) -> List[bool]:
        """Raw path: request i atomically claims exactly its candidate
        slots (all-or-nothing, batch index order).  Exposes the
        contention semantics of ``reserve_slots`` directly."""
        import jax.numpy as jnp
        K = max((len(c) for c in candidates), default=0)
        if K == 0:
            return [True] * len(candidates)
        reqs = np.full((len(candidates), K), -1, np.int32)
        for i, c in enumerate(candidates):
            reqs[i, :len(c)] = sorted(c)
        new_mask, granted = reserve_slots(
            self._mask, jnp.asarray(reqs), use_kernel=self.use_kernel,
            interpret=self.interpret)
        self._mask = new_mask
        return [bool(g) for g in np.asarray(granted)]

    def alloc(self, counts: Sequence[int], max_rounds: int = 4, *,
              on_exhausted: str = "raise") -> List[Optional[List[int]]]:
        """Grant ``counts[i]`` slots to request i.

        Each round partitions the currently-free slots into disjoint
        candidate sets (so a round with enough supply grants everything
        at once); a request denied by contention retries with fresh
        candidates next round.

        Requests that cannot be served because the free list is
        *exhausted* (``count > n_free`` once every servable request got
        its grant) raise :class:`OutOfRegions` — the typed FULL signal
        the service layer distinguishes from conflict.  Pass
        ``on_exhausted="none"`` for the legacy behavior (a ``None``
        grant); a ``None`` under the default mode means the request was
        still losing reservation races after ``max_rounds`` (possible
        only with a concurrent caller mutating the bitmap).
        """
        if on_exhausted not in ("raise", "none"):
            raise ValueError(f"on_exhausted={on_exhausted!r}")
        grants: List[Optional[List[int]]] = [None] * len(counts)
        pending = [i for i, c in enumerate(counts) if c > 0]
        for i, c in enumerate(counts):
            if c == 0:
                grants[i] = []
        for _ in range(max_rounds):
            if not pending:
                break
            free_ids = np.nonzero(self.mask())[0].tolist()
            candidates, owners, cursor = [], [], 0
            for i in pending:
                want = counts[i]
                if cursor + want > len(free_ids):
                    continue               # not enough supply this round
                candidates.append(free_ids[cursor:cursor + want])
                owners.append(i)
                cursor += want
            if not candidates:
                break
            granted = self.reserve(candidates)
            still = [i for i in pending if i not in owners]
            for cand, owner, ok in zip(candidates, owners, granted):
                if ok:
                    grants[owner] = cand
                else:
                    still.append(owner)
            pending = sorted(still)
        exhausted = [i for i in pending if counts[i] > self.n_free]
        if exhausted and on_exhausted == "raise":
            raise OutOfRegions(
                f"free list exhausted: requests {exhausted} need "
                f"{[counts[i] for i in exhausted]} slots but only "
                f"{self.n_free} remain free", requests=exhausted,
                grants=grants)
        return grants

    def free(self, slots: Sequence[int]) -> None:
        """Atomically return a set of slots to the free list (one MwCAS
        flipping every bit 0 -> 1); freeing a free slot is an error."""
        import jax.numpy as jnp
        if not slots:
            return
        ids = sorted(set(slots))
        if len(ids) != len(slots):
            raise DoubleFree(f"duplicate slot ids in free(): {slots}")
        addr = np.asarray(ids, np.int32).reshape(1, -1)
        exp = np.zeros_like(addr, dtype=np.uint32)     # expect claimed
        des = np.ones_like(addr, dtype=np.uint32)      # back to free
        new_mask, success = pmwcas_apply(
            self._mask, jnp.asarray(addr), jnp.asarray(exp),
            jnp.asarray(des), use_kernel=self.use_kernel,
            interpret=self.interpret)
        if not bool(np.asarray(success)[0]):
            raise DoubleFree(f"free() of already-free slot among {ids}")
        self._mask = new_mask

"""repro.structures — lock-free persistent data structures on PMwCAS.

The paper's closing claim is that a practical PMwCAS enables lock-free
persistent data structures; this package is that claim made executable.
Every structure is implemented ONLY against the public ``repro.pmwcas``
surface (``MwCASOp`` + the ``Backend`` protocol), so each one runs
unchanged on the cycle-accurate simulator (shadowed), the batched Pallas
kernel, and the durable descriptor-WAL committer:

- :class:`HashMap` — fixed-capacity open-addressing map; insert/update/
  delete each compile to ONE 2-word MwCAS (key word + value word).
- :class:`SortedNode` — BzTree-style sorted-array node; insert is a
  2-word MwCAS (meta + slot), split freezes then materializes both
  halves with ONE wide MwCAS.
- :class:`BzTreeIndex` — the multi-node payoff: a two-level BzTree of
  :class:`LeafNode` KV leaves under a separator-routing root, leaf
  splits = the one-wide-MwCAS split + a 2-word parent install
  (DESIGN.md Sec. 7).
- :class:`FreeListAllocator` — atomic K-slot reservation layered on
  ``reserve_slots`` (the serving-layer primitive).
- workload compiler — YCSB-style mixes with Zipfian key popularity
  (A/B/C plus the scan-heavy E for the range index), compiled to the
  shared logical-op vocabulary and batched into the kernel's
  ``ops_to_arrays`` wire form.
- checkers + differential — structure-level crash-consistency sweeps
  (durable crash-at-every-persist for map and tree, simulator micro-op
  crash sweep) and :func:`run_struct_differential`, the three-substrate
  agreement check for whole logical workloads.

See DESIGN.md Sec. 6 for operation compilation, per-backend semantics
and the crash invariants, and Sec. 7 for the multi-node tree.
"""
from .bztree import (COUNT_MASK, FROZEN_BIT, NODE_EXHAUSTED, NODE_EXISTS,
                     NODE_FROZEN, NODE_FULL, NODE_OK, SortedNode, SplitError,
                     read_pointer, swap_pointer)
from .bztree_index import (BzTreeIndex, INNER_BIT, LEAF_DEAD, LeafNode,
                           NeedsSplit)
from .checkers import (CrashCheckError, check_durable_crash_sweep,
                       check_hashmap_resize_sweep, check_sim_crash_sweep,
                       check_tree_crash_sweep, replay_effects)
from .differential import (StructDifferentialReport, conservative_verdicts,
                           run_struct_differential, shadow_batch,
                           winner_blocking_verdicts)
from .freelist import DoubleFree, FreeListAllocator, OutOfRegions
from .hashmap import (DELETE, EMPTY, EXHAUSTED, EXISTS, FULL, HashMap,
                      INSERT, KVOp, MIG_BIT, NOT_FOUND, NeedsResize, OK,
                      READ, RoundTrace, SCAN, StructResult, TOMBSTONE,
                      TornStructure, UPDATE)
from .workload import (LOAD, WorkloadSpec, WorkloadStats, YCSB_A, YCSB_B,
                       YCSB_C, YCSB_E, batches, client_streams,
                       compile_workload, interleave, kernel_round_arrays,
                       key_shard, load_phase, partition_ops, run_workload)

__all__ = [
    # hash map
    "HashMap", "KVOp", "StructResult", "RoundTrace", "TornStructure",
    "NeedsResize", "EMPTY", "TOMBSTONE", "MIG_BIT",
    "READ", "INSERT", "UPDATE", "DELETE", "SCAN",
    "OK", "EXISTS", "NOT_FOUND", "FULL", "EXHAUSTED",
    # bztree node
    "SortedNode", "SplitError", "swap_pointer", "read_pointer",
    "FROZEN_BIT", "COUNT_MASK",
    "NODE_OK", "NODE_FULL", "NODE_FROZEN", "NODE_EXISTS", "NODE_EXHAUSTED",
    # multi-node tree
    "BzTreeIndex", "LeafNode", "LEAF_DEAD", "NeedsSplit", "INNER_BIT",
    # allocator
    "FreeListAllocator", "DoubleFree", "OutOfRegions",
    # workload
    "WorkloadSpec", "WorkloadStats", "YCSB_A", "YCSB_B", "YCSB_C", "YCSB_E",
    "LOAD",
    "compile_workload", "load_phase", "batches", "run_workload",
    "kernel_round_arrays", "client_streams", "interleave", "key_shard",
    "partition_ops",
    # checkers + differential
    "check_durable_crash_sweep", "check_sim_crash_sweep",
    "check_tree_crash_sweep", "check_hashmap_resize_sweep",
    "replay_effects",
    "CrashCheckError",
    "run_struct_differential", "StructDifferentialReport",
    "conservative_verdicts", "winner_blocking_verdicts", "shadow_batch",
]

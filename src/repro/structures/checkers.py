"""Structure-level crash-consistency checkers.

Two substrates, two sweeps, one invariant — *no torn multi-word effect,
no lost committed effect*:

- **Durable sweep** (:func:`check_durable_crash_sweep`): replay a logical
  hash-map workload against a :class:`repro.pmwcas.DurableBackend` whose
  pmem pool crashes on the N-th persist, for every N until a run
  completes.  After each crash + recovery the rebuilt map must contain
  exactly the effects of the ops the client saw commit — plus at most
  the one in-flight op (committed iff its SUCCEEDED record was
  persisted before the crash; the client just never saw the verdict).
- **Simulator sweep** (:func:`check_sim_crash_sweep`): shadow a compiled
  structure round into the cycle-accurate simulator (one thread per op,
  the round's address graph preserved) and crash at a sweep of
  micro-op steps via ``SimSession.crash_at``, which already asserts the
  paper's recovery invariant; on top we assert the *structure* reading —
  all words of one op move together (no torn 2-word insert at the
  micro-op granularity either).
- **Tree sweep** (:func:`check_tree_crash_sweep`): the durable sweep
  lifted to the multi-node :class:`repro.structures.BzTreeIndex` —
  crashing at every persist point *through a leaf split* (and, when the
  workload overflows the root, through a root split's pending-word
  handoff) must leave either the pre-split or the fully-linked
  post-split tree (DESIGN.md Sec. 7/12), never a torn node image or a
  half-installed parent entry.
- **Resize sweep** (:func:`check_hashmap_resize_sweep`): the durable
  sweep through directory doubling — every persist of decide / pump /
  split-brain client ops / finalize swing (DESIGN.md Sec. 12).
- **Migration sweep** (``repro.service.check_migration_crash_sweep``):
  the same sweep lifted to the service's online key-range shard
  migration — it needs a whole ``KVService``, so it lives one layer up
  (DESIGN.md Sec. 12).

Both durable sweeps also exercise WAL hygiene in their teardown: after
each recovery check the COMPLETED descriptor records are pruned
(:meth:`DurableBackend.prune_completed`) and a second crash/recover
cycle must reproduce the identical structure state.

The durable sweeps take ``group_commit`` (which flush protocol is under
sweep — the coalesced one-fence-per-round path is the default, matching
:class:`repro.pmwcas.DurableBackend`) and ``batch`` (ops applied per
round, so the coalesced path commits real multi-op rounds); the
acceptable recovered states are computed from an oracle run's ROUND
composition — a crash inside a batch may recover any round prefix,
each round atomic at its commit fence (DESIGN.md Sec. 9.1).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import PMemPool, SimulatedCrash
from repro.pmwcas import (Algorithm, DurableBackend, MwCASOp, OURS,
                          SimSession, resolve)

from .differential import shadow_batch
from .hashmap import DELETE, HashMap, INSERT, KVOp, OK, UPDATE


class CrashCheckError(AssertionError):
    """Recovered structure state the committed history cannot explain."""


def replay_effects(ops_with_status: Iterable[Tuple[KVOp, str]]
                   ) -> Dict[int, int]:
    """Client-side model: the live map after a sequence of (op, status)."""
    model: Dict[int, int] = {}
    for op, status in ops_with_status:
        if status != OK:
            continue
        if op.kind == INSERT or op.kind == UPDATE:
            model[op.key] = op.value
        elif op.kind == DELETE:
            model.pop(op.key, None)
    return model


def _durable_crash_sweep(kvops: Sequence[KVOp], root, attach, *,
                         committer: str, max_crash_points: int,
                         what: str, group_commit: bool = True,
                         batch: int = 1) -> int:
    """The shared sweep engine: ``attach(backend)`` builds/attaches the
    structure under test (it may itself persist — a crashing bootstrap
    is part of the sweep) and must expose ``apply`` +
    ``check_integrity``.

    ``group_commit`` selects the flush protocol under sweep (the
    coalesced one-fence-per-round path vs the per-op 3k+2-persist
    protocol); ``batch`` applies ops ``batch`` at a time so the
    coalesced path commits real multi-op rounds — the in-flight window
    is then the whole torn round (atomic at the round-record fence:
    either every round winner's effect recovers, or none does)."""
    import pathlib
    root = pathlib.Path(root)
    batches = [list(kvops[i:i + batch])
               for i in range(0, len(kvops), batch)]
    # oracle pass on a clean pool: per-op statuses plus the per-batch
    # ROUND composition, so a crash inside batch j has exactly the
    # acceptable states {committed + first r rounds of batch j} — each
    # round is atomic at its (group or per-op) commit fence
    oracle = attach(DurableBackend(pool=PMemPool(root / "oracle"),
                                   committer=committer,
                                   group_commit=group_commit))
    oracle_rounds: List[List[List[Tuple[KVOp, str]]]] = []
    for b in batches:
        res = oracle.apply(b)
        hist = getattr(oracle, "last_history", None)
        if hist is None:
            # no round trace: treat the whole batch as one in-flight unit
            oracle_rounds.append([list(zip(b, [r.status for r in res]))])
        else:
            oracle_rounds.append(
                [[(b[idx], OK) for pos, idx in enumerate(tr.owners)
                  if tr.success[pos]] for tr in hist])
    for crash_at in range(max_crash_points + 1):
        pool = PMemPool(root / f"crash{crash_at}",
                        crash_after_persists=crash_at)
        backend = DurableBackend(pool=pool, committer=committer,
                                 group_commit=group_commit)
        committed: List[Tuple[KVOp, str]] = []
        inflight: Optional[int] = None
        crashed = False
        struct = None
        try:
            struct = attach(backend)
        except SimulatedCrash:
            crashed = True
        if struct is not None:
            for j, b in enumerate(batches):
                try:
                    res = struct.apply(b)
                except SimulatedCrash:
                    inflight = j
                    crashed = True
                    break
                committed.extend(zip(b, (r.status for r in res)))
        # crash (drop unpersisted writes), reopen, recover, re-attach
        recovered = backend.crash()
        items = attach(recovered).check_integrity()   # nothing torn
        base = replay_effects(committed)
        acceptable = [base]
        if inflight is not None:
            rounds = oracle_rounds[inflight]
            for r in range(1, len(rounds) + 1):
                eff = [e for rnd in rounds[:r] for e in rnd]
                acceptable.append(replay_effects(committed + eff))
        if items not in acceptable:
            raise CrashCheckError(
                f"crash_at={crash_at}: recovered {what} {items}, expected "
                f"one of {acceptable} (committed={len(committed)} ops, "
                f"inflight batch={inflight})")
        # teardown WAL hygiene: pruning spent descriptors must not
        # change what a further crash/recover cycle reconstructs
        recovered.prune_completed()
        re2 = recovered.crash()
        struct2 = attach(re2)
        if struct2.check_integrity() != items:
            raise CrashCheckError(
                f"crash_at={crash_at}: prune_completed changed recovery")
        # teardown region hygiene (the word-side analogue): GC-ing
        # unreferenced pair regions must not change the live items,
        # at any crash point — including mid-split residue
        gc = getattr(struct2, "gc_regions", None)
        if gc is not None:
            gc()
            if struct2.check_integrity() != items:
                raise CrashCheckError(
                    f"crash_at={crash_at}: region GC changed live items")
            if attach(re2.crash()).check_integrity() != items:
                raise CrashCheckError(
                    f"crash_at={crash_at}: region GC does not survive a "
                    "further crash/recover cycle")
        if not crashed:
            return crash_at
    raise CrashCheckError(
        f"{what} sweep never completed within {max_crash_points} persists")


def check_durable_crash_sweep(kvops: Sequence[KVOp], n_buckets: int,
                              root, *, committer: str = "wal",
                              max_crash_points: int = 400,
                              group_commit: bool = True,
                              batch: int = 1) -> int:
    """Crash-at-every-persist sweep over a whole logical workload.

    Returns the number of crash points swept (== persists of a clean
    run).  Raises :class:`CrashCheckError` (or
    :class:`repro.structures.TornStructure`) on any torn or lost state.
    ``group_commit``/``batch`` select the flush protocol and the round
    width under sweep (see :func:`_durable_crash_sweep`): with group
    commit and ``batch > 1`` the sweep crosses every persist of the
    COALESCED path, including the torn-round window.
    """
    return _durable_crash_sweep(
        kvops, root, lambda backend: HashMap(backend, n_buckets),
        committer=committer, max_crash_points=max_crash_points,
        what="map", group_commit=group_commit, batch=batch)


def check_tree_crash_sweep(kvops: Sequence[KVOp], root, *,
                           leaf_cap: int = 2, root_cap: int = 4,
                           n_regions: int = 4, committer: str = "wal",
                           max_crash_points: int = 1200,
                           group_commit: bool = True) -> int:
    """Crash-at-every-persist sweep over a multi-node tree workload.

    The workload is expected to drive :class:`BzTreeIndex` through at
    least one leaf split (size it so a leaf overflows), so the sweep
    crosses every persist of freeze, the wide half-materialization and
    the 2-word parent install.  After every crash + recovery the
    re-attached tree must pass :meth:`BzTreeIndex.check_integrity` (no
    torn node, no half-installed parent entry — i.e. the tree is the
    pre-split or the fully-linked post-split one) and hold exactly the
    effects the client saw commit, plus at most the one in-flight op.
    Returns the number of crash points swept.
    """
    from .bztree_index import BzTreeIndex

    def _attach(backend):
        return BzTreeIndex(backend, leaf_cap=leaf_cap, root_cap=root_cap,
                           n_regions=n_regions)

    return _durable_crash_sweep(
        kvops, root, _attach, committer=committer,
        max_crash_points=max_crash_points, what="tree",
        group_commit=group_commit)


def check_hashmap_resize_sweep(kvops: Sequence[KVOp], n_buckets: int,
                               root, *, max_doublings: int = 2,
                               committer: str = "wal",
                               max_crash_points: int = 1200,
                               group_commit: bool = True,
                               batch: int = 1) -> int:
    """Crash-at-every-persist sweep through directory doubling.

    The workload is expected to overflow generation 0 (size it with more
    inserts than ``n_buckets``), so the sweep crosses every persist of
    the decide (MIG_BIT CAS), the pump (4-word moves), the guarded
    split-brain client ops and the finalize swing.  After every crash +
    recovery the re-attached map must pass
    :meth:`HashMap.check_integrity` (pairs untorn in every generation,
    retired generations drained, no key live twice, future arrays
    all-zero — i.e. the table is pre-growth, mid-growth or post-growth,
    never torn) and hold exactly the committed effects; the live items
    are growth-invariant, so the engine's acceptable-state computation
    needs no growth awareness at all.  Returns crash points swept.
    """
    return _durable_crash_sweep(
        kvops, root,
        lambda backend: HashMap(backend, n_buckets,
                                max_doublings=max_doublings),
        committer=committer, max_crash_points=max_crash_points,
        what="elastic map", group_commit=group_commit, batch=batch)


def check_sim_crash_sweep(ops: Sequence[MwCASOp], *,
                          algorithm: Union[str, Algorithm] = OURS,
                          crash_steps: Optional[Sequence[int]] = None,
                          n_steps: int = 4000, seed: int = 0) -> int:
    """Sweep simulator crash points over a shadowed structure round.

    ``ops`` is a compiled structure batch (e.g. ``HashMap`` round or
    BzTree inserts); each op becomes one simulated thread executing an
    increment over the op's (compressed) address set.  Every probed step
    runs ``SimSession.crash_at`` — recovery from the persisted
    descriptors plus the central crash invariant — and additionally
    asserts per-op atomicity for ops with private addresses.  Returns
    the number of crash points checked.
    """
    # mixed widths are fine: shadow_batch pads every op to the round's
    # max width with fresh private words (growth rounds batch 4-word
    # moves next to 1-word generation CASes)
    k = max(op.k for op in ops)
    n_shadow, shadow = shadow_batch(ops)
    T = len(shadow)
    table = np.asarray([[list(op.addrs)] for op in shadow], np.int32)

    session = (SimSession().with_algorithm(resolve(algorithm))
               .with_threads(T).with_words(n_shadow).with_k(k)
               .with_max_ops(1).with_steps(n_steps).with_seed(seed)
               .with_ops(table))
    if crash_steps is None:
        rng = np.random.default_rng(seed)
        crash_steps = sorted(set(
            rng.integers(1, n_steps, size=12).tolist()))

    # which shadow addresses belong to exactly one op (private)
    counts: Dict[int, int] = {}
    for op in shadow:
        for a in op.addrs:
            counts[a] = counts.get(a, 0) + 1
    checked = 0
    for step in crash_steps:
        rec, hist = session.crash_at(int(step))
        assert rec.shape == (n_shadow,)
        for op in shadow:
            if any(counts[a] > 1 for a in op.addrs):
                continue                      # shared word: counts mix
            per_word = {int(hist[a]) for a in op.addrs}
            if len(per_word) != 1:
                raise CrashCheckError(
                    f"crash@{step}: op over {op.addrs} committed "
                    f"unevenly: {sorted(per_word)} — torn multi-word op")
        checked += 1
    return checked

"""Sharding rules: best-effort logical-axis assignment with divisibility.

MaxText-style philosophy, adapted: every parameter/cache leaf gets a
PartitionSpec derived from its *path* and the architecture's geometry.
Assignments degrade gracefully — if a dimension does not divide the mesh
axis (e.g. 40 attention heads on a 16-way model axis, or granite's 40
experts), the rule falls back (FSDP-only, replication, or sequence
sharding) instead of failing; the dry-run proves every (arch x shape x
mesh) cell lowers.  Overrides per cell are the §Perf hill-climb lever.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


@dataclasses.dataclass
class ShardingRules:
    """Per-(arch x shape) sharding policy, overridable for perf iteration."""
    mesh: Mesh
    cfg: ModelConfig
    # axis roles; tuples of mesh axis names, tried in order
    fsdp_candidates: Tuple[Tuple[str, ...], ...] = ()
    model_candidates: Tuple[Tuple[str, ...], ...] = ()
    dp_candidates: Tuple[Tuple[str, ...], ...] = ()
    # decode-cache strategy: shard sequence when heads don't fit
    seq_shard_cache: bool = True
    # residual-stream sequence sharding (sequence parallelism); production
    # default for training — the remat carry per layer shrinks by |axes|
    act_seq_axes: Optional[Tuple[str, ...]] = ("model",)

    def __post_init__(self):
        names = self.mesh.axis_names
        has_pod = "pod" in names
        if not self.fsdp_candidates:
            self.fsdp_candidates = ((("pod", "data") if has_pod else ("data",)),
                                    ("data",), ())
        if not self.model_candidates:
            self.model_candidates = (("model",), ())
        if not self.dp_candidates:
            self.dp_candidates = ((("pod", "data") if has_pod else ("data",)),
                                  ("data",), ())

    # -- helpers -------------------------------------------------------------
    def axis_size(self, axes: Tuple[str, ...]) -> int:
        return _prod(self.mesh.shape[a] for a in axes)

    def fit(self, size: int, candidates, taken) -> Optional[Tuple[str, ...]]:
        for axes in candidates:
            if not axes:
                return None
            if any(a in taken for a in axes):
                continue
            if size % self.axis_size(axes) == 0:
                return axes
        return None

    def _spec(self, shape, wants) -> P:
        """wants: list of (dim, role) in priority order."""
        assign: Dict[int, Tuple[str, ...]] = {}
        taken: set = set()
        for dim, role in wants:
            cands = {"fsdp": self.fsdp_candidates,
                     "model": self.model_candidates,
                     "dp": self.dp_candidates}[role]
            axes = self.fit(shape[dim], cands, taken)
            if axes:
                assign[dim] = axes
                taken.update(axes)
        parts = []
        for d in range(len(shape)):
            a = assign.get(d)
            parts.append(a if a and len(a) > 1 else (a[0] if a else None))
        return P(*parts)

    # -- parameters ----------------------------------------------------------
    def param_spec(self, path: str, shape) -> P:
        cfg = self.cfg
        scanned = bool(re.search(r"(units|encoder)", path))
        core = shape[1:] if scanned else shape

        spec = self._param_spec_core(path, core)
        if scanned:
            spec = P(None, *spec)
        return spec

    def _heads_ok(self, n_heads: int) -> bool:
        m = self.axis_size(self.model_candidates[0]) \
            if self.model_candidates[0] else 1
        return n_heads % m == 0

    def _param_spec_core(self, path: str, shape) -> P:
        cfg = self.cfg
        if re.search(r"embedding$", path):
            return self._spec(shape, [(0, "model"), (1, "fsdp")])
        if re.search(r"lm_head$", path):
            return self._spec(shape, [(1, "model"), (0, "fsdp")])
        if re.search(r"frontend_proj$", path):
            return self._spec(shape, [(1, "model"), (0, "fsdp")])
        # attention ---------------------------------------------------------
        if re.search(r"(attn|cross)/w([qkv])$", path):
            which = re.search(r"w([qkv])$", path).group(1)
            heads = cfg.n_heads if which == "q" else cfg.n_kv_heads
            if self._heads_ok(heads):
                return self._spec(shape, [(1, "model"), (0, "fsdp")])
            return self._spec(shape, [(0, "fsdp")])
        if re.search(r"(attn|cross)/wo$", path):
            if self._heads_ok(cfg.n_heads):
                return self._spec(shape, [(0, "model"), (1, "fsdp")])
            return self._spec(shape, [(1, "fsdp")])
        if re.search(r"(attn|cross)/b([qkv])$", path):
            which = re.search(r"b([qkv])$", path).group(1)
            heads = cfg.n_heads if which == "q" else cfg.n_kv_heads
            if self._heads_ok(heads):
                return self._spec(shape, [(0, "model")])
            return P(*([None] * len(shape)))
        # dense mlp ----------------------------------------------------------
        if re.search(r"mlp/wi_(gate|up)$", path):
            return self._spec(shape, [(1, "model"), (0, "fsdp")])
        if re.search(r"mlp/wo$", path):
            return self._spec(shape, [(0, "model"), (1, "fsdp")])
        # moe -----------------------------------------------------------------
        if re.search(r"moe/router$", path):
            return self._spec(shape, [(0, "fsdp")])
        if re.search(r"moe/wi_(gate|up)$", path):  # [E, D, F]
            return self._spec(shape, [(0, "model"), (1, "fsdp"), (2, "model")])
        if re.search(r"moe/wo$", path):            # [E, F, D]
            return self._spec(shape, [(0, "model"), (2, "fsdp"), (1, "model")])
        # mamba ----------------------------------------------------------------
        if re.search(r"mamba/in_proj$", path):
            return self._spec(shape, [(1, "model"), (0, "fsdp")])
        if re.search(r"mamba/conv_w$", path):
            return self._spec(shape, [(1, "model")])
        if re.search(r"mamba/(conv_b|dt_proj_b|d_skip)$", path):
            return self._spec(shape, [(0, "model")])
        if re.search(r"mamba/(x_proj|a_log|out_proj)$", path):
            return self._spec(shape, [(0, "model"), (1, "fsdp")]
                              if path.endswith("out_proj")
                              else [(0, "model")])
        if re.search(r"mamba/dt_proj_w$", path):
            return self._spec(shape, [(1, "model")])
        # xlstm: tiny -> replicate compute params, fsdp the projections
        if re.search(r"(mlstm|slstm)/(up_proj|down_proj)$", path):
            return self._spec(shape, [(0, "fsdp")])
        if re.search(r"(mlstm|slstm)/", path):
            return P(*([None] * len(shape)))
        # norms / everything else: replicated
        return P(*([None] * len(shape)))

    def params_pspecs(self, abstract_params) -> Any:
        def spec(path, leaf):
            pstr = "/".join(str(getattr(k, "key", k)) for k in path)
            return self.param_spec(pstr, leaf.shape)

        return jax.tree_util.tree_map_with_path(spec, abstract_params)

    # -- batches ---------------------------------------------------------------
    def batch_spec(self, global_batch: int) -> Optional[Tuple[str, ...]]:
        return self.fit(global_batch, self.dp_candidates, set())

    def batch_pspecs(self, abstract_batch) -> Any:
        def spec(path, leaf):
            b = self.batch_spec(leaf.shape[0])
            parts = [b if b and len(b) > 1 else (b[0] if b else None)]
            parts += [None] * (len(leaf.shape) - 1)
            return P(*parts)

        return jax.tree_util.tree_map_with_path(spec, abstract_batch)

    # -- decode caches -----------------------------------------------------------
    def cache_spec(self, path: str, shape) -> P:
        """Cache leaves are stacked [n_units, B, ...]."""
        if len(shape) == 0:     # the index scalar
            return P()
        taken: set = set()
        parts = [None] * len(shape)
        # batch
        b = self.fit(shape[1], self.dp_candidates, taken)
        if b:
            parts[1] = b if len(b) > 1 else b[0]
            taken.update(b)
        if re.search(r"/(k|v|k_scale|v_scale)$", path):
            kv_dim, seq_dim = 2, 3
            kv = self.fit(shape[kv_dim], self.model_candidates, taken)
            if kv:
                parts[kv_dim] = kv if len(kv) > 1 else kv[0]
            elif self.seq_shard_cache:
                sq = self.fit(shape[seq_dim], self.model_candidates, taken)
                if sq:
                    parts[seq_dim] = sq if len(sq) > 1 else sq[0]
        elif re.search(r"mamba|ssm|conv", path) and len(shape) >= 3:
            d = self.fit(shape[-2] if path.endswith("ssm") else shape[-1],
                         self.model_candidates, taken)
            if d:
                parts[-2 if path.endswith("ssm") else -1] = \
                    d if len(d) > 1 else d[0]
        return P(*parts)

    def cache_pspecs(self, abstract_cache) -> Any:
        def spec(path, leaf):
            pstr = "/".join(str(getattr(k, "key", k)) for k in path)
            return self.cache_spec(pstr, leaf.shape)

        return jax.tree_util.tree_map_with_path(spec, abstract_cache)

    # -- activation hints (anchor XLA's propagation) --------------------------
    def _axes_or_none(self, axes):
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    def activation_hints(self, global_batch: int, seq_len: int,
                         use_seq_sharding: bool = True):
        """NamedShardings for residual stream, logits and the MoE buffer."""
        cfg = self.cfg
        b = self.batch_spec(global_batch)
        taken = set(b or ())
        seq = None
        act_seq_axes = self.act_seq_axes if use_seq_sharding else None
        if act_seq_axes and seq_len % self.axis_size(act_seq_axes) == 0:
            seq = act_seq_axes
        hints = {
            "act": NamedSharding(self.mesh, P(self._axes_or_none(b),
                                              self._axes_or_none(seq), None)),
        }
        v = self.fit(cfg.padded_vocab, self.model_candidates, taken)
        hints["logits"] = NamedSharding(
            self.mesh, P(self._axes_or_none(b), None, self._axes_or_none(v)))
        if cfg.moe is not None:
            # experts over the model axis when divisible (EP); the capacity
            # dim always shards over the data axes (it is a token dim)
            e = self.fit(cfg.moe.n_experts, self.model_candidates, set())
            c_axes = self.dp_candidates[0]
            hints["moe_ecd"] = NamedSharding(
                self.mesh, P(self._axes_or_none(e),
                             self._axes_or_none(c_axes), None))
            hints["moe_gather"] = NamedSharding(
                self.mesh, P(self._axes_or_none(c_axes), None, None))
            # group-local dispatch: one group per data shard so every
            # dispatch gather/scatter is shard-local (Switch-style
            # per-device capacity)
            hints["moe_groups"] = self.axis_size(c_axes)
            hints["moe_grp"] = NamedSharding(
                self.mesh, P(self._axes_or_none(c_axes), None, None, None))
        # recurrent (xlstm/mamba) per-step states: batch-sharded
        hints["state_b"] = NamedSharding(
            self.mesh, P(self._axes_or_none(b), None))
        return hints

    # -- conversion ----------------------------------------------------------
    def to_named(self, pspec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), pspec_tree,
            is_leaf=lambda x: isinstance(x, P))

from .sharding import ShardingRules

__all__ = ["ShardingRules"]

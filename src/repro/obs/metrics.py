"""Metrics registry: counters, gauges and wall-clock histograms.

The paper's contribution is counted in instructions *removed* — CAS and
cache-flush operations elided from PMwCAS — so the numbers that matter
here are counts (flushes issued/saved, fences, commits) and wall-clock
latencies (microsecond percentiles).  The registry is the one place both
kinds live: every series is ``(name, labels)``-keyed, so the same metric
name can be tracked per strategy, per shard, or per backend without
inventing new dataclasses.

Three series types:

- :class:`Counter` — monotone-by-convention accumulator (negative deltas
  are allowed for honest-ledger corrections, mirroring
  ``DurabilityStats.flushes_saved``);
- :class:`Gauge` — last-write-wins level (idempotent to re-fold, which
  is why the :mod:`repro.obs.adapters` snapshot folds use gauges);
- :class:`Histogram` — wall-clock samples in MICROSECONDS with p50/p99,
  a bounded reservoir of recent samples (a long-running service must
  not grow its sample list without bound) plus lifetime count/sum.

A process-global default registry (:func:`get_registry`) backs the live
instrumentation in the committer and service layers;
:func:`reset_metrics` starts a fresh measurement window (zero every
series in place, registrations kept) — the registry analogue of
``KVService.reset_stats``.

Thread safety: registry lookups take a lock; the series mutators are
single attribute updates (atomic enough under the GIL for counters whose
writers are the service wave loop and its helpers).
"""
from __future__ import annotations

import threading
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, Hashable], ...]


def _label_key(labels: Dict[str, Hashable]) -> LabelKey:
    return tuple(sorted(labels.items()))


class Counter:
    """Accumulating series (``inc`` deltas; see module docstring)."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, delta: int = 1) -> "Counter":
        self.value += delta
        return self

    def reset(self) -> None:
        self.value = 0

    def __repr__(self) -> str:
        return f"Counter({self.name}{dict(self.labels)}={self.value})"


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, value: float) -> "Gauge":
        self.value = value
        return self

    def reset(self) -> None:
        self.value = 0.0

    def __repr__(self) -> str:
        return f"Gauge({self.name}{dict(self.labels)}={self.value})"


class Histogram:
    """Wall-clock samples (microseconds) with bounded percentile window.

    ``record`` keeps the most recent ``window`` samples for percentiles
    and lifetime ``count``/``total_us`` for means; ``percentile`` is
    computed over the window (recent-traffic percentiles, the same
    semantics as ``ServiceStats.MAX_LATENCY_SAMPLES``).
    """

    __slots__ = ("name", "labels", "window", "samples", "count",
                 "total_us", "max_us")
    kind = "histogram"
    DEFAULT_WINDOW = 4096

    def __init__(self, name: str = "", labels: LabelKey = (),
                 window: int = DEFAULT_WINDOW):
        self.name = name
        self.labels = labels
        self.window = window
        self.samples: List[float] = []
        self.count = 0
        self.total_us = 0.0
        self.max_us = 0.0

    def record(self, us: float) -> "Histogram":
        us = float(us)
        self.samples.append(us)
        if len(self.samples) > self.window:
            del self.samples[:len(self.samples) - self.window]
        self.count += 1
        self.total_us += us
        if us > self.max_us:
            self.max_us = us
        return self

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), q))

    @property
    def p50_us(self) -> float:
        return self.percentile(50.0)

    @property
    def p99_us(self) -> float:
        return self.percentile(99.0)

    @property
    def mean_us(self) -> float:
        return self.total_us / self.count if self.count else 0.0

    def reset(self) -> None:
        self.samples = []
        self.count = 0
        self.total_us = 0.0
        self.max_us = 0.0

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean_us": round(self.mean_us, 3),
                "p50_us": round(self.p50_us, 3),
                "p99_us": round(self.p99_us, 3),
                "max_us": round(self.max_us, 3)}

    def __repr__(self) -> str:
        return (f"Histogram({self.name}{dict(self.labels)} n={self.count} "
                f"p50={self.p50_us:.1f}us p99={self.p99_us:.1f}us)")


class MetricsRegistry:
    """Labeled-series store (see module docstring)."""

    def __init__(self):
        self._series: Dict[Tuple[str, str, LabelKey], object] = {}
        self._lock = threading.Lock()

    # -- get-or-create ---------------------------------------------------------
    def _get(self, kind: str, cls, name: str, labels: Dict, **kw):
        key = (kind, name, _label_key(labels))
        series = self._series.get(key)
        if series is None:
            with self._lock:
                series = self._series.get(key)
                if series is None:
                    series = cls(name, key[2], **kw)
                    self._series[key] = series
        return series

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    # -- reads -----------------------------------------------------------------
    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge series (0 when absent —
        a never-incremented metric measured nothing)."""
        key_labels = _label_key(labels)
        for kind in ("counter", "gauge"):
            series = self._series.get((kind, name, key_labels))
            if series is not None:
                return series.value
        return 0

    def series(self, name: Optional[str] = None) -> List[object]:
        """All registered series, optionally filtered by name."""
        with self._lock:
            out = list(self._series.values())
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def total(self, name: str) -> float:
        """Sum of a counter/gauge name across every label combination."""
        return sum(s.value for s in self.series(name)
                   if s.kind in ("counter", "gauge"))

    def as_rows(self) -> List[Dict]:
        """Flat machine-readable dump (benchmark JSON shape)."""
        rows = []
        for s in self.series():
            row = {"name": s.name, "kind": s.kind, "labels": dict(s.labels)}
            if s.kind == "histogram":
                row.update(s.summary())
            else:
                row["value"] = s.value
            rows.append(row)
        rows.sort(key=lambda r: (r["name"], sorted(r["labels"].items())))
        return rows

    def snapshot(self) -> Dict[str, float]:
        """Counter/gauge values keyed ``name{k=v,...}`` (histograms are
        summarized under ``name.count``/``name.p50_us``/``name.p99_us``)."""
        out: Dict[str, float] = {}
        for s in self.series():
            tag = "" if not s.labels else \
                "{" + ",".join(f"{k}={v}" for k, v in s.labels) + "}"
            if s.kind == "histogram":
                for k, v in s.summary().items():
                    out[f"{s.name}.{k}{tag}"] = v
            else:
                out[f"{s.name}{tag}"] = s.value
        return out

    # -- lifecycle -------------------------------------------------------------
    def reset(self) -> None:
        """Zero every series IN PLACE (registrations and the objects
        callers hold onto survive) — a fresh measurement window."""
        for s in self.series():
            s.reset()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global default registry (live instrumentation and the
    benchmark window accounting both go through it)."""
    return _REGISTRY


def reset_metrics() -> None:
    """Start a fresh measurement window on the default registry."""
    _REGISTRY.reset()

"""Fold the stack's existing ``*Stats`` dataclasses into the registry.

Five disconnected stats objects grew up with the stack —
``DurabilityStats`` (checkpoint), ``DispatchStats`` (executor),
``ServiceStats`` (service), ``CheckStats`` (chaos checker) and
``WorkloadStats`` (structures).  These folds translate each into
labeled registry series WITHOUT importing any of those layers: every
fold duck-types on attribute names, so ``repro.obs`` stays at the
bottom of the import graph (the surface guard asserts it imports
nothing above ``repro.pmwcas`` — in fact nothing of ``repro`` at all).

Folds are SNAPSHOTS, so they write gauges: folding the same stats
object twice leaves the same values (idempotent), unlike counters which
would double-count.  Live accounting (the committer's per-commit flush
counters) uses registry counters directly and is a different stream —
fold names are prefixed by their source (``durability.*``,
``dispatch.*``, ``service.*``, ``check.*``, ``workload.*``) so the two
never collide.
"""
from __future__ import annotations

from typing import Optional

from .metrics import MetricsRegistry, get_registry

_DURABILITY_FIELDS = ("flushes_issued", "flushes_saved", "fences",
                      "round_commits", "op_commits", "ops_committed")
_DISPATCH_FIELDS = ("traces", "hits", "dispatches", "serial_rounds",
                    "bytes_padded")
_SHARD_FIELDS = ("rounds", "ops_executed", "ops_won", "defers",
                 "overflows", "out_of_regions")
_CHECK_FIELDS = ("immediates", "mutations", "unchecked", "crashes",
                 "indeterminate")
_WORKLOAD_FIELDS = ("n_ops", "rounds", "mwcas_submitted", "mwcas_won")


def _gauges(registry: MetricsRegistry, prefix: str, obj, fields,
            **labels) -> None:
    for f in fields:
        registry.gauge(f"{prefix}.{f}", **labels).set(getattr(obj, f))


def fold_durability(stats, registry: Optional[MetricsRegistry] = None,
                    **labels) -> MetricsRegistry:
    """``repro.pmwcas.DurabilityStats`` -> ``durability.*`` gauges."""
    registry = registry or get_registry()
    _gauges(registry, "durability", stats, _DURABILITY_FIELDS, **labels)
    registry.gauge("durability.flushes_per_commit", **labels).set(
        stats.flushes_per_commit)
    return registry


def fold_dispatch(stats, registry: Optional[MetricsRegistry] = None,
                  **labels) -> MetricsRegistry:
    """``repro.service.DispatchStats`` -> ``dispatch.*`` gauges."""
    registry = registry or get_registry()
    _gauges(registry, "dispatch", stats, _DISPATCH_FIELDS, **labels)
    return registry


def fold_service(stats, registry: Optional[MetricsRegistry] = None,
                 **labels) -> MetricsRegistry:
    """``repro.service.ServiceStats`` -> ``service.*`` gauges, the
    per-shard breakdown as ``shard=<i>``-labeled series, plus the
    latency percentiles (rounds AND microseconds)."""
    registry = registry or get_registry()
    _gauges(registry, "service", stats,
            ("steps", "submitted", "completed", "cross_rounds",
             "cross_ops", "journal_pruned", "wal_pruned"), **labels)
    for name, value in (
            ("rounds", stats.rounds),
            ("ops_executed", stats.ops_executed),
            ("occupancy", stats.occupancy),
            ("defer_rate", stats.defer_rate),
            ("conflict_rate", stats.conflict_rate),
            ("ops_per_step", stats.ops_per_step),
            ("p50_latency_rounds", stats.p50_latency_rounds),
            ("p99_latency_rounds", stats.p99_latency_rounds),
            ("p50_latency_us", stats.p50_latency_us),
            ("p99_latency_us", stats.p99_latency_us)):
        registry.gauge(f"service.{name}", **labels).set(value)
    for shard in stats.shards:
        _gauges(registry, "service.shard", shard, _SHARD_FIELDS,
                shard=shard.shard, **labels)
    for status, n in stats.by_status.items():
        registry.gauge("service.by_status", status=status,
                       **labels).set(n)
    if stats.dispatch is not None:
        fold_dispatch(stats.dispatch, registry, **labels)
    return registry


def fold_check(stats, registry: Optional[MetricsRegistry] = None,
               **labels) -> MetricsRegistry:
    """``repro.chaos.CheckStats`` -> ``check.*`` gauges."""
    registry = registry or get_registry()
    _gauges(registry, "check", stats, _CHECK_FIELDS, **labels)
    registry.gauge("check.ok", **labels).set(int(stats.ok))
    return registry


def fold_workload(stats, registry: Optional[MetricsRegistry] = None,
                  **labels) -> MetricsRegistry:
    """``repro.structures.WorkloadStats`` -> ``workload.*`` gauges."""
    registry = registry or get_registry()
    _gauges(registry, "workload", stats, _WORKLOAD_FIELDS, **labels)
    registry.gauge("workload.retries_per_op", **labels).set(
        stats.retries_per_op)
    registry.gauge("workload.cas_ops_per_op", **labels).set(
        stats.cas_ops_per_op)
    for status, n in stats.by_status.items():
        registry.gauge("workload.by_status", status=status,
                       **labels).set(n)
    return registry

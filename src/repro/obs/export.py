"""Exporters: JSONL event dump + Chrome trace-event format.

``export_chrome_trace`` writes the *JSON Object Format* of the Trace
Event spec — ``{"traceEvents": [...]}`` — which chrome://tracing and
Perfetto both load directly, so one chaos scenario or bench section
becomes an inspectable timeline.  ``validate_chrome_trace`` is the
schema check CI runs on every emitted trace (and the exporter runs on
itself before writing): a trace that does not validate is a bug in the
tracer, not a viewer quirk to shrug at.

``export_jsonl`` is the greppable flat form: one JSON event per line,
in buffer order.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional, Union

from .trace import SpanTracer, get_tracer

_PHASES = {"X", "i", "M"}        # complete, instant, metadata


def chrome_trace(tracer: Optional[SpanTracer] = None) -> Dict:
    """The tracer's buffer as a Trace-Event-format object (metadata
    event first so viewers name the process)."""
    tracer = tracer or get_tracer()
    meta = {"name": "process_name", "ph": "M", "pid": 1, "ts": 0.0,
            "args": {"name": "repro-pmwcas"}}
    return {"traceEvents": [meta] + tracer.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": tracer.dropped}}


def validate_chrome_trace(obj: Dict) -> None:
    """Raise ``ValueError`` unless ``obj`` is a loadable Chrome trace:
    a dict with a ``traceEvents`` list whose events carry a string
    ``name``, a known ``ph``, numeric non-negative ``ts`` (and ``dur``
    for complete events), and int ``pid``/``tid`` where present."""
    if not isinstance(obj, dict):
        raise ValueError(f"trace must be a JSON object, got {type(obj)}")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace lacks a traceEvents list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"event {i} has no name")
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} has bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i} has bad dur {dur!r}")
        for key in ("pid", "tid"):
            if key in ev and not isinstance(ev[key], int):
                raise ValueError(f"event {i} has non-int {key}")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i} has non-object args")


def export_chrome_trace(path: Union[str, pathlib.Path],
                        tracer: Optional[SpanTracer] = None
                        ) -> pathlib.Path:
    """Validate, then write the Perfetto-loadable trace JSON."""
    obj = chrome_trace(tracer)
    validate_chrome_trace(obj)
    path = pathlib.Path(path)
    path.write_text(json.dumps(obj, sort_keys=True) + "\n")
    return path


def export_jsonl(path: Union[str, pathlib.Path],
                 tracer: Optional[SpanTracer] = None) -> pathlib.Path:
    """One JSON event per line, buffer order."""
    tracer = tracer or get_tracer()
    path = pathlib.Path(path)
    with open(path, "w") as f:
        for ev in tracer.events():
            f.write(json.dumps(ev, sort_keys=True) + "\n")
    return path


def span_tree(events: List[Dict]) -> Dict[str, List[str]]:
    """``{span name: sorted unique child span names}`` over complete
    events — what the acceptance checks read ("the recovery span
    decomposes into >= 3 named child phases")."""
    children: Dict[str, set] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        parent = (ev.get("args") or {}).get("parent")
        if parent:
            children.setdefault(parent, set()).add(ev["name"])
    return {name: sorted(kids) for name, kids in children.items()}

"""SLO engine: declarative specs, sliding windows, multi-window burn.

The ROADMAP's async-serving and fast-recovery legs both need wall-clock
pass/fail gates before they can land safely; this module is the gate
machinery.  A :class:`SloSpec` declares one bound over one metric (a
p99-latency ceiling in µs, an ops/s floor, a persists/commit ceiling, a
``recover_us`` ceiling, a ``mig_pause_us_p99`` ceiling, …).  An
:class:`SloEngine` holds a set of specs and a sliding window of
observations — each observation is one plain ``{metric: value}`` dict,
typically a registry/stats snapshot taken once per service wave or once
per benchmark cell.

Verdicts use the standard multi-window burn-rate rule rather than a
naive "last sample violated" check: per spec and window, the burn rate
is ``violation_fraction / error_budget``, and the spec only FIRES
(``ok=False``) when BOTH the short window (is it happening *now*?) and
the long window (is it *substantial*?) burn at >= 1.  A single slow
wave inside the budget never fires; a sustained breach always does.  A
spec whose metric never appears in any observation is reported with
``evaluations == 0`` and ``ok=True`` — absence of evidence is surfaced,
not punished.

``report()`` emits the JSON shape the benchmarks write as
``SLO_<section>.json`` (next to ``BENCH_``/``TRACE_``), and
:func:`validate_slo_report` is the schema check CI runs over those
files (``scripts/obs_smoke.py``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional

# burn with a zero error budget would be a division by zero (any
# violation is an infinite burn); cap it to keep the report JSON-safe
_BURN_CAP = 1e9

_KINDS = ("ceiling", "floor")


@dataclasses.dataclass(frozen=True)
class SloSpec:
    """One declarative objective: ``metric`` must stay under (``ceiling``)
    or over (``floor``) ``bound``, with ``error_budget`` — the fraction
    of observations allowed to violate before a window burns."""

    name: str
    metric: str
    bound: float
    kind: str = "ceiling"
    error_budget: float = 0.0
    description: str = ""

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"SloSpec kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if not 0.0 <= self.error_budget < 1.0:
            raise ValueError("error_budget must be in [0, 1)")

    def violated(self, value: float) -> bool:
        if self.kind == "ceiling":
            return value > self.bound
        return value < self.bound


def _burn(violations: int, evaluations: int, budget: float) -> float:
    if evaluations == 0:
        return 0.0
    frac = violations / evaluations
    if budget <= 0.0:
        return _BURN_CAP if frac > 0.0 else 0.0
    return min(frac / budget, _BURN_CAP)


class SloEngine:
    """Sliding-window evaluator for a set of :class:`SloSpec`."""

    def __init__(self, specs: Iterable[SloSpec], short_window: int = 8,
                 long_window: int = 64):
        self.specs: List[SloSpec] = list(specs)
        if short_window < 1 or long_window < short_window:
            raise ValueError("need 1 <= short_window <= long_window")
        self.short_window = short_window
        self.long_window = long_window
        self._obs: Deque[Dict[str, float]] = deque(maxlen=long_window)
        self.observations = 0           # lifetime, beyond the window

    def observe(self, metrics: Dict[str, float]) -> None:
        """Record one observation point (missing metrics are fine — a
        spec simply does not evaluate against this point)."""
        self._obs.append({k: float(v) for k, v in metrics.items()})
        self.observations += 1

    def _evaluate_spec(self, spec: SloSpec) -> Dict:
        values = [o[spec.metric] for o in self._obs if spec.metric in o]
        flags = [spec.violated(v) for v in values]
        short_flags = flags[-self.short_window:]
        result = {
            "name": spec.name, "metric": spec.metric, "kind": spec.kind,
            "bound": spec.bound, "error_budget": spec.error_budget,
            "description": spec.description,
            "evaluations": len(values), "violations": sum(flags),
            "burn_short": round(_burn(sum(short_flags), len(short_flags),
                                      spec.error_budget), 6),
            "burn_long": round(_burn(sum(flags), len(flags),
                                     spec.error_budget), 6),
        }
        if values:
            result["last"] = values[-1]
            result["worst"] = (max(values) if spec.kind == "ceiling"
                               else min(values))
        # fires only when both windows burn — see module docstring
        result["ok"] = not (result["burn_short"] >= 1.0
                            and result["burn_long"] >= 1.0)
        return result

    def evaluate(self) -> List[Dict]:
        return [self._evaluate_spec(s) for s in self.specs]

    def report(self, section: Optional[str] = None, **extra) -> Dict:
        """The ``SLO_<section>.json`` document (schema:
        :func:`validate_slo_report`)."""
        specs = self.evaluate()
        doc = {
            "specs": specs,
            "ok": all(s["ok"] for s in specs),
            "observations": self.observations,
            "windows": {"short": self.short_window,
                        "long": self.long_window},
        }
        if section is not None:
            doc["section"] = section
        doc.update(extra)
        return doc


def validate_slo_report(doc: Dict) -> Dict:
    """Raise ``ValueError`` unless ``doc`` is a well-formed SLO report;
    returns the doc for chaining.  This is the contract obs_smoke checks
    over every committed/emitted ``SLO_<section>.json``."""
    if not isinstance(doc, dict):
        raise ValueError("SLO report must be an object")
    for key, typ in (("specs", list), ("ok", bool), ("observations", int),
                     ("windows", dict)):
        if not isinstance(doc.get(key), typ):
            raise ValueError(f"SLO report field {key!r} must be {typ.__name__}")
    for key in ("short", "long"):
        if not isinstance(doc["windows"].get(key), int):
            raise ValueError(f"windows.{key} must be an int")
    for i, spec in enumerate(doc["specs"]):
        if not isinstance(spec, dict):
            raise ValueError(f"specs[{i}] must be an object")
        for key, typ in (("name", str), ("metric", str), ("kind", str),
                         ("evaluations", int), ("violations", int),
                         ("ok", bool)):
            if not isinstance(spec.get(key), typ):
                raise ValueError(
                    f"specs[{i}].{key} must be {typ.__name__}")
        if spec["kind"] not in _KINDS:
            raise ValueError(f"specs[{i}].kind must be one of {_KINDS}")
        for key in ("bound", "burn_short", "burn_long"):
            if not isinstance(spec.get(key), (int, float)) or \
                    isinstance(spec.get(key), bool):
                raise ValueError(f"specs[{i}].{key} must be a number")
        if spec["evaluations"] < 0 or spec["violations"] < 0 or \
                spec["violations"] > spec["evaluations"]:
            raise ValueError(
                f"specs[{i}]: need 0 <= violations <= evaluations")
    return doc

"""repro.obs — unified tracing, metrics & flush-accounting layer.

The paper's evaluation currency is operations REMOVED — redundant CASes
and cache flushes elided from PMwCAS — and this package is the lens that
makes those removals (and the wall-clock they buy) first-class,
measurable numbers across the whole stack:

- :mod:`repro.obs.metrics` — the registry: counters, gauges and
  microsecond histograms with labeled series; a process-global default
  (:func:`get_registry`) backs the live committer/service accounting.
- :mod:`repro.obs.trace` — the span tracer: nested wall-clock spans at
  the load-bearing seams (round execute, WAL commit/persist/prune,
  recovery phases, stacked dispatch, scheduler waves, chaos
  crash→recover), near-zero overhead while disabled, thread-safe ring
  buffer while enabled.
- :mod:`repro.obs.export` — JSONL and Chrome-trace exporters (Perfetto
  loads the latter directly) plus the schema validator CI runs.
- :mod:`repro.obs.provenance` — the flush-provenance ledger: a
  thread-local ``flush_reason(component, reason)`` stack the persist
  seam reads, plus the redundant-fence detector counters
  (``flush_fences`` / ``redundant_fences``, DESIGN §13).
- :mod:`repro.obs.slo` — declarative :class:`SloSpec` objectives
  evaluated over sliding observation windows with multi-window burn
  rates; every bench section writes its verdicts as
  ``SLO_<section>.json``.
- :mod:`repro.obs.adapters` — idempotent folds of the five legacy
  ``*Stats`` dataclasses into registry series (duck-typed; this package
  imports nothing above ``repro.pmwcas`` — nothing of ``repro`` at
  all, which is what lets the checkpoint layer use it).

Layering: anything may import ``repro.obs`` (the committer below the
public surface, the service and chaos layers above it, benchmarks);
``repro.obs`` itself has no in-repo dependencies.  The AST surface
guard in ``tests/test_public_surface.py`` enforces both directions.
"""
from .adapters import (fold_check, fold_dispatch, fold_durability,
                       fold_service, fold_workload)
from .export import (chrome_trace, export_chrome_trace, export_jsonl,
                     span_tree, validate_chrome_trace)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, reset_metrics)
from .provenance import (current_flush_reason, flush_reason, record_fence)
from .slo import SloEngine, SloSpec, validate_slo_report
from .trace import (NULL_SPAN, SpanTracer, disable_tracing,
                    enable_tracing, get_tracer, instant, span,
                    tracing_enabled)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "reset_metrics",
    "SpanTracer", "NULL_SPAN", "span", "instant", "get_tracer",
    "enable_tracing", "disable_tracing", "tracing_enabled",
    "chrome_trace", "export_chrome_trace", "export_jsonl",
    "validate_chrome_trace", "span_tree",
    "flush_reason", "current_flush_reason", "record_fence",
    "SloSpec", "SloEngine", "validate_slo_report",
    "fold_durability", "fold_dispatch", "fold_service", "fold_check",
    "fold_workload",
]

"""Span tracer: nested wall-clock spans over the PMwCAS stack.

One tracer, two states:

- **disabled** (the default): ``span(...)`` returns a shared no-op
  context manager after a single attribute check — no allocation, no
  clock read, no lock.  The instrumented hot paths (round execute, WAL
  commit, persist fences, wave scheduling) pay ~100ns per seam, which
  the CI smoke (`scripts/obs_smoke.py`) bounds below 5% of the sim
  backend's per-op cost.
- **enabled**: every span records one Chrome-trace "complete" event
  (``ph: "X"``, microsecond ``ts``/``dur``) into a thread-safe ring
  buffer.  Nesting is tracked per thread, so each event knows its
  parent span by name; Perfetto/chrome://tracing reconstruct the same
  nesting from the timestamps alone.

The buffer is a bounded deque (``capacity`` events): a chaos soak run
cannot grow memory without bound — old events fall off the front and
``dropped`` counts them, so an exporter can say what it lost.

Spans mutate: ``sp = span("wal.prune"); with sp: ...; sp.set(pruned=n)``
attaches results discovered mid-span (no-op on the disabled singleton).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

from .metrics import get_registry


class _NullSpan:
    """The disabled-path singleton: every method is a no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """One live span (enabled tracer only); records on ``__exit__``."""

    __slots__ = ("_tracer", "name", "args", "_t0_ns", "_parent")

    def __init__(self, tracer: "SpanTracer", name: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0_ns = 0
        self._parent: Optional[str] = None

    def set(self, **attrs) -> "_Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self._parent = stack[-1] if stack else None
        stack.append(self.name)
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0_ns
        stack = self._tracer._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        args = self.args
        if self._parent is not None:
            args = dict(args, parent=self._parent)
        self._tracer._record({
            "name": self.name, "ph": "X", "cat": "repro",
            "ts": self._t0_ns / 1e3, "dur": dur_ns / 1e3,
            "pid": 1, "tid": threading.get_ident(), "args": args})
        return False


class SpanTracer:
    """Nested-span recorder with an in-memory ring buffer (module
    docstring has the overhead story)."""

    DEFAULT_CAPACITY = 1 << 17          # 131072 events

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self.capacity = capacity
        self.dropped = 0
        self._events: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._local = threading.local()

    # -- recording -------------------------------------------------------------
    def span(self, name: str, **attrs):
        """Context manager for one nested span.  THE hot-path entry:
        when disabled this is one branch + a shared singleton."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Point-in-time event (Chrome ``ph: "i"``)."""
        if not self.enabled:
            return
        self._record({"name": name, "ph": "i", "cat": "repro", "s": "t",
                      "ts": time.perf_counter_ns() / 1e3,
                      "pid": 1, "tid": threading.get_ident(),
                      "args": attrs})

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, event: Dict) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self._count_dropped(1)
            self._events.append(event)

    def _count_dropped(self, n: int) -> None:
        """Every lost event lands in BOTH ledgers: the tracer's own
        ``dropped`` (exported as ``otherData.dropped_events``) and the
        registry counter ``spans_dropped{component="obs"}`` — so a
        benchmark window can see trace loss without holding the tracer."""
        self.dropped += n
        get_registry().counter("spans_dropped", component="obs").inc(n)

    # -- lifecycle -------------------------------------------------------------
    def enable(self, capacity: Optional[int] = None) -> "SpanTracer":
        if capacity is not None and capacity != self.capacity:
            with self._lock:
                # shrinking below the buffered count discards the oldest
                # events; count them — this path used to lose them silently
                lost = max(0, len(self._events) - capacity)
                if lost:
                    self._count_dropped(lost)
                self.capacity = capacity
                self._events = deque(self._events, maxlen=capacity)
        self.enabled = True
        return self

    def disable(self) -> "SpanTracer":
        self.enabled = False
        return self

    def clear(self) -> "SpanTracer":
        with self._lock:
            self._events.clear()
            self.dropped = 0
        return self

    def events(self) -> List[Dict]:
        """Snapshot of the buffered events, oldest first."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        return len(self._events)


_TRACER = SpanTracer()


def get_tracer() -> SpanTracer:
    """The process-global default tracer the stack instruments."""
    return _TRACER


def span(name: str, **attrs):
    """``get_tracer().span(...)`` — the one-liner the seams call."""
    return _TRACER.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    _TRACER.instant(name, **attrs)


def enable_tracing(capacity: Optional[int] = None) -> SpanTracer:
    return _TRACER.enable(capacity)


def disable_tracing() -> SpanTracer:
    return _TRACER.disable()


def tracing_enabled() -> bool:
    return _TRACER.enabled

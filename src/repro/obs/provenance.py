"""Flush provenance: every persist fence carries a ``(component, reason)``.

The paper's thesis is that the original PMwCAS spends fences it does not
need — and PR 7's dual ledger can only *count* fences, not explain them.
This module is the explanation channel: callers wrap the code that is
ABOUT to hit the persist seam in :func:`flush_reason`, and
``PMemPool.persist`` calls :func:`record_fence` so the registry grows two
labeled counter families:

- ``flush_fences{component, reason}`` — every fence, attributed;
- ``redundant_fences{component, reason}`` — fences that covered an
  already-clean line (nothing unpersisted under them).  On the
  group-commit hot path this must be ZERO — ``benchmarks/bench_durable``
  asserts it, which turns the paper's removed-flushes claim into a CI
  gate.  The per-op protocol keeps the original algorithm's conservative
  read barrier, so its count is honestly nonzero.

Attribution is a thread-local stack of frames.  Frames NEST, and the
label is split across the stack on purpose:

- ``component`` comes from the OUTERMOST frame — who initiated the work
  (``"service"`` for a migration swing, ``"structures"`` for a directory
  doubling, ``"committer"`` for a plain commit);
- ``reason`` comes from the INNERMOST frame — the mechanical reason this
  particular line was fenced (``"descriptor"``, ``"group_record"``,
  ``"wal_prune"``, ``"read_barrier"``, ``"migration_routed"``,
  ``"epoch_close"`` — the ONE fence an epoch of buffered rounds shares,
  ``"checkpoint"`` — a WAL checkpoint image plus the covered-record
  GC it durably supersedes, …).

So a descriptor persisted inside a directory-doubling swing shows up as
``{component="structures", reason="descriptor"}`` — both the business
cause and the mechanical one survive, without exploding cardinality.

A fence issued with no frame on the stack records as
``{component="pmem", reason="unattributed"}`` — visible, not silent, so
an uninstrumented call site shows up in the ledger as a taxonomy gap.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Iterator, Tuple

from .metrics import get_registry

DEFAULT_REASON: Tuple[str, str] = ("pmem", "unattributed")

_STATE = threading.local()


def _frames() -> list:
    frames = getattr(_STATE, "frames", None)
    if frames is None:
        frames = _STATE.frames = []
    return frames


@contextmanager
def flush_reason(component: str, reason: str) -> Iterator[None]:
    """Attribute every fence issued inside the ``with`` to
    ``(component, reason)``.  Nests: see the module docstring for how
    outer (business) and inner (mechanical) frames combine."""
    frames = _frames()
    frames.append((str(component), str(reason)))
    try:
        yield
    finally:
        frames.pop()


def current_flush_reason() -> Tuple[str, str]:
    """The label the NEXT fence on this thread would record."""
    frames = _frames()
    if not frames:
        return DEFAULT_REASON
    return frames[0][0], frames[-1][1]


def record_fence(redundant: bool = False) -> None:
    """Called by the persist seam (``PMemPool``) for every fence issued.
    ``redundant=True`` means the fence covered an already-clean line —
    durably a no-op, exactly the instruction class the paper removes."""
    component, reason = current_flush_reason()
    reg = get_registry()
    reg.counter("flush_fences", component=component, reason=reason).inc()
    if redundant:
        reg.counter("redundant_fences",
                    component=component, reason=reason).inc()

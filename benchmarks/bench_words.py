"""Paper Figs. 11 & 12: effect of the number of PMwCAS target words,
including relative-to-P1wCAS curves against the 1/k ideal."""
from __future__ import annotations

from repro.pmwcas import ORIGINAL, OURS, OURS_DF

from .common import BENCH_STEPS, BENCH_WORDS, emit, row, run_cell, \
    throughput_mops

WORDS = (1, 2, 3, 4, 5, 6, 8)


def run(quick: bool = False):
    words = (1, 3, 5) if quick else WORDS
    steps = BENCH_STEPS // 4 if quick else BENCH_STEPS
    base = {}
    for alpha in (0.0, 1.0):
        for k in words:
            for alg in (OURS, OURS_DF, ORIGINAL):
                r = run_cell(alg, n_threads=32, k=k, n_words=BENCH_WORDS,
                             alpha=alpha, n_steps=steps, max_ops=512,
                             seed=13)
                emit(row(f"fig11_k{k}_{alg}_a{alpha:g}", r))
                if alg is OURS:
                    base.setdefault(alpha, {})[k] = throughput_mops(r)
    # Fig. 12: ours relative to its own k=1 (ideal: 1/k)
    for alpha, per_k in base.items():
        if 1 not in per_k:
            continue
        for k, tp in sorted(per_k.items()):
            rel = tp / per_k[1] if per_k[1] else 0.0
            emit(f"fig12_rel_k{k}_a{alpha:g},{0.0:.3f},"
                 f"relative={rel:.4f};ideal={1.0 / k:.4f}")


if __name__ == "__main__":
    run()

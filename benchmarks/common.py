"""Shared helpers for the paper-figure benchmarks.

The simulator reports exact instruction/invalidation counts; wall-clock is
modeled at CLOCK_GHZ from the per-event cycle model (repro CostModel,
calibrated once against the paper's Fig. 9/10 ratios — see
benchmarks/calibration.md).  Every row reports both.

Benchmarks import ONLY the repro.pmwcas public surface: configurations
are built with the fluent SimSession and the algorithm strategy objects
(OURS / OURS_DF / ORIGINAL / PCAS).
"""
from __future__ import annotations

from repro.pmwcas import (CNT_CAS, CNT_FLUSH, CNT_INVAL, SimResult,
                          SimSession)

CLOCK_GHZ = 2.0  # cycles -> seconds conversion for reporting only

# Benchmark-scale defaults: the paper uses 1e6 words / 10s timeouts; we use
# 2^16 words and a fixed micro-op budget, which preserves every contention
# ratio (words >> threads) while keeping CPU sim time tractable.
BENCH_WORDS = 1 << 16
BENCH_STEPS = 60_000


def session(alg, **cfg) -> SimSession:
    """One benchmark cell: algorithm strategy + SimConfig overrides."""
    return SimSession().with_algorithm(alg).configure(**cfg)


def run_cell(alg, **cfg) -> SimResult:
    return session(alg, **cfg).run()


def throughput_mops(r: SimResult) -> float:
    """Modeled throughput in million ops/sec at CLOCK_GHZ."""
    secs = r.wall_cycles / (CLOCK_GHZ * 1e9)
    return r.ops_completed / secs / 1e6 if secs > 0 else 0.0


def latency_us(r: SimResult, q: float = 50.0) -> float:
    cyc = r.percentile_latency_cycles(q)
    return cyc / (CLOCK_GHZ * 1e3)


def row(name: str, r: SimResult) -> str:
    us = r.mean_latency_cycles() / (CLOCK_GHZ * 1e3)
    return (f"{name},{us:.3f},"
            f"mops={throughput_mops(r):.3f};ops={r.ops_completed};"
            f"cas_per_op={r.per_op(CNT_CAS):.2f};"
            f"flush_per_op={r.per_op(CNT_FLUSH):.2f};"
            f"inval_per_op={r.per_op(CNT_INVAL):.2f};"
            f"p99_us={latency_us(r, 99):.3f}")


_ROWS: list = []     # parsed rows since the last drain (see benchmarks.run)


def _parse_row(line: str):
    """``name,us_per_call,k=v;k=v`` -> dict (numbers coerced where they
    parse; anything malformed lands under a ``raw`` key)."""
    parts = line.split(",", 2)
    if len(parts) < 2:
        return {"raw": line}
    row = {"name": parts[0]}
    try:
        row["us_per_call"] = float(parts[1])
    except ValueError:
        return {"raw": line}
    if len(parts) == 3 and parts[2]:
        for kv in parts[2].split(";"):
            key, sep, val = kv.partition("=")
            if not sep:
                row.setdefault("notes", []).append(kv)
                continue
            try:
                row[key] = float(val)
            except ValueError:
                row[key] = val
    return row


def emit(line: str):
    """Print one benchmark row AND record it for machine-readable output
    (``benchmarks.run`` drains the record into BENCH_<section>.json)."""
    print(line, flush=True)
    _ROWS.append(_parse_row(line))


def drain_rows() -> list:
    """Hand over (and clear) the rows emitted since the last drain."""
    rows, _ROWS[:] = list(_ROWS), []
    return rows


_SLO_OBS: list = []  # metric observations queued for the section's SloEngine


def slo_observe(**metrics):
    """Queue one SLO observation for the current section.  Each call is
    one evaluation window entry; ``benchmarks.run`` drains these into the
    section's :class:`repro.obs.SloEngine` and writes the burn-rate
    verdicts to ``SLO_<section>.json``."""
    _SLO_OBS.append({k: float(v) for k, v in metrics.items()})


def drain_slo() -> list:
    """Hand over (and clear) SLO observations queued since last drain."""
    obs, _SLO_OBS[:] = list(_SLO_OBS), []
    return obs

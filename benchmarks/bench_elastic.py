"""Elastic scale-out benchmark: cost of growing while serving.

Three cells, one per question the elastic machinery raises:

- ``elastic_steady``   — insert throughput into a map pre-sized for the
  whole workload (no growth; the baseline);
- ``elastic_growth``   — the SAME workload into a map that starts at a
  quarter of the capacity and doubles its directory online (decide /
  pump / swing interleaved with the client rounds), so the slowdown
  factor is the price of growing in-band;
- ``elastic_migration``— a durable sharded ``KVService`` migrating a
  key range between shards under the decide/copy/swing protocol;
  reports keys moved, the held-op pause in waves, and the wall-clock
  swing pause p99 (``mig_pause_us_p99``, gated lower-is-better by
  ``scripts/perf_trend.py``).

The summary row ASSERTS the acceptance headline: the elastic service
absorbs the whole load with ZERO FULL/EXHAUSTED verdicts (shards double
as they fill; the 4x-capacity test lives in ``tests/test_elastic.py``)
and the migration leaves the key/value image intact.
"""
from __future__ import annotations

import tempfile
import time

from repro.pmwcas import KernelBackend
from repro.service import KVService
from repro.structures import FULL, EXHAUSTED, HashMap, INSERT, KVOp, OK

from .common import emit, slo_observe


def _insert_run(n_keys: int, n_buckets: int, max_doublings: int):
    backend = KernelBackend(
        n_words=HashMap.words_needed(n_buckets, max_doublings),
        use_kernel=False)
    m = HashMap(backend, n_buckets, max_doublings=max_doublings)
    ops = [KVOp(INSERT, k, k + 1) for k in range(1, n_keys + 1)]
    t0 = time.perf_counter()
    res = m.apply(ops, max_rounds=4 * n_keys)
    elapsed = time.perf_counter() - t0
    ok = sum(r.status == OK for r in res)
    return m, ok, elapsed


def run(quick: bool = False):
    n_keys = 96 if quick else 384
    # steady state: the directory is already big enough for every key
    big = 2 * n_keys
    m0, ok0, dt0 = _insert_run(n_keys, big, 0)
    emit(f"elastic_steady,{dt0 / n_keys * 1e6:.1f},"
         f"ops_per_s={n_keys / dt0:.0f};keys={ok0};"
         f"n_buckets={big};resizes=0")
    assert ok0 == n_keys

    # growth: start at a quarter of the needed buckets, double online
    start = max(4, big // 8)
    doublings = 4
    m1, ok1, dt1 = _insert_run(n_keys, start, doublings)
    emit(f"elastic_growth,{dt1 / n_keys * 1e6:.1f},"
         f"ops_per_s={n_keys / dt1:.0f};keys={ok1};"
         f"n_buckets={start};resizes={m1.resizes};"
         f"keys_migrated={m1.keys_migrated};"
         f"growth_cost_x={dt1 / dt0:.2f}")
    assert ok1 == n_keys, f"growth run dropped {n_keys - ok1} inserts"
    assert m1.resizes >= 2, "the growth cell never actually grew"

    # migration: durable sharded service, one key-range shard move
    n_shards, n_buckets = 3, 16 if quick else 64
    span = 3 * n_buckets // 2
    with tempfile.TemporaryDirectory(prefix="bench_elastic_") as tmp:
        svc = KVService(n_shards, backend="durable", n_buckets=n_buckets,
                        max_doublings=2, durable_root=tmp,
                        migration_chunk=8)
        load = {k: k * 3 for k in range(100, 100 + 2 * span, 2)}
        res = svc.apply([KVOp(INSERT, k, v)
                         for k, v in sorted(load.items())])
        statuses = [r.status for r in res]
        full = statuses.count(FULL) + statuses.count(EXHAUSTED)
        t0 = time.perf_counter()
        svc.migrate_range(100, 100 + span, n_shards - 1)
        dt = time.perf_counter() - t0
        st = svc.stats
        moved = st.keys_moved
        emit(f"elastic_migration,{dt / max(1, moved) * 1e6:.1f},"
             f"ops_per_s={moved / dt:.0f};keys_moved={moved};"
             f"mig_pause_waves_max={max(st.mig_pause_waves, default=0)};"
             f"mig_pause_us_p99={st.mig_pause_us.p99_us:.1f}")
        slo_observe(mig_pause_us_p99=st.mig_pause_us.p99_us)
        assert moved > 0, "the migration moved nothing"
        assert svc.check_integrity() == load, \
            "migration changed the key/value image"
        emit(f"elastic_scaleout,0.0,"
             f"growth_cost_x={dt1 / dt0:.2f};"
             f"full_or_exhausted={full};"
             f"migrations={st.migrations};keys_moved={moved}")
        assert full == 0, \
            f"{full} FULL/EXHAUSTED verdicts: elastic absorption failed"

"""Benchmark aggregator — one section per paper table/figure plus the
framework-level benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI)")
    ap.add_argument("--only", default=None,
                    help="threads|words|skew|blocks|ckpt|kernels|diff")
    args = ap.parse_args()

    from . import (bench_blocks, bench_ckpt, bench_diff, bench_kernels,
                   bench_skew, bench_threads, bench_words)
    sections = {
        "threads": bench_threads.run,   # paper Figs. 9 & 10
        "words": bench_words.run,       # paper Figs. 11 & 12
        "skew": bench_skew.run,         # paper Fig. 13
        "blocks": bench_blocks.run,     # paper Fig. 14
        "ckpt": bench_ckpt.run,         # Sec. 4 insight at file granularity
        "kernels": bench_kernels.run,   # TPU-adaptation micro-benches
        "diff": bench_diff.run,         # cross-backend differential smoke
    }
    if args.only and args.only not in sections:
        ap.error(f"unknown section {args.only!r}; "
                 f"choose from {', '.join(sections)}")
    names = [args.only] if args.only else list(sections)
    print("name,us_per_call,derived")
    for name in names:
        print(f"# --- {name} ---", flush=True)
        sections[name](quick=args.quick)


if __name__ == "__main__":
    main()

"""Benchmark aggregator — one section per paper table/figure plus the
framework-level benches.  Prints ``name,us_per_call,derived`` CSV and,
per section, writes a machine-readable ``BENCH_<section>.json`` (the
same rows as structured records: ops/s, CAS/op, flush/op, ... per
variant) so successive runs form a perf trajectory.

Each JSON-emitting section also runs under the span tracer and writes a
``TRACE_<section>.json`` Chrome trace (Perfetto-loadable) next to its
BENCH file — pass ``--no-trace`` to skip (e.g. when timing the benches
themselves) — plus an ``SLO_<section>.json`` burn-rate verdict: the
section's queued :func:`benchmarks.common.slo_observe` observations
replayed through the specs in :mod:`benchmarks.slo_specs` (always at
least one evaluated spec, via the per-section ``elapsed_s`` ceiling).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
                                            [--json-dir DIR | --no-json]
                                            [--no-trace]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time


def write_section_json(directory: pathlib.Path, section: str, rows: list,
                       quick: bool, elapsed_s: float) -> pathlib.Path:
    out = {
        "section": section,
        "quick": quick,
        "elapsed_s": round(elapsed_s, 3),
        "unix_time": int(time.time()),
        "python": platform.python_version(),
        "rows": rows,
    }
    path = directory / f"BENCH_{section}.json"
    path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return path


def write_slo_json(directory: pathlib.Path, section: str,
                   observations: list, quick: bool,
                   elapsed_s: float) -> pathlib.Path:
    from repro.obs import SloEngine, validate_slo_report

    from .slo_specs import for_section
    engine = SloEngine(for_section(section))
    for obs in observations:
        engine.observe(obs)
    # every section gets the wall-clock observation, so the report always
    # carries >= 1 evaluated spec even with no explicit slo_observe calls
    engine.observe({"elapsed_s": elapsed_s})
    doc = validate_slo_report(
        engine.report(section=section, quick=quick,
                      unix_time=int(time.time())))
    path = directory / f"SLO_{section}.json"
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI)")
    ap.add_argument("--only", default=None,
                    help="threads|words|skew|blocks|ckpt|kernels|diff|"
                         "structs|tree|service|durable|chaos|elastic")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<section>.json (default: cwd)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the machine-readable output")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the per-section TRACE_<section>.json")
    args = ap.parse_args()

    from . import (bench_blocks, bench_chaos, bench_ckpt, bench_diff,
                   bench_durable, bench_elastic, bench_kernels,
                   bench_service, bench_skew, bench_structs, bench_threads,
                   bench_words, common)
    sections = {
        "threads": bench_threads.run,   # paper Figs. 9 & 10
        "words": bench_words.run,       # paper Figs. 11 & 12
        "skew": bench_skew.run,         # paper Fig. 13
        "blocks": bench_blocks.run,     # paper Fig. 14
        "ckpt": bench_ckpt.run,         # Sec. 4 insight at file granularity
        "kernels": bench_kernels.run,   # TPU-adaptation micro-benches
        "diff": bench_diff.run,         # cross-backend differential smoke
        "structs": bench_structs.run,   # lock-free structures on PMwCAS
        "tree": bench_structs.run_tree,  # multi-node BzTree index (Sec. 7)
        "service": bench_service.run,   # sharded many-client service (Sec. 8)
        "durable": bench_durable.run,   # per-op vs group commit (Sec. 9)
        "chaos": bench_chaos.run,       # fault harness + lin. check (Sec. 10)
        "elastic": bench_elastic.run,   # online growth + migration (Sec. 12)
    }
    if args.only and args.only not in sections:
        ap.error(f"unknown section {args.only!r}; "
                 f"choose from {', '.join(sections)}")
    names = [args.only] if args.only else list(sections)
    json_dir = None
    if not args.no_json:
        json_dir = pathlib.Path(args.json_dir)
        json_dir.mkdir(parents=True, exist_ok=True)
    trace = json_dir is not None and not args.no_trace
    if trace:
        from repro.obs import (disable_tracing, enable_tracing,
                               export_chrome_trace, get_tracer)
    print("name,us_per_call,derived")
    for name in names:
        print(f"# --- {name} ---", flush=True)
        common.drain_rows()                     # anything stray stays out
        common.drain_slo()
        if trace:
            enable_tracing().clear()
        t0 = time.time()
        try:
            sections[name](quick=args.quick)
        finally:
            if trace:
                disable_tracing()
        rows = common.drain_rows()
        if json_dir is not None:
            elapsed = time.time() - t0
            path = write_section_json(json_dir, name, rows, args.quick,
                                      elapsed)
            print(f"# wrote {path}", file=sys.stderr, flush=True)
            spath = write_slo_json(json_dir, name, common.drain_slo(),
                                   args.quick, elapsed)
            print(f"# wrote {spath}", file=sys.stderr, flush=True)
            if trace and len(get_tracer()):
                tpath = export_chrome_trace(
                    json_dir / f"TRACE_{name}.json")
                print(f"# wrote {tpath}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()

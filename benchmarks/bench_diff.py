"""Cross-backend differential smoke-bench: the same MwCASOp batch through
SimBackend / KernelBackend / DurableBackend, reporting per-backend wall
time and asserting verdict agreement.  Primarily an API regression tripwire
for benchmarks/run.py (scripts/ci.sh runs it with --quick)."""
from __future__ import annotations

import time

from repro.pmwcas import (DurableBackend, KernelBackend, OURS, SimBackend,
                          increment_batch)

from .common import emit


def run(quick: bool = False):
    n_ops, k, n_words = (6, 2, 32) if quick else (12, 3, 128)
    initial, ops = increment_batch(n_words=n_words, k=k, n_ops=n_ops,
                                   seed=23)
    backends = [
        SimBackend(n_words, algorithm=OURS, values=initial),
        KernelBackend(values=initial, use_kernel=not quick),
        DurableBackend(),          # auto-cleaned temp pool
    ]
    backends[2].seed({a: int(initial[a])
                      for op in ops for a in op.addrs})
    verdicts = {}
    for b in backends:
        t0 = time.time()
        res = b.execute(list(ops))
        dt = time.time() - t0
        verdicts[b.name] = [r.success for r in res]
        emit(f"diff_{b.name}_B{len(ops)}_k{k},{dt*1e6:.1f},"
             f"granted={sum(verdicts[b.name])}/{len(ops)}")
    vs = list(verdicts.values())
    agree = all(v == vs[0] for v in vs)
    emit(f"diff_agreement,0.0,agree={agree}")
    assert agree, f"cross-backend disagreement: {verdicts}"


if __name__ == "__main__":
    run()

"""Checkpoint-commit benchmark: descriptor-WAL (ours, no per-slot markers)
vs marker-based commit (the dirty-flag analogue).  Reports persists
(fsyncs) per commit and wall time — the paper's Sec. 4 comparison at file
granularity."""
from __future__ import annotations

import shutil
import tempfile
import time

from repro import Committer, MarkerCommitter, PMemPool

from .common import emit


def _run(committer_cls, n_slots: int, payload_kb: int, n_commits: int):
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        pool = PMemPool(root)
        c = committer_cls(pool)
        payload = b"x" * (payload_kb * 1024)
        names = [f"s{i}" for i in range(n_slots)]
        t0 = time.time()
        for ver in range(1, n_commits + 1):
            targets = [(n, ver - 1, ver) for n in names]
            ok = c.commit(f"c{ver}", targets, {n: payload for n in names})
            assert ok
        dt = time.time() - t0
        return dt / n_commits, pool.persist_count / n_commits
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(quick: bool = False):
    n_commits = 5 if quick else 20
    for n_slots in (4, 16, 64):
        for payload_kb in (64,):
            t_wal, p_wal = _run(Committer, n_slots, payload_kb, n_commits)
            t_mk, p_mk = _run(MarkerCommitter, n_slots, payload_kb,
                              n_commits)
            emit(f"ckpt_wal_slots{n_slots},{t_wal*1e6:.1f},"
                 f"persists_per_commit={p_wal:.1f}")
            emit(f"ckpt_markers_slots{n_slots},{t_mk*1e6:.1f},"
                 f"persists_per_commit={p_mk:.1f};"
                 f"wal_speedup={t_mk/t_wal:.2f}x;"
                 f"persist_savings={p_mk-p_wal:.0f}")


if __name__ == "__main__":
    run()

"""Paper Fig. 13: effect of Zipf access skew on P1wCAS/P3wCAS."""
from __future__ import annotations

from repro.pmwcas import ORIGINAL, OURS, OURS_DF, PCAS

from .common import BENCH_STEPS, BENCH_WORDS, emit, row, run_cell

ALPHAS = (0.0, 0.25, 0.5, 0.75, 1.0, 1.25)


def run(quick: bool = False):
    alphas = (0.0, 0.75, 1.25) if quick else ALPHAS
    steps = BENCH_STEPS // 4 if quick else BENCH_STEPS
    for k in (1, 3):
        algs = (OURS, OURS_DF, ORIGINAL)
        if k == 1:
            algs = algs + (PCAS,)
        for alpha in alphas:
            for alg in algs:
                r = run_cell(alg, n_threads=32, k=k, n_words=BENCH_WORDS,
                             alpha=alpha, n_steps=steps, max_ops=512,
                             seed=17)
                emit(row(f"fig13_k{k}_{alg}_a{alpha:g}", r))


if __name__ == "__main__":
    run()

"""Durable-path benchmarks: per-op commit vs round-level group commit.

The paper deletes redundant flushes from PMwCAS; `BENCH_service.json`
showed the durable SERVICE path reintroducing them one level up — 11+
persists per committed op, every op paying its own WAL record, slot
reservations and commit fence.  Round-level group commit
(DESIGN.md Sec. 9) coalesces each conflict-free batch round into ONE
WAL record and ONE persist fence; this section measures the A/B on the
same many-client workload and ASSERTS the win in-process:

- group commit must beat per-op commit on ops/s (>= 3x full, >= 1.5x
  quick — wall-clock fsync cost is noisy at CI sizes);
- group commit must spend <= 4 persists per committed op (vs ~11 for
  the per-op protocol, load phase included);
- the flush-dedup counters must show real savings (flushes_saved > 0,
  exactly one fence per committing round).

A crash/recover row keeps the optimization honest: recovery from the
coalesced records must reconstruct the identical map.

Two epoch-durability sections extend the A/B (DESIGN.md Sec. 14):
``durable_kv_S2_epoch`` runs the same workload with ``epoch_rounds=4``
(up to four rounds share ONE epoch-close fence; dependent rounds close
early — ``dep_fences``; acks withheld behind open epochs), asserting
<= 0.16 flushes per commit; ``durable_group_recover`` times recovery
on the same service with ``checkpoint_every=2`` (the checkpoint bounds
replay), and ``durable_recover_scaling`` shows 4x the history does NOT
mean 4x the replayable WAL.
"""
from __future__ import annotations

import dataclasses
import time

from repro.obs import get_registry
from repro.service import KVService
from repro.structures import WorkloadSpec, client_streams, load_phase

from .common import emit, slo_observe

SPEC = WorkloadSpec(n_ops=96, n_keys=48, read=0.1, update=0.55,
                    insert=0.25, delete=0.1, alpha=0.9, seed=23)


def _window(svc: KVService, streams) -> dict:
    """Run the measurement window: submit every client stream
    round-robin, drain, and report persists/flushes DELTAS over the
    window (the load phase warms structures and caches but its persists
    are not billed to the steady state)."""
    svc.reset_stats()
    # collect_durability merges into a fresh object: d0 is a snapshot
    d0 = svc.durability_stats()
    p0 = sum(b.pool.persist_count for b in svc.backends)
    n = 0
    t0 = time.time()
    for i in range(max(len(s) for s in streams)):
        for client, stream in enumerate(streams):
            if i < len(stream):
                svc.submit(stream[i], client=client)
                n += 1
    svc.drain()
    dt = time.time() - t0
    svc.check_integrity()
    d1 = svc.durability_stats()
    won = sum(s.ops_won for s in svc.stats.shards)
    row = {
        "n_ops": n, "dt": dt,
        "ops_per_s": n / dt,
        "persists": sum(b.pool.persist_count for b in svc.backends) - p0,
        "ops_won": won,
        "flushes_issued": d1.flushes_issued - d0.flushes_issued,
        "flushes_saved": d1.flushes_saved - d0.flushes_saved,
        "fences": d1.fences - d0.fences,
        "rounds": sum(s.rounds for s in svc.stats.shards),
    }
    # the obs registry keeps an INDEPENDENT ledger of the same commits
    # (reset_stats zeroed it at window start): the committer accounts
    # both through one helper, so the two must agree to the exact
    # integer — any drift means double- or under-counting somewhere
    reg = get_registry()
    for key in ("flushes_issued", "flushes_saved", "fences"):
        obs = reg.value(key, component="committer")
        assert obs == row[key], (
            f"registry {key}={obs} disagrees with DurabilityStats "
            f"delta {row[key]} — the two ledgers drifted")
    obs_committed = reg.value("ops_committed", component="committer")
    row["obs_flushes_issued"] = int(
        reg.value("flushes_issued", component="committer"))
    row["flushes_per_commit"] = (row["obs_flushes_issued"]
                                 / max(1, obs_committed))
    # provenance ledger totals for the window (reset_stats zeroed the
    # registry): every fence carries a (component, reason) label, and
    # redundant_fences counts fences over already-clean lines
    row["flush_fences"] = int(reg.total("flush_fences"))
    row["redundant_fences"] = int(reg.total("redundant_fences"))
    return row


def run(quick: bool = False):
    spec = dataclasses.replace(SPEC, n_ops=48) if quick else SPEC
    n_clients = 8
    round_cap = 8
    load = load_phase(spec, fraction=1.0)
    streams = client_streams(spec, n_clients)

    # -- the A/B: identical workload, flush placement flipped ----------------
    rows = {}
    for mode, group in (("per_op", False), ("group", True)):
        svc = KVService(2, structure="hashmap", backend="durable",
                        n_buckets=2 * spec.n_keys, round_cap=round_cap,
                        group_commit=group)
        svc.apply(load)
        row = _window(svc, streams)
        rows[mode] = row
        ppc = row["persists"] / max(1, row["ops_won"])
        # the provenance ledger's headline claim, asserted per mode: the
        # group-commit hot path issues ZERO redundant fences, while the
        # per-op protocol's conservative read barrier (Committer._commit
        # step 2b) honestly pays them on steady-state clean slot lines.
        # Distinct field names so the perf_trend zero-tolerance gate only
        # sees the group-path counter.
        if mode == "group":
            prov = f"redundant_fences={row['redundant_fences']}"
        else:
            prov = f"redundant_fences_per_op={row['redundant_fences']}"
        emit(f"durable_kv_S2_{mode},{row['dt'] / row['n_ops'] * 1e6:.1f},"
             f"ops_per_s={row['ops_per_s']:.0f};"
             f"persists_per_commit={ppc:.2f};"
             f"flushes_per_commit={row['flushes_per_commit']:.3f};"
             f"obs_flushes_issued={row['obs_flushes_issued']};"
             f"flushes_issued={row['flushes_issued']};"
             f"flushes_saved={row['flushes_saved']};"
             f"flush_fences={row['flush_fences']};{prov};"
             f"fences={row['fences']};rounds={row['rounds']:.0f}")
        if mode == "per_op":
            assert row["redundant_fences"] > 0, (
                "the per-op protocol's read barrier should flag redundant "
                "fences on steady-state clean slot lines — the detector "
                "is dead")
        if mode == "group":
            assert row["redundant_fences"] == 0, (
                f"group-commit hot path issued "
                f"{row['redundant_fences']} redundant fences — the "
                "coalesced protocol reintroduced the instruction class "
                "the paper removes")
            slo_observe(persists_per_commit=ppc,
                        redundant_fences=row["redundant_fences"])
            # crash/recover from the coalesced records (redo path); the
            # TIMED recover row lives on the epoch+checkpoint service
            # below, where replay length is bounded
            before = svc.check_integrity()
            rec = svc.crash()
            assert rec.check_integrity() == before, \
                "group-commit recovery lost or tore state"

    # -- WAL hygiene: the prune cadence bounds the on-disk log ---------------
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=2 * spec.n_keys, round_cap=round_cap,
                    group_commit=True, wal_prune_every=4)
    svc.apply(load)
    row = _window(svc, streams)
    wal_records = sum(len(b.pool.listdir("wal")) for b in svc.backends)
    emit(f"durable_kv_S2_pruned,{row['dt'] / row['n_ops'] * 1e6:.1f},"
         f"ops_per_s={row['ops_per_s']:.0f};"
         f"wal_records={wal_records};wal_pruned={svc.stats.wal_pruned};"
         f"rounds={row['rounds']:.0f}")
    assert svc.stats.wal_pruned > 0, "prune cadence never fired"
    # without pruning the log holds ~1 record per committed round (load
    # included); the cadence must keep it bounded by the prune interval
    cap = 2 * svc.wal_prune_every * len(svc.backends)
    assert wal_records <= cap, (
        f"WAL grew to {wal_records} records despite wal_prune_every="
        f"{svc.wal_prune_every} (cap {cap}) — the cadence is not bounding "
        "the log")

    # -- epoch durability: rounds share one coalesced fence ------------------
    # epoch_rounds=4 buffers up to four committed rounds under ONE
    # epoch-close fence (dependent rounds — a later round touching a
    # word a buffered round wrote — close early: dep_fences).  The
    # service withholds client acks behind open epochs (acks_held), so
    # the bounded-loss window is invisible to acked clients.
    def _epoch_service(checkpoint_every):
        return KVService(2, structure="hashmap", backend="durable",
                         n_buckets=2 * spec.n_keys, round_cap=round_cap,
                         group_commit=True, epoch_rounds=4,
                         checkpoint_every=checkpoint_every)

    # hot-path cell: checkpoints OFF — this row isolates the fence
    # amortization itself (checkpoint-image persists amortize over the
    # cadence, not over a CI-sized window; the checkpointed service is
    # measured by the timed recover rows below)
    svc = _epoch_service(0)
    svc.apply(load)
    row = _window(svc, streams)
    rows["epoch"] = row
    dur = svc.durability_stats()
    ppc = row["persists"] / max(1, row["ops_won"])
    emit(f"durable_kv_S2_epoch,{row['dt'] / row['n_ops'] * 1e6:.1f},"
         f"ops_per_s={row['ops_per_s']:.0f};"
         f"persists_per_commit={ppc:.2f};"
         f"flushes_per_commit={row['flushes_per_commit']:.3f};"
         f"flushes_issued={row['flushes_issued']};"
         f"flushes_saved={row['flushes_saved']};"
         f"redundant_fences={row['redundant_fences']};"
         f"fences={row['fences']};rounds={row['rounds']:.0f};"
         f"epochs_closed={dur.epochs_closed};"
         f"dep_fences={dur.dep_fences};"
         f"acks_held={svc.stats.acks_held};"
         f"epoch_syncs={svc.stats.epoch_syncs}")
    assert row["redundant_fences"] == 0, (
        f"epoch hot path issued {row['redundant_fences']} redundant "
        "fences — deferred persists are leaking through clean lines")
    assert row["flushes_saved"] > 0, "epoch dedup counters dead"
    assert row["fences"] <= row["rounds"], \
        "more fences than rounds under epochs — coalescing broken"
    assert svc.stats.acks_held > 0, \
        "epoch service never withheld an ack — the gate is dead"
    if not quick:
        assert row["flushes_per_commit"] <= 0.16, (
            f"epoch_rounds=4 must amortize to <= 0.16 flushes per "
            f"commit, got {row['flushes_per_commit']:.3f}")

    # crash/recover on the CHECKPOINTED epoch service: replay is bounded
    # by the checkpoint (load the image, replay only the records past
    # it, in per-epoch batches) — THE timed recovery row
    svc = _epoch_service(2)
    svc.apply(load)
    _window(svc, streams)
    before = svc.check_integrity()
    t0 = time.time()
    rec = svc.crash()
    recover_ms = (time.time() - t0) * 1e3
    assert rec.check_integrity() == before, \
        "epoch recovery lost or tore acked state"
    recover_us = get_registry().histogram(
        "recover_us", component="committer").total_us
    emit(f"durable_group_recover,{recover_ms * 1e3:.0f},"
         f"recover_ms={recover_ms:.1f};"
         f"recover_us={recover_us:.0f};ok=1")
    slo_observe(recover_us=recover_us)
    if not quick:
        assert recover_ms <= 60.0, (
            f"checkpointed recovery took {recover_ms:.1f}ms — the "
            "checkpoint is not bounding replay length")

    # -- replay-length scaling: recovery cost vs history length --------------
    # 4x the committed history must NOT mean 4x the recovery: the
    # checkpoint cadence keeps the replayable WAL bounded by the gap
    # (records since the last checkpoint), independent of total ops
    scaling = {}
    for label, factor in (("1x", 1), ("4x", 4)):
        sp_f = dataclasses.replace(spec, n_ops=spec.n_ops * factor)
        svc = _epoch_service(2)
        svc.apply(load)
        _window(svc, client_streams(sp_f, n_clients))
        wal_records = sum(len(b.pool.listdir("wal"))
                          for b in svc.backends)
        before = svc.check_integrity()
        t0 = time.time()
        rec = svc.crash()
        ms = (time.time() - t0) * 1e3
        assert rec.check_integrity() == before, \
            f"scaling recovery ({label}) lost or tore state"
        scaling[label] = (ms, wal_records)
    ms1, wal1 = scaling["1x"]
    ms4, wal4 = scaling["4x"]
    emit(f"durable_recover_scaling,{ms4 * 1e3:.0f},"
         f"recover_ms={ms4:.1f};recover_ms_1x={ms1:.1f};"
         f"recover_ms_4x={ms4:.1f};wal_records_1x={wal1};"
         f"wal_records_4x={wal4}")
    # deterministic form of the scaling claim (wall-clock ratios are
    # too noisy at CI sizes): the replayable record count after 4x the
    # history stays within the checkpoint gap, not within 4x of it
    wal_cap = 2 * (svc.checkpoint_every + 1) * len(svc.backends)
    assert wal4 <= wal_cap, (
        f"4x history left {wal4} replayable WAL records (cap {wal_cap})"
        " — checkpoints are not bounding replay length")

    # -- the acceptance row ---------------------------------------------------
    speedup = rows["group"]["ops_per_s"] / max(rows["per_op"]["ops_per_s"],
                                               1e-9)
    ppc_group = rows["group"]["persists"] / max(1, rows["group"]["ops_won"])
    ppc_per_op = rows["per_op"]["persists"] / max(1,
                                                  rows["per_op"]["ops_won"])
    emit(f"durable_group_speedup,0.0,"
         f"speedup={speedup:.2f};"
         f"persists_per_commit_group={ppc_group:.2f};"
         f"persists_per_commit_per_op={ppc_per_op:.2f};"
         f"flushes_saved={rows['group']['flushes_saved']}")
    floor = 1.5 if quick else 3.0
    assert speedup >= floor, (
        f"group commit must beat per-op commit by >= {floor}x on ops/s, "
        f"got {speedup:.2f}x ({rows['group']['ops_per_s']:.0f} vs "
        f"{rows['per_op']['ops_per_s']:.0f})")
    assert ppc_group <= 4.0, (
        f"group commit must spend <= 4 persists per committed op, got "
        f"{ppc_group:.2f}")
    assert ppc_group < ppc_per_op, "group commit must flush less"
    assert rows["group"]["flushes_saved"] > 0, "dedup counters dead"
    assert rows["group"]["fences"] <= rows["group"]["rounds"], \
        "more fences than rounds: the coalesced path is not coalescing"


if __name__ == "__main__":
    run()

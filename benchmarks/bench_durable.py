"""Durable-path benchmarks: per-op commit vs round-level group commit.

The paper deletes redundant flushes from PMwCAS; `BENCH_service.json`
showed the durable SERVICE path reintroducing them one level up — 11+
persists per committed op, every op paying its own WAL record, slot
reservations and commit fence.  Round-level group commit
(DESIGN.md Sec. 9) coalesces each conflict-free batch round into ONE
WAL record and ONE persist fence; this section measures the A/B on the
same many-client workload and ASSERTS the win in-process:

- group commit must beat per-op commit on ops/s (>= 3x full, >= 1.5x
  quick — wall-clock fsync cost is noisy at CI sizes);
- group commit must spend <= 4 persists per committed op (vs ~11 for
  the per-op protocol, load phase included);
- the flush-dedup counters must show real savings (flushes_saved > 0,
  exactly one fence per committing round).

A crash/recover row keeps the optimization honest: recovery from the
coalesced records must reconstruct the identical map.
"""
from __future__ import annotations

import dataclasses
import time

from repro.obs import get_registry
from repro.service import KVService
from repro.structures import WorkloadSpec, client_streams, load_phase

from .common import emit, slo_observe

SPEC = WorkloadSpec(n_ops=96, n_keys=48, read=0.1, update=0.55,
                    insert=0.25, delete=0.1, alpha=0.9, seed=23)


def _window(svc: KVService, streams) -> dict:
    """Run the measurement window: submit every client stream
    round-robin, drain, and report persists/flushes DELTAS over the
    window (the load phase warms structures and caches but its persists
    are not billed to the steady state)."""
    svc.reset_stats()
    # collect_durability merges into a fresh object: d0 is a snapshot
    d0 = svc.durability_stats()
    p0 = sum(b.pool.persist_count for b in svc.backends)
    n = 0
    t0 = time.time()
    for i in range(max(len(s) for s in streams)):
        for client, stream in enumerate(streams):
            if i < len(stream):
                svc.submit(stream[i], client=client)
                n += 1
    svc.drain()
    dt = time.time() - t0
    svc.check_integrity()
    d1 = svc.durability_stats()
    won = sum(s.ops_won for s in svc.stats.shards)
    row = {
        "n_ops": n, "dt": dt,
        "ops_per_s": n / dt,
        "persists": sum(b.pool.persist_count for b in svc.backends) - p0,
        "ops_won": won,
        "flushes_issued": d1.flushes_issued - d0.flushes_issued,
        "flushes_saved": d1.flushes_saved - d0.flushes_saved,
        "fences": d1.fences - d0.fences,
        "rounds": sum(s.rounds for s in svc.stats.shards),
    }
    # the obs registry keeps an INDEPENDENT ledger of the same commits
    # (reset_stats zeroed it at window start): the committer accounts
    # both through one helper, so the two must agree to the exact
    # integer — any drift means double- or under-counting somewhere
    reg = get_registry()
    for key in ("flushes_issued", "flushes_saved", "fences"):
        obs = reg.value(key, component="committer")
        assert obs == row[key], (
            f"registry {key}={obs} disagrees with DurabilityStats "
            f"delta {row[key]} — the two ledgers drifted")
    obs_committed = reg.value("ops_committed", component="committer")
    row["obs_flushes_issued"] = int(
        reg.value("flushes_issued", component="committer"))
    row["flushes_per_commit"] = (row["obs_flushes_issued"]
                                 / max(1, obs_committed))
    # provenance ledger totals for the window (reset_stats zeroed the
    # registry): every fence carries a (component, reason) label, and
    # redundant_fences counts fences over already-clean lines
    row["flush_fences"] = int(reg.total("flush_fences"))
    row["redundant_fences"] = int(reg.total("redundant_fences"))
    return row


def run(quick: bool = False):
    spec = dataclasses.replace(SPEC, n_ops=48) if quick else SPEC
    n_clients = 8
    round_cap = 8
    load = load_phase(spec, fraction=1.0)
    streams = client_streams(spec, n_clients)

    # -- the A/B: identical workload, flush placement flipped ----------------
    rows = {}
    for mode, group in (("per_op", False), ("group", True)):
        svc = KVService(2, structure="hashmap", backend="durable",
                        n_buckets=2 * spec.n_keys, round_cap=round_cap,
                        group_commit=group)
        svc.apply(load)
        row = _window(svc, streams)
        rows[mode] = row
        ppc = row["persists"] / max(1, row["ops_won"])
        # the provenance ledger's headline claim, asserted per mode: the
        # group-commit hot path issues ZERO redundant fences, while the
        # per-op protocol's conservative read barrier (Committer._commit
        # step 2b) honestly pays them on steady-state clean slot lines.
        # Distinct field names so the perf_trend zero-tolerance gate only
        # sees the group-path counter.
        if mode == "group":
            prov = f"redundant_fences={row['redundant_fences']}"
        else:
            prov = f"redundant_fences_per_op={row['redundant_fences']}"
        emit(f"durable_kv_S2_{mode},{row['dt'] / row['n_ops'] * 1e6:.1f},"
             f"ops_per_s={row['ops_per_s']:.0f};"
             f"persists_per_commit={ppc:.2f};"
             f"flushes_per_commit={row['flushes_per_commit']:.3f};"
             f"obs_flushes_issued={row['obs_flushes_issued']};"
             f"flushes_issued={row['flushes_issued']};"
             f"flushes_saved={row['flushes_saved']};"
             f"flush_fences={row['flush_fences']};{prov};"
             f"fences={row['fences']};rounds={row['rounds']:.0f}")
        if mode == "per_op":
            assert row["redundant_fences"] > 0, (
                "the per-op protocol's read barrier should flag redundant "
                "fences on steady-state clean slot lines — the detector "
                "is dead")
        if mode == "group":
            assert row["redundant_fences"] == 0, (
                f"group-commit hot path issued "
                f"{row['redundant_fences']} redundant fences — the "
                "coalesced protocol reintroduced the instruction class "
                "the paper removes")
            slo_observe(persists_per_commit=ppc,
                        redundant_fences=row["redundant_fences"])
            # crash/recover from the coalesced records (redo path)
            before = svc.check_integrity()
            t0 = time.time()
            rec = svc.crash()
            recover_ms = (time.time() - t0) * 1e3
            assert rec.check_integrity() == before, \
                "group-commit recovery lost or tore state"
            # the committer times its own recover() into the registry
            # (one sample per shard this window)
            recover_us = get_registry().histogram(
                "recover_us", component="committer").total_us
            emit(f"durable_group_recover,{recover_ms * 1e3:.0f},"
                 f"recover_ms={recover_ms:.1f};"
                 f"recover_us={recover_us:.0f};ok=1")
            slo_observe(recover_us=recover_us)

    # -- WAL hygiene: the prune cadence bounds the on-disk log ---------------
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=2 * spec.n_keys, round_cap=round_cap,
                    group_commit=True, wal_prune_every=4)
    svc.apply(load)
    row = _window(svc, streams)
    wal_records = sum(len(b.pool.listdir("wal")) for b in svc.backends)
    emit(f"durable_kv_S2_pruned,{row['dt'] / row['n_ops'] * 1e6:.1f},"
         f"ops_per_s={row['ops_per_s']:.0f};"
         f"wal_records={wal_records};wal_pruned={svc.stats.wal_pruned};"
         f"rounds={row['rounds']:.0f}")
    assert svc.stats.wal_pruned > 0, "prune cadence never fired"
    # without pruning the log holds ~1 record per committed round (load
    # included); the cadence must keep it bounded by the prune interval
    cap = 2 * svc.wal_prune_every * len(svc.backends)
    assert wal_records <= cap, (
        f"WAL grew to {wal_records} records despite wal_prune_every="
        f"{svc.wal_prune_every} (cap {cap}) — the cadence is not bounding "
        "the log")

    # -- the acceptance row ---------------------------------------------------
    speedup = rows["group"]["ops_per_s"] / max(rows["per_op"]["ops_per_s"],
                                               1e-9)
    ppc_group = rows["group"]["persists"] / max(1, rows["group"]["ops_won"])
    ppc_per_op = rows["per_op"]["persists"] / max(1,
                                                  rows["per_op"]["ops_won"])
    emit(f"durable_group_speedup,0.0,"
         f"speedup={speedup:.2f};"
         f"persists_per_commit_group={ppc_group:.2f};"
         f"persists_per_commit_per_op={ppc_per_op:.2f};"
         f"flushes_saved={rows['group']['flushes_saved']}")
    floor = 1.5 if quick else 3.0
    assert speedup >= floor, (
        f"group commit must beat per-op commit by >= {floor}x on ops/s, "
        f"got {speedup:.2f}x ({rows['group']['ops_per_s']:.0f} vs "
        f"{rows['per_op']['ops_per_s']:.0f})")
    assert ppc_group <= 4.0, (
        f"group commit must spend <= 4 persists per committed op, got "
        f"{ppc_group:.2f}")
    assert ppc_group < ppc_per_op, "group commit must flush less"
    assert rows["group"]["flushes_saved"] > 0, "dedup counters dead"
    assert rows["group"]["fences"] <= rows["group"]["rounds"], \
        "more fences than rounds: the coalesced path is not coalescing"


if __name__ == "__main__":
    run()

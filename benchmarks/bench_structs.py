"""Structure-layer benchmarks: the paper's "productive uses of PMwCAS"
made measurable.  YCSB-style mixes drive the lock-free hash map on the
kernel backend (wall ops/s, retry rate) and the durable backend
(persists per op); one compiled round is shadowed through the
cycle-accurate simulator so every variant also reports modeled CAS/op
and flush/op — the same cost vocabulary as the paper-figure benches.
BzTree node insert/split and free-list reservation round out the
structure suite."""
from __future__ import annotations

import dataclasses
import time

from repro.pmwcas import (CNT_CAS, CNT_FLUSH, DurableBackend, KernelBackend,
                          OURS, SimBackend)
from repro.structures import (BzTreeIndex, FreeListAllocator, HashMap,
                              NODE_OK, SortedNode, WorkloadSpec, YCSB_A,
                              YCSB_B, YCSB_C, YCSB_E, compile_workload,
                              load_phase, run_workload, shadow_batch)

from .common import emit


def _shadow_costs(hmap):
    """Modeled CAS/flush per op of the last executed rounds (sim shadow)."""
    cas = flush = n = 0
    # cap the shadow at two rounds: each distinct (B, words) shape pays
    # one engine compile, and two rounds already average the cost
    for trace in hmap.last_history[:2]:
        n_words, shadow = shadow_batch(trace.ops)
        sim = SimBackend(n_words, algorithm=OURS)
        sim.execute(shadow)
        cas += float(sim.counters[:, CNT_CAS].sum())
        flush += float(sim.counters[:, CNT_FLUSH].sum())
        n += len(shadow)
    return (cas / n, flush / n) if n else (0.0, 0.0)


def _loaded_map(backend, spec: WorkloadSpec) -> HashMap:
    hmap = HashMap(backend, spec.n_keys * 2)
    hmap.apply(load_phase(spec))
    return hmap


def _hashmap_cell(name: str, hmap: HashMap, spec: WorkloadSpec, *,
                  shadow: bool = False):
    ops = compile_workload(spec)
    t0 = time.time()
    stats = run_workload(hmap, spec, ops=ops)
    dt = time.time() - t0
    hmap.check_integrity()
    derived = (f"ops_per_s={stats.n_ops / dt:.0f};"
               f"ok={stats.by_status.get('ok', 0)};"
               f"rounds={stats.rounds};"
               f"retries_per_op={stats.retries_per_op:.3f};"
               f"cas_ops_per_op={stats.cas_ops_per_op:.3f}")
    if shadow:
        cas, flush = _shadow_costs(hmap)
        derived += f";cas_per_op={cas:.2f};flush_per_op={flush:.2f}"
    emit(f"{name},{dt / stats.n_ops * 1e6:.1f},{derived}")
    return stats


def run(quick: bool = False):
    n_ops, n_keys = (48, 16) if quick else (256, 64)
    base = WorkloadSpec(n_ops=n_ops, n_keys=n_keys, batch=8, seed=11)
    mixes = [
        ("ycsb_a", dataclasses.replace(YCSB_A, n_ops=n_ops, n_keys=n_keys,
                                       batch=8, seed=11)),
        ("ycsb_b", dataclasses.replace(YCSB_B, n_ops=n_ops, n_keys=n_keys,
                                       batch=8, seed=11)),
        ("mixed", base),
    ]
    skews = (0.0,) if quick else (0.0, 0.99)

    # -- hash map on the kernel backend (jnp oracle; use_kernel on TPU) ------
    for mix_name, spec in mixes:
        for alpha in skews:
            spec_a = dataclasses.replace(spec, alpha=alpha)
            _hashmap_cell(
                f"structs_hashmap_{mix_name}_zipf{alpha:g}",
                _loaded_map(KernelBackend(n_words=2 * spec_a.n_keys * 2,
                                          use_kernel=False), spec_a),
                spec_a, shadow=(mix_name == "mixed"))

    # -- hash map on the durable committer (real persists) -------------------
    d_spec = dataclasses.replace(base, n_ops=min(n_ops, 64))
    backend = DurableBackend()
    dmap = _loaded_map(backend, d_spec)
    p0 = backend.pool.persist_count                    # exclude load phase
    stats = _hashmap_cell("structs_hashmap_durable", dmap, d_spec)
    persists = backend.pool.persist_count - p0
    emit(f"structs_hashmap_durable_persists,0.0,"
         f"persists_per_commit={persists / max(1, stats.mwcas_won):.2f}")

    # -- BzTree node: insert throughput + split latency -----------------------
    cap = 8 if quick else 32
    kb = KernelBackend(n_words=4 * (cap + 1), use_kernel=False)
    node = SortedNode(kb, base=0, capacity=cap)
    t0 = time.time()
    sts = node.insert_batch(list(range(1, cap + 1)))
    dt = time.time() - t0
    assert all(s == NODE_OK for s in sts)
    emit(f"structs_node_insert_cap{cap},{dt / cap * 1e6:.1f},"
         f"keys={cap};rounds={cap}")           # one winner per round
    t0 = time.time()
    left, right, _sep = node.split(cap + 1, 2 * (cap + 1))
    dt = time.time() - t0
    emit(f"structs_node_split_cap{cap},{dt * 1e6:.1f},"
         f"left={left.count};right={right.count};one_wide_mwcas=k"
         f"{left.count + right.count + 2}")

    # -- free-list allocator over reserve_slots -------------------------------
    n_slots = 64 if quick else 256
    fl = FreeListAllocator(n_slots)
    t0 = time.time()
    grants = fl.alloc([4] * (n_slots // 8))
    dt = time.time() - t0
    served = sum(1 for g in grants if g)
    emit(f"structs_freelist_alloc{n_slots},{dt / len(grants) * 1e6:.1f},"
         f"served={served}/{len(grants)};free={fl.n_free}")


def _loaded_tree(backend_factory, spec: WorkloadSpec, *, leaf_cap: int,
                 root_cap: int, n_regions: int) -> BzTreeIndex:
    n_words = BzTreeIndex.words_needed(leaf_cap, root_cap, n_regions)
    tree = BzTreeIndex(backend_factory(n_words), leaf_cap=leaf_cap,
                       root_cap=root_cap, n_regions=n_regions)
    tree.apply(load_phase(spec))
    return tree


def _tree_cell(name: str, tree: BzTreeIndex, spec: WorkloadSpec, *,
               shadow: bool = False):
    ops = compile_workload(spec)
    s0 = (tree.splits, tree.consolidations)
    t0 = time.time()
    stats = run_workload(tree, spec, ops=ops)
    dt = time.time() - t0
    tree.check_integrity()
    derived = (f"ops_per_s={stats.n_ops / dt:.0f};"
               f"ok={stats.by_status.get('ok', 0)};"
               f"rounds={stats.rounds};"
               f"retries_per_op={stats.retries_per_op:.3f};"
               f"cas_ops_per_op={stats.cas_ops_per_op:.3f};"
               f"splits={tree.splits - s0[0]};"
               f"leaves={len(tree.leaf_bases())}")
    if shadow:
        cas, flush = _shadow_costs(tree)
        derived += f";cas_per_op={cas:.2f};flush_per_op={flush:.2f}"
    emit(f"{name},{dt / stats.n_ops * 1e6:.1f},{derived}")
    return stats


def run_tree(quick: bool = False):
    """The multi-node section: YCSB A/B/C + the scan-heavy E mix on the
    two-level BzTree (kernel + durable backends), plus a split-latency
    micro-bench — ``BENCH_tree.json``."""
    n_ops, n_keys = (32, 12) if quick else (160, 48)
    leaf_cap = 4 if quick else 8
    root_cap = max(4, 2 * n_keys // leaf_cap)
    n_regions = root_cap + 2
    shape = dict(leaf_cap=leaf_cap, root_cap=root_cap, n_regions=n_regions)
    mixes = [("ycsb_a", YCSB_A), ("ycsb_b", YCSB_B), ("ycsb_c", YCSB_C),
             ("ycsb_e_scan", YCSB_E)]
    skews = (0.0,) if quick else (0.0, 0.99)

    # -- tree on the kernel backend (jnp oracle; use_kernel on TPU) -----------
    for mix_name, mix in mixes:
        for alpha in skews:
            spec = dataclasses.replace(mix, n_ops=n_ops, n_keys=n_keys,
                                       batch=8, seed=11, alpha=alpha)
            tree = _loaded_tree(
                lambda n: KernelBackend(n_words=n, use_kernel=False),
                spec, **shape)
            _tree_cell(f"tree_{mix_name}_zipf{alpha:g}", tree, spec,
                       shadow=(mix_name == "ycsb_a" and alpha == 0.0))

    # -- tree on the durable committer (real persists, incl. split WALs) -----
    d_spec = dataclasses.replace(YCSB_A, n_ops=min(n_ops, 48),
                                 n_keys=n_keys, batch=8, seed=11)
    holder = {}

    def durable_factory(n_words):
        holder["backend"] = DurableBackend()
        return holder["backend"]

    dtree = _loaded_tree(durable_factory, d_spec, **shape)
    p0 = holder["backend"].pool.persist_count       # exclude load phase
    stats = _tree_cell("tree_ycsb_a_durable", dtree, d_spec)
    persists = holder["backend"].pool.persist_count - p0
    pruned = holder["backend"].prune_completed()    # WAL hygiene pass
    emit(f"tree_durable_persists,0.0,"
         f"persists_per_commit={persists / max(1, stats.mwcas_won):.2f};"
         f"wal_pruned={pruned}")

    # -- split latency: fill one leaf past capacity, time the two rounds -----
    cap = 8 if quick else 32
    n_words = BzTreeIndex.words_needed(cap, 4, 4)
    tree = BzTreeIndex(KernelBackend(n_words=n_words, use_kernel=False),
                       leaf_cap=cap, root_cap=4, n_regions=4)
    from repro.structures import INSERT, KVOp
    tree.apply([KVOp(INSERT, k, k) for k in range(1, cap + 1)])
    t0 = time.time()
    tree.apply([KVOp(INSERT, cap + 1, 1)])          # triggers the split
    dt = time.time() - t0
    assert tree.splits == 1
    emit(f"tree_split_cap{cap},{dt * 1e6:.1f},"
         f"splits={tree.splits};leaves={len(tree.leaf_bases())};"
         f"wide_k={2 * (1 + 2 * (cap // 2)) + 2}")


if __name__ == "__main__":
    run()
    run_tree()

"""Chaos-harness benchmark: throughput *under* faults, with every
history checked.

Runs the named scenario families (``repro.chaos.default_scenarios``)
against durable-backed ``KVService`` shards — crash/recover cycles,
storms, stragglers, drifting skew — and reports per-family ops/s, crash
counts, checker coverage, and the WAL-prune accounting.  The section
ASSERTS what the chaos harness exists to prove:

- every scenario's completed history is linearizable (checker ok);
- the durable families actually injected crashes (a chaos bench that
  never crashes measures nothing);
- the per-shard WAL prune cadence ran and kept the on-disk record
  count bounded below one record per committed op;
- every scenario evaluated its SLOs DURING the fault schedule (the
  in-run ``SloEngine`` verdict rides on each report) and, when tracing
  is on, the injected faults appear as ``chaos.fault`` instant events
  in the section trace.
"""
from __future__ import annotations

import tempfile
import time

from repro.chaos import default_scenarios, run_scenario
from repro.obs import get_tracer, tracing_enabled

from .common import emit, slo_observe


def run(quick: bool = False):
    waves = 30 if quick else 60
    reports = []
    t0 = time.time()
    with tempfile.TemporaryDirectory(prefix="bench_chaos_") as tmp:
        for i, sc in enumerate(default_scenarios(seed=0, waves=waves)):
            rep = run_scenario(sc, durable_root=(
                f"{tmp}/run{i}" if sc.backend == "durable" else None))
            reports.append(rep)
            c = rep.check
            us = (rep.elapsed_s / max(1, rep.ops_completed)) * 1e6
            # the in-run SLO verdict: evaluated wave by wave WHILE the
            # scenario's faults fired, not after the fact
            slo = rep.slo or {}
            slo_evals = sum(s["evaluations"] for s in slo.get("specs", ()))
            emit(f"chaos_{rep.scenario.family},{us:.1f},"
                 f"ops_per_s={rep.ops_per_s:.0f};"
                 f"waves={rep.waves_run};"
                 f"ops_completed={rep.ops_completed};"
                 f"crashes={rep.crashes};faults_fired={rep.faults_fired};"
                 f"lin_ok={int(c.ok)};immediates={c.immediates};"
                 f"mutations={c.mutations};indeterminate={c.indeterminate};"
                 f"slo_ok={int(slo.get('ok', False))};"
                 f"slo_evaluations={slo_evals};"
                 f"p99_latency_us={rep.p99_latency_us:.1f};"
                 f"wal_records={rep.wal_records};wal_pruned={rep.wal_pruned}")
            assert rep.slo is not None and slo_evals > 0, (
                f"{rep.scenario.name}: the driver never evaluated its "
                "SLOs during the fault schedule")
            slo_observe(p99_latency_us=rep.p99_latency_us,
                        ops_per_s=rep.ops_per_s)

    durable = [r for r in reports if r.scenario.backend == "durable"]
    crashes = sum(r.crashes for r in durable)
    pruned = sum(r.wal_pruned for r in durable)
    emit(f"chaos_sweep,0.0,"
         f"scenarios={len(reports)};families={len(reports)};"
         f"crashes={crashes};"
         f"lin_ok={int(all(r.check.ok for r in reports))};"
         f"ops_completed={sum(r.ops_completed for r in reports)};"
         f"wal_pruned={pruned};elapsed_s={time.time() - t0:.1f}")

    assert all(r.check.ok for r in reports), \
        "a chaos history failed the linearizability check"
    assert crashes >= 2, \
        f"chaos sweep injected only {crashes} crashes; faults are dead"
    assert pruned > 0, "WAL prune cadence never ran under chaos"
    # fault injections are trace instants: when this section runs under
    # the tracer (benchmarks.run), the injected faults must be visible
    # inline with the service waves
    if tracing_enabled() and sum(r.faults_fired for r in reports):
        names = {e["name"] for e in get_tracer().events()}
        assert "chaos.fault" in names, (
            "faults fired but no chaos.fault instant reached the trace")
    for r in durable:
        assert r.wal_records < max(1, r.ops_completed), (
            f"{r.scenario.name}: {r.wal_records} WAL records for "
            f"{r.ops_completed} ops — pruning is not bounding the log")


if __name__ == "__main__":
    run()

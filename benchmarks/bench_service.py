"""Service-layer benchmarks: many clients on the sharded MwCAS service.

The section the ISSUE acceptance reads: aggregate round throughput
(completions per round wave — the substrate-independent unit; shard
rounds in one wave execute concurrently, kernel shards in ONE stacked
dispatch) must SCALE WITH SHARD COUNT on a Zipf-skewed many-client
workload.  The ``service_scaling`` row records s4/s1 explicitly and the
bench asserts S=4 strictly beats S=1, so a scaling regression fails CI
rather than just drifting.

Also measured: client-count sensitivity, defer/conflict rates and
p50/p99 latency in rounds, the durable service (real persists per op +
crash/recover), the BzTree-sharded service, and the raw scheduler's
cross-shard serialization cost.
"""
from __future__ import annotations

import dataclasses
import time

from repro.pmwcas import KernelBackend, MwCASOp
from repro.service import BatchScheduler, KVService, ShardRouter
from repro.structures import WorkloadSpec, client_streams, load_phase

from .common import emit, slo_observe

# Mutation-heavy so nearly every logical op compiles to a CAS (reads and
# misses complete at compile time and never occupy a round slot): the
# scaling lever under test is per-wave CAS capacity (round_cap x S), and
# a read-dominated mix would measure the compiler, not the substrate.
SPEC = WorkloadSpec(n_ops=192, n_keys=48, read=0.1, update=0.55,
                    insert=0.25, delete=0.1, alpha=0.9, seed=23)


def _run_service(svc: KVService, streams, load) -> dict:
    """Load, reset the measurement window, then submit every client's
    stream round-robin (the many-client arrival order) and drain."""
    svc.apply(load)
    svc.reset_stats()
    n = 0
    t0 = time.time()
    for i in range(max(len(s) for s in streams)):
        for client, stream in enumerate(streams):
            if i < len(stream):
                svc.submit(stream[i], client=client)
                n += 1
    svc.drain()
    dt = time.time() - t0
    svc.check_integrity()
    row = svc.stats.as_row()
    row["n_ops"] = n
    row["dt"] = dt
    return row


def _emit_kv(name: str, row: dict):
    extra = ""
    if "traces" in row:          # stacked dispatch ran: trace-cache row
        extra = (f";traces={row['traces']};"
                 f"dispatch_hits={row['dispatch_hits']};"
                 f"serial_rounds={row['serial_rounds']}")
    if "queue_us_p50" in row:    # the op-lifecycle latency breakdown
        extra += (f";queue_us_p50={row['queue_us_p50']:.1f};"
                  f"queue_us_p99={row['queue_us_p99']:.1f};"
                  f"dispatch_us_p50={row['dispatch_us_p50']:.1f};"
                  f"dispatch_us_p99={row['dispatch_us_p99']:.1f};"
                  f"persist_us_p50={row['persist_us_p50']:.1f};"
                  f"persist_us_p99={row['persist_us_p99']:.1f};"
                  f"retry_waves_max={row['retry_waves_max']}")
        # the three components partition each op's latency BY
        # CONSTRUCTION (service._complete), so their means must
        # reconcile with the latency mean to rounding noise
        parts = (row["queue_us_mean"] + row["dispatch_us_mean"]
                 + row["persist_us_mean"])
        lat = row["latency_us_mean"]
        assert abs(parts - lat) <= 0.02 * lat + 1e-6, (
            f"{name}: queue+dispatch+persist means ({parts:.3f}us) do "
            f"not reconcile with latency_us mean ({lat:.3f}us) — the "
            "lifecycle breakdown no longer partitions latency")
    emit(f"{name},{row['dt'] / row['n_ops'] * 1e6:.1f},"
         f"ops_per_s={row['n_ops'] / row['dt']:.0f};"
         f"ops_per_round={row['ops_per_step']:.2f};"
         f"steps={row['steps']:.0f};rounds={row['rounds']:.0f};"
         f"occupancy={row['occupancy']:.2f};"
         f"defer_rate={row['defer_rate']:.3f};"
         f"conflict_rate={row['conflict_rate']:.3f};"
         f"p50_rounds={row['p50_latency_rounds']:.0f};"
         f"p99_rounds={row['p99_latency_rounds']:.0f};"
         f"p50_us={row['p50_latency_us']:.1f};"
         f"p99_us={row['p99_latency_us']:.1f}" + extra)
    slo_observe(p99_latency_us=row["p99_latency_us"],
                ops_per_s=row["n_ops"] / row["dt"],
                **({"persist_us_p99": row["persist_us_p99"]}
                   if "persist_us_p99" in row else {}))


def run(quick: bool = False):
    spec = dataclasses.replace(SPEC, n_ops=96, n_keys=32) if quick else SPEC
    n_clients = 8
    round_cap = 4
    # full key universe pre-loaded: updates/deletes hit, so nearly every
    # logical op occupies a round slot (see SPEC comment)
    load = load_phase(spec, fraction=1.0)
    streams = client_streams(spec, n_clients)

    # -- KV service: throughput vs shard count (Zipf-skewed, 8 clients) ------
    shard_counts = (1, 4) if quick else (1, 2, 4)
    ops_per_round = {}
    us_per_call = {}
    traces = {}
    for s_count in shard_counts:
        svc = KVService(s_count, structure="hashmap",
                        n_buckets=-(-2 * spec.n_keys // s_count),
                        round_cap=round_cap)
        row = _run_service(svc, streams, load)
        ops_per_round[s_count] = row["ops_per_step"]
        us_per_call[s_count] = row["dt"] / row["n_ops"] * 1e6
        traces[s_count] = row.get("traces")
        _emit_kv(f"service_kv_S{s_count}_c{n_clients}_zipf{spec.alpha:g}",
                 row)

    # -- the acceptance rows: round throughput must scale AND the stacked
    # dispatch must be retrace-free in steady state (wall-clock ops/s
    # therefore scales too, instead of inverting under recompiles) -----------
    s_lo, s_hi = min(shard_counts), max(shard_counts)
    speedup = ops_per_round[s_hi] / max(ops_per_round[s_lo], 1e-9)
    emit(f"service_scaling,0.0,"
         f"ops_per_round_s{s_lo}={ops_per_round[s_lo]:.2f};"
         f"ops_per_round_s{s_hi}={ops_per_round[s_hi]:.2f};"
         f"speedup={speedup:.2f};"
         f"us_ratio_s{s_hi}_vs_s{s_lo}="
         f"{us_per_call[s_hi] / us_per_call[s_lo]:.2f};"
         f"traces_s{s_hi}={traces[s_hi]:.0f}")
    assert ops_per_round[s_hi] > ops_per_round[s_lo], (
        f"sharding must scale round throughput: S={s_hi} gave "
        f"{ops_per_round[s_hi]:.2f} ops/round vs S={s_lo} "
        f"{ops_per_round[s_lo]:.2f}")
    assert traces[s_hi] == 0, (
        f"stacked dispatch retraced {traces[s_hi]} times in the "
        "measurement window; shape bucketing has regressed")
    assert us_per_call[s_hi] <= 1.5 * us_per_call[s_lo], (
        f"S={s_hi} wall clock per call ({us_per_call[s_hi]:.0f}us) must "
        f"stay within 1.5x of S={s_lo} ({us_per_call[s_lo]:.0f}us) — "
        "the stacked dispatch is supposed to be cached, not recompiled")

    # -- client-count sensitivity at fixed S ---------------------------------
    for c in ((2,) if quick else (2, 16)):
        svc = KVService(4, structure="hashmap",
                        n_buckets=-(-2 * spec.n_keys // 4),
                        round_cap=round_cap)
        row = _run_service(svc, client_streams(spec, c), load)
        _emit_kv(f"service_kv_S4_c{c}_zipf{spec.alpha:g}", row)

    # -- BzTree-sharded service (splits + GC under service traffic) ----------
    t_spec = dataclasses.replace(spec, n_ops=min(spec.n_ops, 96),
                                 read=0.3, delete=0.0, insert=0.3,
                                 update=0.4)
    tsvc = KVService(2, structure="bztree", leaf_cap=4,
                     root_cap=max(4, t_spec.n_keys // 2),
                     n_regions=max(6, t_spec.n_keys // 2 + 2),
                     round_cap=round_cap)
    row = _run_service(tsvc, client_streams(t_spec, n_clients),
                       load_phase(t_spec))
    splits = sum(t.splits for t in tsvc.structs)
    freed = tsvc.gc_regions()
    _emit_kv("service_tree_S2", row)
    emit(f"service_tree_gc,0.0,splits={splits};regions_freed={freed}")

    # -- durable service: real persists per committed op + crash/recover -----
    d_spec = dataclasses.replace(spec, n_ops=min(spec.n_ops, 64))
    dsvc = KVService(2, structure="hashmap", backend="durable",
                     n_buckets=2 * d_spec.n_keys, round_cap=round_cap)
    d_load = load_phase(d_spec)
    d_streams = client_streams(d_spec, n_clients)
    row = _run_service(dsvc, d_streams, d_load)
    persists = sum(b.pool.persist_count for b in dsvc.backends)
    t0 = time.time()
    rec = dsvc.crash()
    recover_ms = (time.time() - t0) * 1e3
    assert rec.check_integrity() == dsvc.check_integrity()
    _emit_kv("service_kv_S2_durable", row)
    dstats = dsvc.durability_stats()
    emit(f"service_durable_recover,{recover_ms * 1e3:.0f},"
         f"persists_total={persists};"
         f"persists_per_commit="
         f"{persists / max(1, sum(s.ops_won for s in dsvc.stats.shards)):.2f};"
         f"flushes_saved={dstats.flushes_saved};fences={dstats.fences}")

    # -- raw scheduler: cross-shard serialization cost -----------------------
    n_shards, words = 4, 32
    for cross_pct in (0, 12):
        backends = [KernelBackend(n_words=words, use_kernel=False)
                    for _ in range(n_shards)]
        sched = BatchScheduler(
            backends, ShardRouter(n_shards, words_per_shard=words),
            round_cap=round_cap)
        ops = []
        n_raw = 32 if quick else 128
        for i in range(n_raw):
            if cross_pct and i % (100 // cross_pct) == 0:
                a = (i * 5) % words
                ops.append(MwCASOp([(a, 0, 1),
                                    (words + (a + 1) % words, 0, 1)]))
            else:
                shard = i % n_shards
                ops.append(MwCASOp([(shard * words + (i * 3) % words,
                                     0, 1)]))
        futs = sched.submit_many(ops)
        t0 = time.time()
        sched.step()                       # absorb first-dispatch compile
        sched.drain()
        dt = time.time() - t0
        ok = sum(1 for f in futs if f.success)
        emit(f"service_sched_cross{cross_pct},{dt / n_raw * 1e6:.1f},"
             f"ops_per_s={n_raw / dt:.0f};ok={ok};"
             f"ops_per_round={sched.stats.ops_per_step:.2f};"
             f"cross_rounds={sched.stats.cross_rounds}")


if __name__ == "__main__":
    run()

"""Kernel micro-benchmarks (CPU: jnp reference timing + interpret-mode
correctness scale sweep; the Pallas kernels target TPU — wall numbers here
are for the jnp paths that the dry-run deploys)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import _sdpa_chunked, _sdpa_ref
from repro.pmwcas import pmwcas_apply_ref

from .common import emit


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(quick: bool = False):
    # batched MwCAS: jnp reference path scaling
    for B in ((64,) if quick else (64, 256, 1024)):
        W, K = 1 << 16, 4
        rng = np.random.default_rng(0)
        words = jnp.zeros(W, jnp.uint32)
        addr = jnp.asarray(np.sort(rng.choice(W, (B, K), replace=False),
                                   axis=1), jnp.int32)
        exp = jnp.zeros((B, K), jnp.uint32)
        des = jnp.ones((B, K), jnp.uint32)
        f = jax.jit(pmwcas_apply_ref)
        dt = _time(f, words, addr, exp, des)
        emit(f"kern_pmwcas_apply_B{B},{dt*1e6:.1f},"
             f"descriptors_per_sec={B/dt:.0f}")

    # flash (chunked online-softmax) vs materialized reference
    for S in ((256,) if quick else (256, 1024)):
        B, KV, G, hd = 1, 2, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, KV, G, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
        qp = kp = jnp.arange(S)
        kw = dict(causal=True, window=0, attn_cap=0.0, scale=0.125)
        f_ref = jax.jit(lambda q, k, v: _sdpa_ref(q, k, v, qp, kp, **kw))
        f_chk = jax.jit(lambda q, k, v: _sdpa_chunked(q, k, v, qp, kp,
                                                      chunk=128, **kw))
        t_ref = _time(f_ref, q, k, v)
        t_chk = _time(f_chk, q, k, v)
        emit(f"kern_attn_ref_S{S},{t_ref*1e6:.1f},impl=materialized")
        emit(f"kern_attn_flash_S{S},{t_chk*1e6:.1f},impl=online_softmax;"
             f"ratio={t_ref/t_chk:.2f}")


if __name__ == "__main__":
    run()

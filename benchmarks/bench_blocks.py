"""Paper Fig. 14: false sharing — memory-block size vs throughput/latency.
Blocks < 64 B put several PMwCAS words on one cache line (invalidation
storms); blocks >= 64 B never do.  High-competitive environment only,
matching the paper."""
from __future__ import annotations

from repro.pmwcas import ORIGINAL, OURS, OURS_DF

from .common import BENCH_STEPS, BENCH_WORDS, emit, row, run_cell

BLOCKS = (8, 16, 32, 64, 128, 256)


def run(quick: bool = False):
    blocks = (8, 64, 256) if quick else BLOCKS
    steps = BENCH_STEPS // 4 if quick else BENCH_STEPS
    for k in (1, 3):
        for bs in blocks:
            for alg in (OURS, OURS_DF, ORIGINAL):
                r = run_cell(alg, n_threads=32, k=k,
                             n_words=BENCH_WORDS // 4, alpha=1.0,
                             block_bytes=bs, n_steps=steps, max_ops=512,
                             seed=19)
                emit(row(f"fig14_k{k}_block{bs}_{alg}", r))


if __name__ == "__main__":
    run()

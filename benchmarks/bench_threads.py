"""Paper Figs. 9 & 10: thread scaling of persistent 3-word and 1-word CAS
in low- (alpha=0) and high- (alpha=1) competitive environments."""
from __future__ import annotations

from repro.pmwcas import ORIGINAL, OURS, OURS_DF, PCAS

from .common import BENCH_STEPS, BENCH_WORDS, emit, row, run_cell

THREADS = (1, 4, 8, 16, 32, 56)


def run(quick: bool = False):
    threads = (1, 8, 32) if quick else THREADS
    steps = BENCH_STEPS // 4 if quick else BENCH_STEPS
    # Fig. 9: persistent three-word CAS
    for alpha in (0.0, 1.0):
        for t in threads:
            for alg in (OURS, OURS_DF, ORIGINAL):
                r = run_cell(alg, n_threads=t, k=3, n_words=BENCH_WORDS,
                             alpha=alpha, n_steps=steps, max_ops=512,
                             seed=11)
                emit(row(f"fig9_p3wcas_{alg}_t{t}_a{alpha:g}", r))
    # Fig. 10: persistent one-word CAS (incl. the PCAS competitor)
    for alpha in (0.0, 1.0):
        for t in threads:
            for alg in (OURS, OURS_DF, ORIGINAL, PCAS):
                r = run_cell(alg, n_threads=t, k=1, n_words=BENCH_WORDS,
                             alpha=alpha, n_steps=steps, max_ops=512,
                             seed=11)
                emit(row(f"fig10_p1wcas_{alg}_t{t}_a{alpha:g}", r))


if __name__ == "__main__":
    run()

"""Declarative SLOs for the benchmark sections.

Each section that calls :func:`benchmarks.common.slo_observe` gets its
queued observations replayed through a :class:`repro.obs.SloEngine`
built from the specs here, and the burn-rate verdicts land in
``SLO_<section>.json`` next to the BENCH file (schema-validated by
``scripts/obs_smoke.py``).

Bounds are intentionally loose regression tripwires, not performance
targets: a spec firing (``ok=False``) means BOTH the short and long
burn windows exceeded their error budget — sustained degradation, not a
single noisy sample.  The ``DEFAULT`` spec applies to every section, so
every ``SLO_<section>.json`` carries at least one evaluated spec even
for sections that queue no explicit observations (``benchmarks.run``
always appends one ``elapsed_s`` observation per section).
"""
from __future__ import annotations

from repro.obs import SloSpec

# applies to EVERY section: a whole-section wall-clock ceiling.  Bound is
# generous (full runs take minutes, quick runs seconds) — it exists so
# each section has >= 1 evaluated spec and a runaway run trips the gate.
DEFAULT = SloSpec(
    "section_elapsed", "elapsed_s", 3600.0, "ceiling", error_budget=0.0,
    description="benchmark section completes within an hour")

SECTION_SPECS = {
    "service": (
        SloSpec("service_p99_latency", "p99_latency_us", 2_000_000.0,
                "ceiling", error_budget=0.25,
                description="client p99 completion latency under 2s per "
                            "measured cell"),
        SloSpec("service_throughput", "ops_per_s", 1.0, "floor",
                error_budget=0.25,
                description="completed ops per wall second above 1"),
        SloSpec("service_persist_p99", "persist_us_p99", 1_000_000.0,
                "ceiling", error_budget=0.25,
                description="per-op persist share p99 under 1s"),
    ),
    "durable": (
        SloSpec("durable_group_redundant", "redundant_fences", 0.0,
                "ceiling", error_budget=0.0,
                description="group-commit hot path issues ZERO redundant "
                            "fences (the instruction class the paper "
                            "removes)"),
        SloSpec("durable_flushes_per_commit", "persists_per_commit", 64.0,
                "ceiling", error_budget=0.1,
                description="flush fences per committed op stay bounded"),
        SloSpec("durable_recover", "recover_us", 5_000_000.0, "ceiling",
                error_budget=0.1,
                description="WAL recovery under 5s"),
    ),
    "chaos": (
        SloSpec("chaos_p99_latency", "p99_latency_us", 5_000_000.0,
                "ceiling", error_budget=0.25,
                description="p99 completion latency under 5s through "
                            "fault schedules"),
        SloSpec("chaos_throughput", "ops_per_s", 1.0, "floor",
                error_budget=0.34,
                description="throughput floor holds during chaos"),
    ),
    "elastic": (
        SloSpec("elastic_mig_pause", "mig_pause_us_p99", 2_000_000.0,
                "ceiling", error_budget=0.25,
                description="migration write-pause p99 under 2s"),
    ),
}


def for_section(name: str):
    """Specs evaluated for a section: its own (if any) plus DEFAULT."""
    return tuple(SECTION_SPECS.get(name, ())) + (DEFAULT,)

#!/usr/bin/env python
"""Dead-import linter (stdlib-only fallback for ruff/pyflakes).

The public-surface migration keeps moving imports around; this catches
the classic residue — a name imported and never referenced — without
needing any package the image doesn't have.

    python scripts/check_imports.py src tests benchmarks examples

Heuristics (deliberately conservative, zero false positives preferred):
- a binding is "used" if its name occurs anywhere in the file outside
  its own import statement lines (source-text word match, so names in
  docstrings/string annotations/comments count as used);
- ``__init__.py`` files are skipped entirely (re-export surfaces);
- names listed in ``__all__``, underscore-prefixed names, and
  ``from __future__`` imports are exempt;
- an import line carrying a ``noqa`` comment is exempt (deliberate
  re-exports, import-order side effects).
Exit status 1 if any dead import is found.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys
from typing import Iterator, List, Tuple


def iter_py_files(roots: List[str]) -> Iterator[pathlib.Path]:
    for root in roots:
        p = pathlib.Path(root)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            yield from sorted(p.rglob("*.py"))


def import_bindings(tree: ast.Module) -> List[Tuple[str, int, int]]:
    """(bound name, first line, last line) of every import statement."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.asname or alias.name.split(".")[0]
                out.append((name, node.lineno, node.end_lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                name = alias.asname or alias.name
                out.append((name, node.lineno, node.end_lineno))
    return out


def declared_all(tree: ast.Module) -> set:
    names = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
            if "__all__" in targets:
                for elt in getattr(node.value, "elts", []):
                    if isinstance(elt, ast.Constant) and \
                            isinstance(elt.value, str):
                        names.add(elt.value)
    return names


def dead_imports(path: pathlib.Path) -> List[str]:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:
        return [f"{path}:{exc.lineno}: syntax error: {exc.msg}"]
    exported = declared_all(tree)
    lines = source.splitlines()
    findings = []
    for name, lo, hi in import_bindings(tree):
        if name.startswith("_") or name in exported:
            continue
        if any("noqa" in lines[i - 1]
               for i in range(lo, (hi or lo) + 1) if i <= len(lines)):
            continue
        pattern = re.compile(rf"\b{re.escape(name)}\b")
        uses = 0
        for i, line in enumerate(lines, start=1):
            if lo <= i <= (hi or lo):
                continue                      # the import statement itself
            uses += len(pattern.findall(line))
        if uses == 0:
            findings.append(f"{path}:{lo}: '{name}' imported but unused")
    return findings


def main(argv: List[str]) -> int:
    roots = argv or ["src", "tests", "benchmarks", "examples"]
    findings = []
    n_files = 0
    for path in iter_py_files(roots):
        if path.name == "__init__.py":
            continue
        n_files += 1
        findings.extend(dead_imports(path))
    for f in findings:
        print(f)
    print(f"check_imports: {n_files} files, {len(findings)} dead imports")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python
"""Observability smoke for CI: the obs layer must be free when off and
honest when on.

Two checks (both exercise the real instrumented stack, not mocks):

1. **Disabled overhead < 5%.**  The tracer's off-path is one enabled
   check returning a null span; direct A/B wall-clock of the workload
   is far too noisy at CI sizes to resolve a few percent, so the bound
   is computed from first principles instead: measure the per-span
   disabled cost c (tight loop over ``with span(...): pass``), count
   the spans E one traced run of the same workload actually emits, and
   assert ``c * E < 5%`` of the median disabled workload time.  Every
   quantity is measured, none assumed.

2. **Traces are loadable.**  Run one chaos scenario (crash faults on a
   durable service — the deepest span stack in the repo) under the
   tracer, export the Chrome trace, and re-validate it with the same
   schema check Perfetto relies on; also assert the scenario span
   actually decomposed (chaos.scenario has children).

3. **SLO reports are schema-valid.**  Build a small SloEngine, feed it
   observations, and run the report through
   :func:`repro.obs.validate_slo_report`; then, when a directory is
   given (CI passes the bench-smoke output dir), validate every
   ``SLO_<section>.json`` in it the same way and require each to carry
   at least one evaluated spec.

    PYTHONPATH=src python scripts/obs_smoke.py [BENCH_DIR]
"""
from __future__ import annotations

import json
import pathlib
import statistics
import sys
import tempfile
import time

from repro.obs import (SloEngine, SloSpec, disable_tracing, enable_tracing,
                       export_chrome_trace, get_tracer, span, span_tree,
                       tracing_enabled, validate_chrome_trace,
                       validate_slo_report)
from repro.pmwcas import MwCASOp, make_backend

OVERHEAD_BUDGET = 0.05


def _sim_workload(n_rounds: int = 24, batch: int = 16,
                  n_words: int = 256) -> None:
    """A steady-state sim-backend workload: disjoint 2-word MwCAS
    batches, round after round (the backend wraps each round in a
    ``mwcas.round`` span)."""
    backend = make_backend("sim", n_words=n_words)
    for r in range(n_rounds):
        ops = [MwCASOp([(2 * i, r, r + 1), (2 * i + 1, r, r + 1)])
               for i in range(batch)]
        results = backend.execute(ops)
        assert all(res.success for res in results)


def check_disabled_overhead() -> None:
    assert not tracing_enabled(), "smoke must start with tracing off"
    # median disabled workload time (median shrugs off one-off stalls)
    times = []
    for _ in range(5):
        t0 = time.perf_counter_ns()
        _sim_workload()
        times.append(time.perf_counter_ns() - t0)
    t_work = statistics.median(times)
    # per-span cost with the tracer DISABLED (the null-span fast path)
    n = 200_000
    t0 = time.perf_counter_ns()
    for _ in range(n):
        with span("obs_smoke.noop"):
            pass
    per_span_ns = (time.perf_counter_ns() - t0) / n
    # how many spans does one such workload actually emit when traced?
    enable_tracing().clear()
    try:
        _sim_workload()
        n_spans = len(get_tracer())
    finally:
        disable_tracing()
    overhead = per_span_ns * n_spans / t_work
    print(f"obs-smoke: disabled span cost {per_span_ns:.0f}ns x "
          f"{n_spans} spans = {overhead:.2%} of workload "
          f"({t_work / 1e6:.1f}ms)")
    assert overhead < OVERHEAD_BUDGET, (
        f"disabled-tracer overhead {overhead:.2%} exceeds the "
        f"{OVERHEAD_BUDGET:.0%} budget")


def check_trace_export() -> None:
    from repro.chaos import ScenarioDriver, default_scenarios

    # the crash family drives the deepest stack: scenario -> service
    # wave -> scheduler -> committer -> pmem, plus WAL recovery spans
    scenario = next(s for s in default_scenarios()
                    if s.family == "crash_mid_scan")
    enable_tracing().clear()
    try:
        with tempfile.TemporaryDirectory(prefix="obs_smoke_") as root:
            report = ScenarioDriver(scenario, durable_root=root).run()
    finally:
        disable_tracing()
    assert report.check is not None and report.check.ok, (
        f"{scenario.name} failed its linearizability check under tracing")
    with tempfile.TemporaryDirectory() as tmp:
        path = export_chrome_trace(pathlib.Path(tmp) / "TRACE_smoke.json")
        obj = json.loads(path.read_text())
    validate_chrome_trace(obj)
    tree = span_tree(obj["traceEvents"])
    children = tree.get("chaos.scenario", [])
    print(f"obs-smoke: {scenario.name} traced "
          f"{len(obj['traceEvents'])} events; "
          f"chaos.scenario -> {children}")
    assert children, "chaos.scenario span never decomposed into children"


def check_slo_reports(bench_dir: pathlib.Path | None) -> None:
    # self-check: a live engine's report must pass its own schema
    engine = SloEngine([
        SloSpec("p99", "p99_latency_us", 100.0, "ceiling",
                error_budget=0.1),
        SloSpec("tput", "ops_per_s", 10.0, "floor", error_budget=0.1),
    ], short_window=2, long_window=4)
    for v in (50.0, 150.0, 60.0):
        engine.observe({"p99_latency_us": v, "ops_per_s": 100.0})
    validate_slo_report(engine.report(section="smoke"))
    if bench_dir is None:
        print("obs-smoke: SLO schema self-check OK (no dir given)")
        return
    # every SLO_<section>.json the bench smoke emitted must validate
    # and carry at least one evaluated spec
    paths = sorted(bench_dir.glob("SLO_*.json"))
    assert paths, f"no SLO_*.json under {bench_dir} — the section " \
                  "runner stopped writing SLO verdicts"
    for path in paths:
        doc = validate_slo_report(json.loads(path.read_text()))
        evals = sum(s["evaluations"] for s in doc["specs"])
        assert evals > 0, f"{path.name}: no spec was ever evaluated"
    print(f"obs-smoke: {len(paths)} SLO report(s) schema-valid "
          f"({', '.join(p.name for p in paths)})")


def main() -> int:
    bench_dir = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else None
    check_disabled_overhead()
    check_trace_export()
    check_slo_reports(bench_dir)
    print("obs-smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Checkpointed-recovery smoke: the epoch-durability pipeline end to end.

Drives a 2-shard durable ``KVService`` with ``epoch_rounds=4,
checkpoint_every=2`` (one fence per four committed rounds, a WAL
checkpoint image every two epoch closes — DESIGN §14), then crashes it
and recovers from the on-disk image + surviving WAL tail.  Asserts:

- every acked op survives the crash (check_integrity image identical);
- the epoch machinery actually engaged (acks were held behind open
  epochs, fences were saved vs the per-round protocol);
- at least one checkpoint image landed on disk and bounded the WAL
  (surviving record count <= the cadence bound, not the op count);
- a second crash on the recovered service is a fixpoint.

Exit 0 on success; any assertion failing is a recovery regression.
CI runs this after the obs smoke (scripts/ci.sh step 5b).
"""
from __future__ import annotations

import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.service import KVService           # noqa: E402
from repro.structures import KVOp             # noqa: E402


def main() -> int:
    with tempfile.TemporaryDirectory(prefix="recovery-smoke-") as tmp:
        root = pathlib.Path(tmp)
        # round_cap=4 so 96 inserts make ~12 rounds per shard: enough
        # epoch closes (3 per shard at epoch_rounds=4) to cross the
        # checkpoint cadence and exercise the image-GC path
        svc = KVService(2, structure="hashmap", backend="durable",
                        n_buckets=64, round_cap=4, durable_root=root,
                        epoch_rounds=4, checkpoint_every=2)
        futs = [svc.submit(KVOp("insert", key=k, value=k + 1), client="c0")
                for k in range(1, 97)]
        svc.drain()
        assert all(f.done and f.result.status == "ok" for f in futs), \
            "smoke workload did not fully commit"
        stats = svc.stats
        dur = svc.durability_stats()
        assert stats.acks_held > 0, "no ack was ever held: epoch gate idle"
        assert dur.flushes_saved > 0, "epoch mode saved zero flushes"

        images = sorted(root.glob("shard*/ckpt/ckpt-*.json"))
        assert images, "no checkpoint image on disk after drain"
        wal = sorted(root.glob("shard*/wal/*.json"))
        cadence_bound = 2 * (svc.checkpoint_every + 1) * len(svc.backends)
        assert len(wal) <= cadence_bound, \
            f"WAL not bounded by checkpoints: {len(wal)} > {cadence_bound}"

        before = svc.check_integrity()
        rec = svc.crash()
        after = rec.check_integrity()
        assert after == before, "acked keys lost across crash+recover"
        assert rec.crash().check_integrity() == before, \
            "second crash is not a recovery fixpoint"

        print(f"recovery smoke OK: {len(futs)} acked ops survived crash; "
              f"acks_held={stats.acks_held} "
              f"flushes_saved={dur.flushes_saved} "
              f"ckpt_images={len(images)} wal_records={len(wal)}"
              f" (bound {cadence_bound})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Fold a benchmark output directory into one markdown observability
report.

Reads every ``BENCH_<section>.json``, ``SLO_<section>.json`` and
``TRACE_<section>.json`` in DIR (all three are optional per section)
and writes a single human-readable summary: per-section row tables,
SLO burn-rate verdicts, and trace event counts.  This is the "one
page" view of a CI bench run — the raw JSONs stay the machine
interface.

    python scripts/obs_report.py DIR [-o OUT.md]

With no ``-o`` the report goes to stdout.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# row fields promoted into the per-section table when present (the long
# tail of derived fields stays in the JSON)
_ROW_FIELDS = (
    "us_per_call", "ops_per_s", "mops", "persists_per_commit",
    "flushes_per_commit", "redundant_fences", "redundant_fences_per_op",
    "queue_us_p99", "dispatch_us_p99", "persist_us_p99",
    "p99_latency_us", "mig_pause_us_p99", "crashes", "lin_ok", "slo_ok",
)


def _fmt(val) -> str:
    if isinstance(val, bool):
        return str(int(val))
    if isinstance(val, float):
        return f"{val:.3g}"
    return str(val)


def _sections(directory: pathlib.Path) -> list:
    names = set()
    for kind in ("BENCH", "SLO", "TRACE"):
        for p in directory.glob(f"{kind}_*.json"):
            names.add(p.stem[len(kind) + 1:])
    return sorted(names)


def _load(directory: pathlib.Path, kind: str, section: str):
    path = directory / f"{kind}_{section}.json"
    if not path.exists():
        return None
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError:
        return {"_error": f"{path.name}: invalid JSON"}


def _bench_table(bench: dict) -> list:
    rows = [r for r in bench.get("rows", []) if "name" in r]
    if not rows:
        return ["(no rows)", ""]
    cols = ["name"] + [f for f in _ROW_FIELDS
                       if any(f in r for r in rows)]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(
            _fmt(r[c]) if c in r else "" for c in cols) + " |")
    out.append("")
    return out


def _slo_block(slo: dict) -> list:
    verdict = "OK" if slo.get("ok") else "**FIRING**"
    out = [f"SLO verdict: {verdict} "
           f"({slo.get('observations', 0)} observations, windows "
           f"short={slo.get('windows', {}).get('short')}/"
           f"long={slo.get('windows', {}).get('long')})", ""]
    cols = ("name", "metric", "kind", "bound", "evaluations",
            "violations", "burn_short", "burn_long", "worst", "ok")
    out += ["| " + " | ".join(cols) + " |",
            "|" + "|".join("---" for _ in cols) + "|"]
    for s in slo.get("specs", []):
        out.append("| " + " | ".join(
            _fmt(s[c]) if c in s else "" for c in cols) + " |")
    out.append("")
    return out


def _trace_block(trace: dict) -> list:
    events = trace.get("traceEvents", [])
    by_name = {}
    for e in events:
        by_name[e.get("name", "?")] = by_name.get(e.get("name", "?"), 0) + 1
    top = sorted(by_name.items(), key=lambda kv: -kv[1])[:8]
    return [f"Trace: {len(events)} events; top spans: "
            + ", ".join(f"`{n}`×{c}" for n, c in top), ""]


def _recover_block(trace: dict) -> list:
    """Recovery-phase breakdown from the ``recover.*`` spans: where the
    replay wall-clock went (checkpoint load vs record scan vs batched
    round replay).  Empty when the section's trace recorded no
    recovery."""
    phases = {}
    for e in trace.get("traceEvents", []):
        name = e.get("name", "")
        if e.get("ph") == "X" and name.startswith("recover."):
            spans, total = phases.get(name, (0, 0.0))
            phases[name] = (spans + 1, total + float(e.get("dur", 0)))
    if not phases:
        return []
    grand = sum(total for _n, total in phases.values()) or 1.0
    out = ["Recovery phases:", "",
           "| phase | spans | total_us | share |",
           "|---|---|---|---|"]
    for name in sorted(phases, key=lambda n: -phases[n][1]):
        spans, total = phases[name]
        out.append(f"| `{name}` | {spans} | {total:.0f} | "
                   f"{total / grand:.0%} |")
    out.append("")
    return out


def build_report(directory: pathlib.Path) -> str:
    sections = _sections(directory)
    lines = [f"# Observability report — `{directory}`", ""]
    if not sections:
        lines.append("No BENCH_/SLO_/TRACE_ JSON found.")
        return "\n".join(lines) + "\n"
    firing = [s for s in sections
              if (_load(directory, "SLO", s) or {}).get("ok") is False]
    lines.append(f"Sections: {len(sections)} "
                 f"({', '.join(sections)}); "
                 + (f"SLOs firing in: {', '.join(firing)}"
                    if firing else "all SLOs ok") + ".")
    lines.append("")
    for section in sections:
        lines.append(f"## {section}")
        lines.append("")
        bench = _load(directory, "BENCH", section)
        if bench is not None:
            if "_error" in bench:
                lines += [bench["_error"], ""]
            else:
                lines.append(f"Bench: {len(bench.get('rows', []))} rows, "
                             f"elapsed {bench.get('elapsed_s', '?')}s"
                             + (", quick" if bench.get("quick") else "")
                             + ".")
                lines.append("")
                lines += _bench_table(bench)
        slo = _load(directory, "SLO", section)
        if slo is not None:
            lines += (_slo_block(slo) if "_error" not in slo
                      else [slo["_error"], ""])
        trace = _load(directory, "TRACE", section)
        if trace is not None:
            if "_error" in trace:
                lines += [trace["_error"], ""]
            else:
                lines += _trace_block(trace)
                lines += _recover_block(trace)
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("directory", type=pathlib.Path,
                    help="dir holding BENCH_/SLO_/TRACE_<section>.json")
    ap.add_argument("-o", "--out", type=pathlib.Path, default=None,
                    help="write the markdown here (default: stdout)")
    args = ap.parse_args()
    if not args.directory.is_dir():
        print(f"obs-report: {args.directory} is not a directory",
              file=sys.stderr)
        return 2
    report = build_report(args.directory)
    if args.out is not None:
        args.out.write_text(report)
        print(f"obs-report: wrote {args.out}")
    else:
        print(report, end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())

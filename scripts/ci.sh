#!/usr/bin/env bash
# CI entry point: install the package, run the tier-1 suite, then a quick
# benchmark smoke so API regressions in benchmarks/run.py are caught.
#
#   bash scripts/ci.sh            # full tier-1 + smoke
#   SKIP_INSTALL=1 bash scripts/ci.sh   # PYTHONPATH fallback (no pip)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "=== 1. install ==="
if [ "${SKIP_INSTALL:-0}" = "1" ]; then
    echo "SKIP_INSTALL=1: using PYTHONPATH=src instead of pip"
    export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
elif pip install -e . --no-deps --no-build-isolation --quiet 2>/dev/null; then
    # --no-build-isolation: the image's setuptools builds offline;
    # --no-deps: jax/numpy come from the environment ('pip install -e
    # .[test]' adds the optional hypothesis when a network exists)
    echo "installed repro-pmwcas (editable)"
else
    echo "pip install failed (offline image?); falling back to PYTHONPATH=src"
    export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
fi

echo "=== 2. lint: dead imports can't land ==="
if command -v ruff >/dev/null 2>&1; then
    ruff check --select F401 src tests benchmarks examples
elif python -c "import pyflakes" 2>/dev/null; then
    python -m pyflakes src tests benchmarks examples
else
    # offline image: stdlib fallback with the same intent
    python scripts/check_imports.py src tests benchmarks examples
fi

echo "=== 3. tier-1 tests ==="
python -m pytest -x -q

echo "=== 4. benchmark smoke (API regression tripwire) ==="
BENCH_DIR=".bench/current"
rm -rf "$BENCH_DIR" && mkdir -p "$BENCH_DIR"
python -m benchmarks.run --quick --only diff --json-dir "$BENCH_DIR"
python -m benchmarks.run --quick --only ckpt --json-dir "$BENCH_DIR"
python -m benchmarks.run --quick --only structs --json-dir "$BENCH_DIR"
python -m benchmarks.run --quick --only tree --json-dir "$BENCH_DIR"
# the service section asserts S=4 strictly beats S=1 on round throughput
# AND zero steady-state retraces of the stacked dispatch
python -m benchmarks.run --quick --only service --json-dir "$BENCH_DIR"
# the durable section asserts group commit beats per-op commit on ops/s
# and flush count (and seeds the .bench/baseline entry below)
python -m benchmarks.run --quick --only durable --json-dir "$BENCH_DIR"
# the chaos section runs every scenario family under fault injection and
# asserts all completed histories pass the linearizability check
python -m benchmarks.run --quick --only chaos --json-dir "$BENCH_DIR"
# the elastic section asserts online growth absorbs the load with zero
# FULL/EXHAUSTED and that migrations preserve the key/value image
python -m benchmarks.run --quick --only elastic --json-dir "$BENCH_DIR"

echo "=== 5. obs smoke (tracer overhead + trace/SLO schemas) ==="
# asserts the off-path costs < 5% of a sim workload, that a traced
# chaos scenario exports a schema-valid (Perfetto-loadable) trace, and
# that every SLO_<section>.json the bench smoke wrote validates with
# >= 1 evaluated spec
python scripts/obs_smoke.py "$BENCH_DIR"

echo "=== 5b. checkpointed-recovery smoke (epoch durability end to end) ==="
# drives an epoch_rounds=4/checkpoint_every=2 durable service through
# write -> crash -> recover and asserts acked ops survive, a checkpoint
# image bounds the WAL, and a second crash is a fixpoint (DESIGN Sec. 14)
python scripts/recovery_smoke.py

echo "=== 6. perf trend (>20% regressions vs previous run) ==="
# warn-only by default (first run has no baseline); PERF_STRICT=1 gates.
# The redundant_fences zero-tolerance check fails even without strict.
python scripts/perf_trend.py "$BENCH_DIR" .bench/baseline \
    ${PERF_STRICT:+--strict}

echo "=== 6b. obs report (fold BENCH_/TRACE_/SLO_ into one page) ==="
python scripts/obs_report.py "$BENCH_DIR" -o "$BENCH_DIR/REPORT.md"
head -n 5 "$BENCH_DIR/REPORT.md"

echo "=== 7. cross-backend differential examples ==="
python examples/quickstart.py > /dev/null
echo "quickstart OK"
python examples/kv_store.py > /dev/null
echo "kv_store OK"
python examples/range_index.py > /dev/null
echo "range_index OK"
python examples/kv_service.py > /dev/null
echo "kv_service OK"
python examples/chaos_demo.py > /dev/null
echo "chaos_demo OK"

echo "CI PASSED"

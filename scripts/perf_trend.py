#!/usr/bin/env python
"""Perf-trend check over the machine-readable benchmark output.

Compares every ``BENCH_<section>.json`` in CURRENT_DIR against the copy
from the previous run in BASELINE_DIR and flags regressions in BOTH
directions: a row regresses when its throughput metric drops by more
than --threshold (default 20%), or when a lower-is-better metric
(``flushes_per_commit``, ``recover_us`` — the paper's headline costs)
RISES by more than the threshold.  Rows are matched by their ``name``
field; the throughput metric is ``ops_per_s`` where present, else
``mops`` (the simulator sections).
After the comparison the current JSONs are promoted to the baseline, so
successive CI runs always compare against their predecessor.

A third gate needs no baseline at all: ``ZERO_TOLERANCE`` metrics
(``redundant_fences`` — the group-commit hot path's provenance counter)
must be exactly zero in every current row, and a violation fails the
run even without --strict (it is a correctness property, not a noisy
wall-clock trend).

On the first run (no baseline) nothing else is compared — warn-only by
design.  Regressions print warnings and exit 0 unless --strict (CI can
opt in via ``PERF_STRICT=1 bash scripts/ci.sh``): wall-clock benches on
shared runners are noisy, so the trend is a tripwire, not a gate, until
an operator decides otherwise.

    python scripts/perf_trend.py CURRENT_DIR BASELINE_DIR [--threshold F]
                                 [--strict]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys

METRICS = ("ops_per_s", "mops")      # first present wins
# cost metrics where a RISE is the regression (flush accounting comes
# straight from the obs registry, so a rise means the flush-elision
# machinery — the paper's point — has leaked flushes back in; the
# migration pause is the elastic section's availability headline; the
# queue/persist tails are the op-lifecycle breakdown's gateable legs)
LOWER_IS_BETTER = ("flushes_per_commit", "recover_us", "recover_ms",
                   "mig_pause_us_p99", "queue_us_p99", "persist_us_p99")
# metrics that must be EXACTLY ZERO in the current run, baseline or not:
# a single redundant fence on the group-commit hot path reintroduces the
# instruction class the paper removes (the per-op row deliberately uses
# the distinct name ``redundant_fences_per_op``, which is expected > 0)
ZERO_TOLERANCE = ("redundant_fences",)


def _metric(row: dict):
    for key in METRICS:
        val = row.get(key)
        if isinstance(val, (int, float)) and val > 0:
            return key, float(val)
    return None, None


def _rows_by_name(path: pathlib.Path) -> dict:
    """Measured rows keyed by name.  Synthetic summary rows (e.g.
    ``service_scaling``, ``service_tree_gc``) carry ``us_per_call ==
    0.0`` — they are derived ratios/counts, not measurements, and must
    not pollute trend comparisons."""
    data = json.loads(path.read_text())
    return {r["name"]: r for r in data.get("rows", [])
            if "name" in r and r.get("us_per_call") != 0.0}


def compare(current: pathlib.Path, baseline: pathlib.Path,
            threshold: float) -> list:
    """[(section, row name, metric, old, new, change fraction,
    direction), ...] — direction is "drop" for throughput metrics and
    "rise" for the lower-is-better cost metrics."""
    regressions = []
    for cur_path in sorted(current.glob("BENCH_*.json")):
        base_path = baseline / cur_path.name
        section = cur_path.stem[len("BENCH_"):]
        if not base_path.exists():
            print(f"perf-trend: no baseline for {section}; recording only")
            continue
        base_rows = _rows_by_name(base_path)
        for name, row in _rows_by_name(cur_path).items():
            if name not in base_rows:
                continue
            base = base_rows[name]
            key, new = _metric(row)
            if key is not None:
                old_key, old = _metric(base)
                if old_key == key and old:
                    drop = (old - new) / old
                    if drop > threshold:
                        regressions.append(
                            (section, name, key, old, new, drop, "drop"))
            for key in LOWER_IS_BETTER:
                new, old = row.get(key), base.get(key)
                if not isinstance(new, (int, float)) or \
                        not isinstance(old, (int, float)) or old <= 0:
                    continue
                rise = (new - old) / old
                if rise > threshold:
                    regressions.append(
                        (section, name, key, old, new, rise, "rise"))
    return regressions


def zero_check(current: pathlib.Path) -> list:
    """Zero-tolerance gate: runs over EVERY current row (including the
    synthetic summary rows), needs no baseline.  Returns
    [(section, row name, metric, value, value, 1.0, "nonzero"), ...]."""
    violations = []
    for cur_path in sorted(current.glob("BENCH_*.json")):
        section = cur_path.stem[len("BENCH_"):]
        data = json.loads(cur_path.read_text())
        for row in data.get("rows", []):
            for key in ZERO_TOLERANCE:
                val = row.get(key)
                if isinstance(val, (int, float)) and val != 0:
                    violations.append((section, row.get("name", "?"), key,
                                       val, val, 1.0, "nonzero"))
    return violations


def promote(current: pathlib.Path, baseline: pathlib.Path) -> None:
    baseline.mkdir(parents=True, exist_ok=True)
    for cur_path in current.glob("BENCH_*.json"):
        shutil.copy2(cur_path, baseline / cur_path.name)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", type=pathlib.Path,
                    help="directory holding this run's BENCH_*.json")
    ap.add_argument("baseline", type=pathlib.Path,
                    help="directory holding the previous run's copies")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="flag drops larger than this fraction (0.20)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when a regression is flagged")
    args = ap.parse_args()

    regressions = compare(args.current, args.baseline, args.threshold)
    zeros = zero_check(args.current)
    for section, name, key, old, new, change, direction in regressions:
        sign = "-" if direction == "drop" else "+"
        print(f"perf-trend REGRESSION [{section}] {name}: "
              f"{key} {old:.3g} -> {new:.3g} ({sign}{change:.0%})")
    for section, name, key, _old, new, _change, _direction in zeros:
        print(f"perf-trend ZERO-TOLERANCE [{section}] {name}: "
              f"{key} = {new:.3g} (must be 0)")
    if not regressions and not zeros:
        print(f"perf-trend: no >{args.threshold:.0%} regressions; "
              "zero-tolerance metrics clean")
    # the zero-tolerance gate is a correctness property, not a noisy
    # wall-clock trend: it fails even without --strict
    failing = bool(zeros) or bool(regressions and args.strict)
    if failing:
        # keep the pre-regression baseline: promoting the regressed run
        # would make an unchanged retry compare against itself and pass
        print("perf-trend: strict failure — baseline NOT updated")
    else:
        promote(args.current, args.baseline)
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())

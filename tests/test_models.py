"""Model substrate tests: per-arch smoke, attention impl equivalence,
prefill/decode parity against the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Smoke: every assigned arch, one train step on CPU, shapes + finite values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward(arch, key):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init_params(key)
    B, S = 2, 16
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = 0.02 * jnp.ones(
            (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    loss = jax.jit(m.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch, key):
    """One full optimizer step: loss decreases over a few steps on a
    memorizable batch."""
    from repro.optim import adamw
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init_params(key)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=50,
                                weight_decay=0.0)
    opt = adamw.init_state(opt_cfg, params)
    B, S = 2, 16
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = 0.02 * jnp.ones(
            (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
        params, opt, _ = adamw.update(opt_cfg, grads, opt, params)
        return params, opt, loss

    losses = []
    for _ in range(5):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


# ---------------------------------------------------------------------------
# Prefill/decode parity: decoding token-by-token must match the teacher-forced
# forward pass (same cache semantics across attn/mamba/mlstm/slstm layers)
# ---------------------------------------------------------------------------

PARITY_ARCHS = ["llama3_8b", "gemma2_9b", "glm4_9b", "qwen3_moe_30b_a3b",
                "jamba_v01_52b", "xlstm_125m", "paligemma_3b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_decode_parity(arch, key):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8)
    if cfg.moe is not None:
        # prefill uses the capacity path; make it effectively dropless so
        # parity with the (always dropless) decode path is exact
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg)
    params = m.init_params(key)
    B, S, extra = 2, 12, 4
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                             cfg.vocab)
    fe = (0.02 * jnp.ones((B, cfg.frontend_len, cfg.frontend_dim),
                          jnp.float32) if cfg.frontend != "none" else None)

    # the cache covers prefix (vision/audio stub) + text positions
    total = S + extra + cfg.frontend_len
    # reference: prefill over k tokens gives logits for position k-1
    def logits_at(k):
        cache = m.init_cache(B, total)
        lg, _ = jax.jit(m.prefill)(params, tok[:, :k], cache,
                                   fe) if fe is not None else \
            jax.jit(m.prefill)(params, tok[:, :k], cache)
        return lg

    cache = m.init_cache(B, total)
    if fe is not None:
        last, cache = jax.jit(m.prefill)(params, tok[:, :S], cache, fe)
    else:
        last, cache = jax.jit(m.prefill)(params, tok[:, :S], cache)
    dec = jax.jit(m.decode_step)
    for i in range(extra):
        ref = logits_at(S + i)
        # bf16 matmul reduction order differs between the batched prefill
        # and the single-token decode; tolerance sized to bf16 eps
        np.testing.assert_allclose(np.asarray(last, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=6e-2, atol=6e-2)
        last, cache = dec(params, tok[:, S + i:S + i + 1], cache)


def test_int8_kv_cache_close_to_bf16(key):
    cfg = get_config("llama3_8b", smoke=True)
    m16 = build_model(dataclasses.replace(cfg, kv_dtype="bfloat16"))
    m8 = build_model(dataclasses.replace(cfg, kv_dtype="int8"))
    params = m16.init_params(key)
    B, S = 2, 12
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    l16, _ = jax.jit(m16.prefill)(params, tok, m16.init_cache(B, S))
    l8, _ = jax.jit(m8.prefill)(params, tok, m8.init_cache(B, S))
    # int8 quantization error is bounded; logits stay close
    corr = np.corrcoef(np.asarray(l16, np.float32).ravel(),
                       np.asarray(l8, np.float32).ravel())[0, 1]
    assert corr > 0.99


# ---------------------------------------------------------------------------
# Attention implementations agree (ref vs chunked incl. gqa/softcap/window)
# ---------------------------------------------------------------------------

def test_attention_impls_agree(key):
    from repro.models import attention as A
    B, KV, G, Sq, Sk, hd = 2, 2, 4, 24, 24, 16
    q = jax.random.normal(key, (B, KV, G, Sq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, Sk, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, Sk, hd))
    qp = jnp.arange(Sq)
    kp = jnp.arange(Sk)
    for causal in (True, False):
        for window in (0, 7):
            for cap in (0.0, 30.0):
                kw = dict(causal=causal, window=window, attn_cap=cap,
                          scale=0.25)
                o1 = A._sdpa_ref(q, k, v, qp, kp, **kw)
                o2 = A._sdpa_chunked(q, k, v, qp, kp, chunk=5, **kw)
                np.testing.assert_allclose(o1, o2, rtol=3e-5, atol=3e-5)


def test_flash_gradients_match_ref(key):
    from repro.models import attention as A
    B, KV, G, S, hd = 1, 2, 2, 16, 8
    q = jax.random.normal(key, (B, KV, G, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd))
    qp = kp = jnp.arange(S)
    kw = dict(causal=True, window=0, attn_cap=25.0, scale=0.3)
    g1 = jax.grad(lambda *a: A._sdpa_ref(*a, qp, kp, **kw).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: A._sdpa_chunked(*a, qp, kp, chunk=6,
                                             **kw).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# MoE unit behaviour
# ---------------------------------------------------------------------------

def test_moe_routes_and_combines(key):
    from repro.models import moe as M
    from repro.models.layers import KeyGen
    kg = KeyGen(key)
    D, E, F = 16, 4, 32
    p = M.init_moe(kg, D, E, F, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, D))
    y, aux = M.apply_moe(p, x, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # Switch aux is ~1 for balanced routing (can dip slightly below when
    # probability mass and dispatch counts anticorrelate)
    assert 0.5 < float(aux) < 4.0


def test_moe_capacity_drops_dont_nan(key):
    from repro.models import moe as M
    from repro.models.layers import KeyGen
    kg = KeyGen(key)
    p = M.init_moe(kg, 8, 2, 16, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 8))
    # capacity_factor tiny -> most tokens dropped -> residual passthrough
    y, _ = M.apply_moe(p, x, top_k=2, capacity_factor=0.05)
    assert np.isfinite(np.asarray(y)).all()

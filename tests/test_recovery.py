"""Crash-recovery consistency: the paper's Figs. 6/7 state machines, tested
by crashing at many points of real schedules and at hypothesis-chosen
configurations.  The central invariant:

    recovered(w) == initial(w) + #(durably-committed ops covering w)

where durable commitment is exactly "state=Succeeded was persisted"
(Fig. 4 line 15) — descriptors acting as write-ahead logs."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ALG_ORIGINAL, ALG_OURS, ALG_OURS_DF, ALG_PCAS,
                        SimConfig, check_crash_consistency, recover,
                        run_until)

ALGS = [(ALG_OURS, 3), (ALG_OURS_DF, 3), (ALG_ORIGINAL, 2), (ALG_PCAS, 1)]


def _cfg(alg, k, seed=3, **kw):
    base = dict(algorithm=alg, n_threads=4, n_words=64, k=k,
                n_steps=1200, max_ops=32, seed=seed)
    base.update(kw)
    return SimConfig(**base)


@pytest.mark.parametrize("alg,k", ALGS)
def test_crash_sweep(alg, k):
    """Crash at a grid of points across one schedule."""
    cfg = _cfg(alg, k)
    for step in range(1, cfg.n_steps, 53):
        r = run_until(cfg, step)
        check_crash_consistency(cfg, r.state)


@pytest.mark.parametrize("alg,k", ALGS)
def test_crash_exhaustive_prefix(alg, k):
    """Every single crash point of a short hot schedule (16 words, dense
    conflicts) recovers consistently."""
    cfg = _cfg(alg, k, n_words=16, n_steps=400, alpha=1.0)
    for step in range(1, 400, 1):
        r = run_until(cfg, step)
        check_crash_consistency(cfg, r.state)


@pytest.mark.parametrize("alg,k", ALGS)
def test_recovery_idempotent(alg, k):
    cfg = _cfg(alg, k)
    r = run_until(cfg, 777)
    rec1 = recover(cfg, r.state)
    st2 = dict(r.state)
    st2["pmem"] = rec1
    rec2 = recover(cfg, st2)
    assert np.array_equal(rec1, rec2)


@settings(max_examples=25, deadline=None)
@given(
    alg=st.sampled_from([ALG_OURS, ALG_OURS_DF, ALG_ORIGINAL]),
    k=st.integers(min_value=1, max_value=4),
    threads=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    crash_frac=st.floats(min_value=0.01, max_value=0.99),
    alpha=st.sampled_from([0.0, 1.0]),
)
def test_crash_consistency_property(alg, k, threads, seed, crash_frac, alpha):
    """Hypothesis: any (algorithm, geometry, skew, schedule, crash point)
    combination recovers to the committed-prefix state."""
    cfg = SimConfig(algorithm=alg, n_threads=threads, n_words=32, k=k,
                    n_steps=600, max_ops=16, seed=seed, alpha=alpha)
    step = max(1, int(600 * crash_frac))
    r = run_until(cfg, step)
    check_crash_consistency(cfg, r.state)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       crash_frac=st.floats(min_value=0.01, max_value=0.99))
def test_crash_consistency_pcas_property(seed, crash_frac):
    cfg = SimConfig(algorithm=ALG_PCAS, n_threads=4, n_words=16, k=1,
                    n_steps=600, max_ops=16, seed=seed, alpha=1.0)
    r = run_until(cfg, max(1, int(600 * crash_frac)))
    check_crash_consistency(cfg, r.state)


def test_recovered_state_has_no_tags():
    for alg, k in ALGS:
        cfg = _cfg(alg, k, alpha=1.0, n_words=16)
        r = run_until(cfg, 399)
        rec = recover(cfg, r.state)
        assert (rec & 0b111 == 0).all()

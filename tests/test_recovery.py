"""Crash-recovery consistency: the paper's Figs. 6/7 state machines, tested
by crashing at many points of real schedules.  The central invariant:

    recovered(w) == initial(w) + #(durably-committed ops covering w)

where durable commitment is exactly "state=Succeeded was persisted"
(Fig. 4 line 15) — descriptors acting as write-ahead logs.

Property tests run under hypothesis when installed (``pip install -e
.[test]``); a deterministic configuration sweep runs regardless."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dependency
    HAVE_HYPOTHESIS = False

from repro.pmwcas import (ORIGINAL, OURS, OURS_DF, PCAS, SimConfig,
                          SimSession, check_crash_consistency, recover,
                          run_until)

ALGS = [(OURS, 3), (OURS_DF, 3), (ORIGINAL, 2), (PCAS, 1)]


def _session(alg, k, seed=3, **kw) -> SimSession:
    base = dict(n_threads=4, n_words=64, k=k, n_steps=1200, max_ops=32,
                seed=seed)
    base.update(kw)
    return SimSession().with_algorithm(alg).configure(**base)


@pytest.mark.parametrize("alg,k", ALGS)
def test_crash_sweep(alg, k):
    """Crash at a grid of points across one schedule."""
    s = _session(alg, k)
    for step in range(1, s.cfg.n_steps, 53):
        s.crash_at(step)


@pytest.mark.parametrize("alg,k", ALGS)
def test_crash_exhaustive_prefix(alg, k):
    """Every single crash point of a short hot schedule (16 words, dense
    conflicts) recovers consistently."""
    s = _session(alg, k, n_words=16, n_steps=400, alpha=1.0)
    for step in range(1, 400, 1):
        s.crash_at(step)


@pytest.mark.parametrize("alg,k", ALGS)
def test_recovery_idempotent(alg, k):
    s = _session(alg, k)
    r = s.run_until(777)
    rec1 = recover(s.cfg, r.state)
    st2 = dict(r.state)
    st2["pmem"] = rec1
    rec2 = recover(s.cfg, st2)
    assert np.array_equal(rec1, rec2)


def _check_crash_property(alg, k, threads, seed, crash_frac, alpha):
    """Any (algorithm, geometry, skew, schedule, crash point) combination
    recovers to the committed-prefix state."""
    s = (SimSession().with_algorithm(alg)
         .configure(n_threads=threads, n_words=32, k=k, n_steps=600,
                    max_ops=16, seed=seed, alpha=alpha))
    s.crash_at(max(1, int(600 * crash_frac)))


# Deterministic sweep: always runs, hypothesis or not.
@pytest.mark.parametrize("alg,k,threads,seed,crash_frac,alpha", [
    (OURS, 3, 4, 0, 0.13, 1.0),
    (OURS, 1, 2, 1, 0.77, 0.0),
    (OURS_DF, 4, 6, 2, 0.42, 1.0),
    (OURS_DF, 2, 3, 3, 0.95, 0.0),
    (ORIGINAL, 2, 4, 4, 0.31, 1.0),
    (ORIGINAL, 3, 5, 5, 0.58, 0.0),
])
def test_crash_consistency_deterministic(alg, k, threads, seed, crash_frac,
                                         alpha):
    _check_crash_property(alg, k, threads, seed, crash_frac, alpha)


@pytest.mark.parametrize("seed,crash_frac", [(0, 0.2), (1, 0.6), (2, 0.9)])
def test_crash_consistency_pcas_deterministic(seed, crash_frac):
    cfg = SimConfig(algorithm=PCAS.name, n_threads=4, n_words=16, k=1,
                    n_steps=600, max_ops=16, seed=seed, alpha=1.0)
    r = run_until(cfg, max(1, int(600 * crash_frac)))
    check_crash_consistency(cfg, r.state)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        alg=st.sampled_from([OURS, OURS_DF, ORIGINAL]),
        k=st.integers(min_value=1, max_value=4),
        threads=st.integers(min_value=2, max_value=6),
        seed=st.integers(min_value=0, max_value=2 ** 16),
        crash_frac=st.floats(min_value=0.01, max_value=0.99),
        alpha=st.sampled_from([0.0, 1.0]),
    )
    def test_crash_consistency_property(alg, k, threads, seed, crash_frac,
                                        alpha):
        _check_crash_property(alg, k, threads, seed, crash_frac, alpha)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16),
           crash_frac=st.floats(min_value=0.01, max_value=0.99))
    def test_crash_consistency_pcas_property(seed, crash_frac):
        cfg = SimConfig(algorithm=PCAS.name, n_threads=4, n_words=16, k=1,
                        n_steps=600, max_ops=16, seed=seed, alpha=1.0)
        r = run_until(cfg, max(1, int(600 * crash_frac)))
        check_crash_consistency(cfg, r.state)
else:
    def test_crash_consistency_property():
        pytest.importorskip("hypothesis")

    def test_crash_consistency_pcas_property():
        pytest.importorskip("hypothesis")


def test_recovered_state_has_no_tags():
    for alg, k in ALGS:
        s = _session(alg, k, alpha=1.0, n_words=16)
        r = s.run_until(399)
        rec = recover(s.cfg, r.state)
        assert (rec & 0b111 == 0).all()

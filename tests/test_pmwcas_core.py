"""Core PMwCAS algorithm tests: quiescent invariants + the paper's
instruction-count claims (Sec. 2.1/3/4), through the repro.pmwcas
public surface (SimSession + algorithm strategies)."""
import numpy as np
import pytest

from repro.pmwcas import (CNT_CAS, CNT_FLUSH, CNT_HELPS, CNT_INVAL,
                          ORIGINAL, OURS, OURS_DF, PCAS, SimSession,
                          TAG_DIRTY)


def _session(alg, k, **kw) -> SimSession:
    base = dict(n_threads=4, n_words=256, k=k, n_steps=4000, max_ops=64,
                seed=7)
    base.update(kw)
    return SimSession().with_algorithm(alg).configure(**base)


def _run(alg, k, **kw):
    return _session(alg, k, **kw).run()


QUIESCE = [(OURS, 3), (OURS_DF, 3), (ORIGINAL, 3), (PCAS, 1)]


@pytest.mark.parametrize("alg,k", QUIESCE)
def test_quiescent_sum_invariant(alg, k):
    """Every successful k-word op adds exactly 1 to each target word."""
    r = _run(alg, k)
    assert r.ops_completed > 0
    # cache is always clean at quiescence
    assert (r.tags("cache") == 0).all()
    got = r.payload_values("cache").astype(np.int64)
    assert np.array_equal(got, r.expected_histogram())
    # pmem holds the same values; ours/ours_df also clear tags in pmem,
    # original/pcas legitimately leave dirty flags (single-flush finalize)
    ptags = r.tags("pmem")
    if alg in (OURS, OURS_DF):
        assert (ptags == 0).all()
        assert np.array_equal(r.state["cache"], r.state["pmem"])
    else:
        assert np.isin(ptags, [0, int(TAG_DIRTY)]).all()
    assert np.array_equal(r.payload_values("pmem").astype(np.int64),
                          r.expected_histogram())


@pytest.mark.parametrize("alg,k", QUIESCE)
def test_no_descriptor_references_leak(alg, k):
    """The paper's no-GC claim: zero outstanding references at quiescence."""
    r = _run(alg, k)
    assert (r.state["ref_cache"] == 0).all()
    assert (r.state["ref_pmem"] == 0).all()


def test_cas_counts_ours_2k():
    """Sec 2.1: ours needs 2k CAS-class ops per op in the no-conflict case,
    exactly the strategy object's analytical claim."""
    # single thread -> zero conflicts -> exact counts
    r = _run(OURS, 3, n_threads=1, n_steps=3000)
    assert r.ops_completed > 10
    assert r.per_op(CNT_CAS) == pytest.approx(OURS.cas_per_op(3), abs=0.01)


def test_cas_counts_original_4k():
    """Sec 2.1: the original algorithm needs 4k CAS-class ops on the target
    words (+1 for the status-word CAS, which the paper does not count)."""
    r = _run(ORIGINAL, 3, n_threads=1, n_steps=3000)
    assert r.ops_completed > 10
    assert r.per_op(CNT_CAS) == pytest.approx(ORIGINAL.cas_per_op(3) + 1,
                                              abs=0.01)


def test_cas_counts_pcas():
    """PCAS: one CAS + one clear store (2 CAS-class), single flush."""
    r = _run(PCAS, 1, n_threads=1, n_steps=2000)
    assert r.per_op(CNT_CAS) == pytest.approx(PCAS.cas_per_op(1), abs=0.01)
    assert r.per_op(CNT_FLUSH) == pytest.approx(1, abs=0.01)


def test_flush_counts_ours_vs_df():
    """Dirty flags add exactly k flushes per op (lines 20-22 of Fig. 4)."""
    k = 3
    r1 = _run(OURS, k, n_threads=1, n_steps=3000)
    r2 = _run(OURS_DF, k, n_threads=1, n_steps=3000)
    assert r2.per_op(CNT_FLUSH) - r1.per_op(CNT_FLUSH) == pytest.approx(
        k, abs=0.01)


def test_ours_beats_original_under_contention():
    """Fig. 9's headline: fewer CAS/flush events under high contention."""
    kw = dict(n_threads=8, n_words=64, alpha=1.0, n_steps=12_000,
              max_ops=128)
    ours = _run(OURS, 3, **kw)
    orig = _run(ORIGINAL, 3, **kw)
    assert ours.per_op(CNT_CAS) < orig.per_op(CNT_CAS)
    assert ours.per_op(CNT_FLUSH) < orig.per_op(CNT_FLUSH)
    assert ours.throughput > orig.throughput
    assert orig.total(CNT_HELPS) > 0  # helping actually exercised


def test_original_helping_completes_foreign_ops():
    """Readers of the original algorithm help in-flight operations."""
    r = _run(ORIGINAL, 2, n_threads=8, n_words=32, alpha=1.0, n_steps=8000)
    assert r.total(CNT_HELPS) > 0
    got = r.payload_values("cache").astype(np.int64)
    assert np.array_equal(got, r.expected_histogram())


def test_determinism():
    """Same config => bit-identical results."""
    a = _run(OURS, 3)
    b = _run(OURS, 3)
    assert np.array_equal(a.state["pmem"], b.state["pmem"])
    assert np.array_equal(a.counters, b.counters)


def test_word_geometry_false_sharing():
    """Smaller blocks => words share cache lines => more invalidations
    (the Fig. 14 mechanism)."""
    kw = dict(n_threads=8, n_words=512, alpha=1.0, n_steps=10_000,
              max_ops=128)
    big = _run(OURS, 1, block_bytes=256, **kw)
    small = _run(OURS, 1, block_bytes=8, **kw)
    assert small.per_op(CNT_INVAL) > big.per_op(CNT_INVAL)

"""Epoch-based minimally-ordered durability (DESIGN.md Sec. 14).

The tentpole invariants:

- rounds inside an open epoch buffer WITHOUT persisting: the epoch-close
  fence is the ONE persist an epoch of rounds shares, and a crash in an
  open epoch loses at most ``epoch_rounds - 1`` committed-but-unsynced
  rounds — always a whole-epoch prefix, never a torn round;
- the dependency rule keeps ordering minimal: an incoming round touching
  a word an earlier buffered round wrote pays a fence FIRST
  (``dep_fences``), independent rounds pay nothing;
- epoch checkpoints fold the WAL into one image so replay length — and
  recovery time — is bounded by the checkpoint gap, not history length;
- the service withholds client acks behind open epochs, so the
  bounded-loss window is invisible to acked clients.
"""
import pytest

from repro import Committer, MarkerCommitter, PMemPool, SimulatedCrash
from repro.pmwcas import DurableBackend, MwCASOp
from repro.service import KVService
from repro.structures import INSERT, KVOp, OK


# ---------------------------------------------------------------------------
# committer: the epoch protocol itself
# ---------------------------------------------------------------------------

def test_epoch_buffers_rounds_under_one_fence(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool, epoch_rounds=4)
    p0 = pool.persist_count
    assert c.commit_round([("a", [("x", 0, 1)])], {"x": b"X1"}) == [True]
    assert c.commit_round([("b", [("y", 0, 1)])], {"y": b"Y1"}) == [True]
    assert c.commit_round([("c", [("z", 0, 1)])], {"z": b"Z1"}) == [True]
    # three committed rounds, zero persists: the epoch is still open
    assert pool.persist_count == p0
    assert c.epoch_pending == 3
    # commits are VISIBLE before they are durable (the bounded-loss
    # window): reads resolve through the buffered slot records
    assert (c.slot_version("x"), c.slot_version("y"),
            c.slot_version("z")) == (1, 1, 1)
    assert c.sync() == 3
    assert pool.persist_count - p0 == 1        # the one epoch-close fence
    assert c.epoch_pending == 0
    s = c.stats
    assert s.epochs_closed == 1 and s.fences == 1
    assert s.round_commits == 3 and s.ops_committed == 3
    # vs per-op: each 1-op round would have paid 3*1+2 = 5 persists
    assert s.flushes_saved == 3 * (5 - 1) + 2
    assert c.sync() == 0                        # idempotent when empty


def test_nth_round_closes_the_epoch(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool, epoch_rounds=2)
    p0 = pool.persist_count
    c.commit_round([("a", [("x", 0, 1)])], {"x": b"A"})
    assert pool.persist_count == p0 and c.epoch_pending == 1
    c.commit_round([("b", [("y", 0, 1)])], {"y": b"B"})
    # the epoch_rounds-th round triggers the scheduled close inline
    assert pool.persist_count - p0 == 1 and c.epoch_pending == 0
    assert c.stats.epochs_closed == 1


def test_open_epoch_crash_loses_bounded_prefix_never_torn(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool, epoch_rounds=4)
    c.commit_round([("a", [("x", 0, 1)])], {"x": b"X1"})
    c.sync()                                    # x=1 is durable
    c.commit_round([("b", [("x", 1, 2)])], {"x": b"X2"})
    c.commit_round([("c", [("y", 0, 1)])], {"y": b"Y1"})
    c2 = Committer(pool.crash(), epoch_rounds=4)
    c2.recover()
    # exactly the open epoch is gone (<= epoch_rounds-1 rounds), the
    # synced prefix survives whole — nothing torn, nothing reordered
    assert c2.slot_version("x") == 1 and c2.slot_version("y") == 0
    assert c2.pool.read("data/x.v1.bin") == b"X1"


def test_dependency_fence_orders_only_dependent_rounds(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool, epoch_rounds=8)
    p0 = pool.persist_count
    c.commit_round([("a", [("x", 0, 1)])], {"x": b"X1"})
    c.commit_round([("b", [("y", 0, 1)])], {"y": b"Y1"})
    assert pool.persist_count == p0             # independent: no fence
    # round advancing x AGAIN depends on the buffered write of x: the
    # minimal-ordering rule fences the earlier rounds first
    c.commit_round([("c", [("x", 1, 2)])], {"x": b"X2"})
    assert pool.persist_count - p0 == 1
    assert c.stats.dep_fences == 1 and c.stats.epochs_closed == 1
    assert c.epoch_pending == 1                 # round c buffers anew
    # crash: the fenced prefix (x=1, y=1) is durable, round c is lost
    c2 = Committer(pool.crash(), epoch_rounds=8)
    c2.recover()
    assert c2.slot_version("x") == 1 and c2.slot_version("y") == 1


def test_per_op_commit_pays_the_epoch_barrier(tmp_path):
    """Mixed mode: a per-op commit arriving with rounds buffered must
    sync first — its durable-at-return contract cannot order before
    rounds that committed earlier."""
    pool = PMemPool(tmp_path)
    c = Committer(pool, epoch_rounds=4)
    c.commit_round([("a", [("x", 0, 1)])], {"x": b"X1"})
    assert c.epoch_pending == 1
    assert c.commit("op1", [("y", 0, 1)], {"y": b"Y1"})
    assert c.epoch_pending == 0                 # barrier paid
    c2 = Committer(pool.crash(), epoch_rounds=4)
    c2.recover()
    assert c2.slot_version("x") == 1 and c2.slot_version("y") == 1


def test_checkpoint_bounds_wal_and_recovers_from_image(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool, epoch_rounds=2, checkpoint_every=2)
    for i in range(8):                     # independent words: 4 clean
        c.commit_round([(f"r{i}", [(f"w{i}", 0, 1)])],  # epochs -> 2 ckpts
                       {f"w{i}": f"V{i}".encode()})
    assert c.stats.checkpoints == 2 and c.stats.dep_fences == 0
    # covered records are durably gone; the image is the durable truth
    assert pool.listdir("wal") == []
    assert len(pool.listdir("ckpt")) == 1
    c2 = Committer(pool.crash(), epoch_rounds=2, checkpoint_every=2)
    rec = c2.recover()
    assert all(rec[f"w{i}"] == 1 for i in range(8))
    assert pool.read("data/w3.v1.bin") == b"V3"
    # post-recovery commits must not reuse sequence numbers the
    # checkpoint already covers (they would be dropped next recovery)
    c2.commit_round([("r9", [("w0", 1, 2)])], {"w0": b"V9"})
    c2.sync()
    c3 = Committer(c2.pool.crash(), epoch_rounds=2, checkpoint_every=2)
    assert c3.recover()["w0"] == 2


def test_epoch_replay_equals_per_round_replay(tmp_path):
    """Batched per-epoch replay recovers the exact state the classic
    per-round path recovers — the 10x replay win changes cost, not
    outcome."""
    script = [("a", "x", 0, 1, b"X1"), ("b", "y", 0, 1, b"Y1"),
              ("c", "x", 1, 2, b"X2"), ("d", "z", 0, 1, b"Z1"),
              ("e", "y", 1, 2, b"Y2")]
    recovered = {}
    for label, rounds in (("epoch", 4), ("classic", 1)):
        pool = PMemPool(tmp_path / label)
        c = Committer(pool, epoch_rounds=rounds)
        for rid, name, exp, des, payload in script:
            assert c.commit_round([(rid, [(name, exp, des)])],
                                  {name: payload}) == [True]
        c.sync()
        c2 = Committer(pool.crash(), epoch_rounds=rounds)
        recovered[label] = c2.recover()
        assert c2.pool.read("data/x.v2.bin") == b"X2"
        assert c2.pool.read("data/y.v2.bin") == b"Y2"
    assert recovered["epoch"] == recovered["classic"]


def test_epoch_crash_sweep_at_every_persist(tmp_path):
    """Crash at EVERY persist through closes, a checkpoint and a final
    sync: every recovered state is a whole-epoch prefix of the script
    (checkpoint persists change the encoding, never the state), and a
    second crash/recover is a fixpoint."""
    states = {0: (0, 0), 1: (1, 1), 2: (2, 2), 3: (3, 2)}

    def drive(c):
        # epoch 1: x->1, y->1; epoch 2: x->2, y->2 (+ checkpoint);
        # epoch 3 (explicit sync): x->3
        c.commit_round([("a", [("x", 0, 1)])], {"x": b"X1"})
        c.commit_round([("b", [("y", 0, 1)])], {"y": b"Y1"})
        yield 1
        c.commit_round([("c", [("x", 1, 2)])], {"x": b"X2"})
        c.commit_round([("d", [("y", 1, 2)])], {"y": b"Y2"})
        yield 2
        c.commit_round([("e", [("x", 2, 3)])], {"x": b"X3"})
        c.sync()
        yield 3

    crash_at, seen = 0, set()
    while True:
        pool = PMemPool(tmp_path / f"c{crash_at}",
                        crash_after_persists=crash_at)
        c = Committer(pool, epoch_rounds=2, checkpoint_every=2)
        reached, crashed = 0, False
        try:
            for reached in drive(c):
                pass
        except SimulatedCrash:
            crashed = True
        c2 = Committer(pool.crash(), epoch_rounds=2, checkpoint_every=2)
        c2.recover()
        got = (c2.slot_version("x"), c2.slot_version("y"))
        allowed = [states[k] for k in range(reached, 4)]
        assert got in allowed, (crash_at, got, allowed)
        seen.add(got)
        # current versions' payloads must exist whole
        for name, ver in zip("xy", got):
            if ver:
                assert c2.pool.read(f"data/{name}.v{ver}.bin") == \
                    f"{name.upper()}{ver}".encode()
        c3 = Committer(c2.pool.crash(), epoch_rounds=2,
                       checkpoint_every=2)
        c3.recover()
        assert (c3.slot_version("x"), c3.slot_version("y")) == got
        if not crashed:
            assert got == states[3]
            # the sweep exercised both loss outcomes
            assert states[0] in seen and states[3] in seen
            return
        crash_at += 1
        assert crash_at < 60, "sweep did not terminate"


def test_marker_committer_refuses_epochs(tmp_path):
    with pytest.raises(ValueError, match="epoch"):
        MarkerCommitter(PMemPool(tmp_path), epoch_rounds=2)
    with pytest.raises(ValueError, match="epoch"):
        MarkerCommitter(PMemPool(tmp_path), checkpoint_every=1)
    m = MarkerCommitter(PMemPool(tmp_path))
    assert m.epoch_pending == 0 and m.sync() == 0   # uniform surface


# ---------------------------------------------------------------------------
# backend surface
# ---------------------------------------------------------------------------

def test_backend_epoch_surface_and_crash_carryover(tmp_path):
    b = DurableBackend(pool=PMemPool(tmp_path), epoch_rounds=3,
                       checkpoint_every=2)
    (r,) = b.execute([MwCASOp([("0", 0, 1)])])
    assert r.success and b.epoch_pending == 1
    assert b.sync() == 1 and b.epoch_pending == 0
    rec = b.crash()
    # the epoch configuration survives crash/recover
    assert rec.epoch_rounds == 3 and rec.checkpoint_every == 2
    assert rec.read("0") == 1


def test_backend_epochs_require_group_commit(tmp_path):
    with pytest.raises(ValueError, match="group"):
        DurableBackend(pool=PMemPool(tmp_path), epoch_rounds=2,
                       group_commit=False)
    with pytest.raises(ValueError, match="epoch"):
        DurableBackend(pool=PMemPool(tmp_path), committer="marker",
                       epoch_rounds=2)


# ---------------------------------------------------------------------------
# service: acks held behind open epochs
# ---------------------------------------------------------------------------

def test_service_withholds_acks_until_epoch_close(tmp_path):
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=32, durable_root=tmp_path,
                    epoch_rounds=4, checkpoint_every=2)
    futs = [svc.submit(KVOp(INSERT, k, k * 10)) for k in range(1, 17)]
    svc.drain()
    assert all(f.done and f.status == OK for f in futs)
    assert svc.stats.acks_held > 0, "the ack gate never engaged"
    assert svc.stats.epoch_syncs >= 1, "drain never paid the barrier"
    d = svc.durability_stats()
    assert d.epochs_closed > 0 and d.flushes_saved > 0


def test_service_acked_ops_survive_crash_unacked_never_lie(tmp_path):
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=32, durable_root=tmp_path,
                    epoch_rounds=4, checkpoint_every=2)
    acked = [svc.submit(KVOp(INSERT, k, k * 10)) for k in range(1, 13)]
    svc.drain()
    assert all(f.done for f in acked)
    # a tail the service has NOT drained: decided-but-held acks may ride
    # an open epoch when the crash lands
    tail = [svc.submit(KVOp(INSERT, 100 + k, k)) for k in range(1, 7)]
    for _ in range(3):
        svc.step()
    rec = svc.crash()
    items = {}
    for struct in rec.structs:
        items.update(struct.items())
    # every ACKED op survived the crash
    for f in acked:
        assert items.get(f.op.key) == f.op.value, f.op.key
    for f in tail:
        if f.done and f.status == OK:
            assert items.get(f.op.key) == f.op.value, f.op.key
        else:
            # held acks die with the crash: the client got NO verdict,
            # so a lost round never contradicts an answer
            assert not f.done


def test_service_epoch_rounds_one_is_behavior_neutral(tmp_path):
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=32, durable_root=tmp_path)
    futs = [svc.submit(KVOp(INSERT, k, k)) for k in range(1, 9)]
    svc.drain()
    assert all(f.done and f.status == OK for f in futs)
    assert svc.stats.acks_held == 0 and svc.stats.epoch_syncs == 0
    assert "acks_held" not in svc.stats.as_row()

"""Elastic scale-out: hash-map directory doubling and online key-range
shard migration — one decide/materialize/swing protocol at two layers
(DESIGN.md Sec. 12), property-tested against a dict oracle, crash-swept
at every persist, and differentially verified across substrates.

The tree instance of the protocol (root splits) is covered in
``test_structures.py``; this file owns the map and service instances.
"""
import pytest

from repro.pmwcas import DurableBackend, KernelBackend
from repro.structures import (DELETE, EXHAUSTED, FULL, HashMap, INSERT,
                              KVOp, NOT_FOUND, OK,
                              READ, SCAN, UPDATE,
                              check_hashmap_resize_sweep,
                              run_struct_differential)
from repro.service import (KVService, ShardRouter,
                           check_migration_crash_sweep)
from repro import SimulatedCrash

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # deterministic fallback
    HAVE_HYPOTHESIS = False


def elastic_map(n_buckets=4, max_doublings=3, backend=None):
    backend = backend or KernelBackend(
        n_words=HashMap.words_needed(n_buckets, max_doublings),
        use_kernel=False)
    return HashMap(backend, n_buckets, max_doublings=max_doublings)


# ---------------------------------------------------------------------------
# directory doubling: layout and unit semantics
# ---------------------------------------------------------------------------

def test_words_needed_layouts():
    # legacy (max_doublings=0): exactly the historical 2n words, no header
    assert HashMap.words_needed(16) == 32
    assert HashMap.words_needed(16, 0, base=5) == 37
    # elastic: header (gen word + reserved) + every generation's array
    assert HashMap.words_needed(4, 1) == 2 + 2 * 4 * 3     # gens 0,1
    assert HashMap.words_needed(4, 2) == 2 + 2 * 4 * 7     # gens 0,1,2
    m = elastic_map(4, 2)
    assert m.hdr == 2 and m.cap(0) == 4 and m.cap(2) == 16
    legacy = HashMap(KernelBackend(n_words=8, use_kernel=False), 4)
    assert legacy.hdr == 0 and legacy.n_words == 8


def test_doubling_growth_is_unbounded_until_cap():
    """Inserting far past gen-0 capacity grows the directory through
    repeated doublings — no FULL until max_doublings is spent."""
    m = elastic_map(4, max_doublings=3)          # 4 -> 32 buckets
    keys = list(range(10, 290, 10))              # 28 keys >> 4 buckets
    res = m.apply([KVOp(INSERT, k, k + 1) for k in keys])
    assert all(r.status == OK for r in res), [r.status for r in res]
    assert m.gen >= 2 and m.resizes >= 2
    assert m.keys_migrated > 0
    assert m.check_integrity() == {k: k + 1 for k in keys}


def test_doubling_exhausts_to_full():
    m = elastic_map(2, max_doublings=1)          # 2 -> 4 buckets, then FULL
    res = m.apply([KVOp(INSERT, k, 1) for k in range(10, 80, 10)])
    statuses = [r.status for r in res]
    assert statuses.count(OK) == 4               # final capacity
    assert statuses.count(FULL) == 3
    assert m.gen == 1 and not m.migrating


def test_split_brain_ops_during_migration():
    """Client ops proceed while the doubling is in flight: lookups see
    both generations, mutations carry the generation guard."""
    m = elastic_map(4, max_doublings=2)
    m.apply([KVOp(INSERT, k, k) for k in (11, 22, 33, 44)])
    assert m.begin_resize()
    assert m.migrating
    res = m.apply([KVOp(INSERT, 55, 5), KVOp(UPDATE, 22, 220),
                   KVOp(READ, 33), KVOp(DELETE, 44)])
    assert [r.status for r in res] == [OK, OK, OK, OK]
    assert res[2].value == 33
    # finalize and verify: the union survived the swing
    for _ in range(16):
        if not m.migrating:
            break
        m.resize_step()
    assert not m.migrating and m.gen == 1
    assert m.check_integrity() == {11: 11, 22: 220, 33: 33, 55: 5}


def test_doubling_survives_crash_mid_pump(tmp_path):
    """Crash between pump rounds: recovery replays the WAL, the gen
    word still carries MIG_BIT, and a fresh attach completes the
    doubling."""
    backend = DurableBackend(tmp_path / "d")
    m = HashMap(backend, 4, max_doublings=2)
    m.apply([KVOp(INSERT, k, k) for k in (11, 22, 33, 44)])
    assert m.begin_resize()
    m.resize_step(max_moves=1)                   # partial pump
    before = m.items()
    m2 = HashMap(backend.crash(), 4, max_doublings=2)
    assert m2.migrating                          # decision survived
    assert m2.check_integrity() == before
    assert m2.ensure_room(max_steps=16)
    assert m2.gen == 1 and m2.check_integrity() == before


def test_resize_crash_sweep(tmp_path):
    """Tentpole acceptance: crash at EVERY persist through a workload
    that drives gen 0 -> 1 -> 2 (decide, pump moves, guarded
    split-brain ops, finalize swing)."""
    kvops = [KVOp(INSERT, k, k * 3) for k in range(7, 90, 7)]
    kvops += [KVOp(UPDATE, 14, 999), KVOp(DELETE, 21)]
    swept = check_hashmap_resize_sweep(kvops, 3, tmp_path,
                                       max_doublings=2, batch=3)
    assert swept > 10


# ---------------------------------------------------------------------------
# directory doubling: property tests vs a dict oracle
# ---------------------------------------------------------------------------

def _oracle_apply(model, op):
    """Sequential dict semantics, returning the expected status."""
    if op.kind == INSERT:
        if op.key in model:
            return "exists"
        model[op.key] = op.value
        return OK
    if op.kind == UPDATE:
        if op.key not in model:
            return NOT_FOUND
        model[op.key] = op.value
        return OK
    if op.kind == DELETE:
        if op.key not in model:
            return NOT_FOUND
        del model[op.key]
        return OK
    if op.kind == READ:
        return OK if op.key in model else NOT_FOUND
    return OK                                     # SCAN never fails


def _check_against_oracle(plan):
    """Run (kind, key, value, resize?) steps on an elastic map and a
    dict; statuses and final items must agree, and the map's invariants
    must hold mid- and post-growth.  FULL is only legal once the
    doubling budget is spent AND the final generation truly has no slot
    left; with the headroom sized here it must not happen."""
    m = elastic_map(4, max_doublings=3)          # headroom: 32 buckets
    model = {}
    for kind, key, value, pump in plan:
        if pump and m.gen < 3 and not m.migrating:
            assert m.begin_resize()              # adversarial mid-op growth
        op = KVOp(kind, key, value if kind in (INSERT, UPDATE) else 0)
        (r,) = m.apply([op])
        expect = _oracle_apply(model, op)
        assert r.status == expect, (kind, key, r.status, expect)
        if kind == READ and r.status == OK:
            assert r.value == model[key]
    if m.migrating:
        assert m.ensure_room(max_steps=64)
    assert m.check_integrity() == model


def _plan_from_rng(rng, n_steps=40):
    kinds = [INSERT, UPDATE, DELETE, READ]
    plan = []
    for _ in range(n_steps):
        kind = kinds[int(rng.integers(4))]
        key = int(rng.integers(1, 25))
        value = int(rng.integers(1, 1 << 16))
        plan.append((kind, key, value, bool(rng.random() < 0.1)))
    return plan


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from([INSERT, UPDATE, DELETE, READ]),
                  st.integers(1, 24), st.integers(1, 1 << 16),
                  st.booleans()),
        min_size=1, max_size=40))
    def test_doubling_matches_dict_oracle(plan):
        _check_against_oracle(plan)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_doubling_matches_dict_oracle(seed):
        """Deterministic stand-in for the hypothesis property (the
        dependency is optional): seeded random interleavings of client
        ops and adversarial mid-workload resizes vs a dict oracle."""
        import numpy as np
        _check_against_oracle(_plan_from_rng(
            np.random.default_rng(seed)))


def test_guarded_retry_never_loses_an_update():
    """The generation guard makes mutations conditional on the doubling
    epoch; a losing guard must RETRY (next round), never drop the op —
    pumping the resize between every single-op apply maximizes guard
    traffic."""
    m = elastic_map(4, max_doublings=2)
    m.apply([KVOp(INSERT, k, 1) for k in range(1, 9)])   # forces growth
    assert m.gen >= 1 or m.migrating
    for k in range(1, 9):
        (r,) = m.apply([KVOp(UPDATE, k, k * 7)])
        assert r.status == OK
    if m.migrating:
        m.ensure_room(max_steps=64)
    assert m.check_integrity() == {k: k * 7 for k in range(1, 9)}


# ---------------------------------------------------------------------------
# directory doubling: cross-substrate differential
# ---------------------------------------------------------------------------

def test_elastic_differential_growth_rounds_zero_skips(tmp_path):
    """Growth rounds — generation CAS, 4-word pump moves, guarded
    split-brain ops — run in kernel/durable lockstep and shadow-verify
    on the simulator with ZERO expressibility skips: at most one
    gen-guarded mutation compiles per round, so conservative and
    winner-blocking verdicts provably coincide."""
    kvops = [KVOp(INSERT, k, k * 2) for k in range(5, 100, 5)]
    kvops += [KVOp(UPDATE, 25, 7), KVOp(DELETE, 30), KVOp(READ, 25)]
    rep = run_struct_differential(kvops, n_buckets=4, max_doublings=3,
                                  durable_root=tmp_path / "diff")
    assert rep.agree, rep.summary()
    assert rep.sim_rounds_skipped == 0, rep.summary()
    assert rep.sim_rounds_checked > 5
    # growth really happened: more live keys than gen-0 capacity
    assert len(rep.items["kernel"]) > 4


# ---------------------------------------------------------------------------
# online key-range shard migration (service layer)
# ---------------------------------------------------------------------------

def _loaded_service(root, n_shards=3, n_buckets=32, chunk=4, **kw):
    svc = KVService(n_shards, backend="durable", n_buckets=n_buckets,
                    durable_root=root, migration_chunk=chunk, **kw)
    keys = {k: k * 10 for k in range(100, 200, 3)}
    res = svc.apply([KVOp(INSERT, k, v) for k, v in sorted(keys.items())])
    assert all(r.status == OK for r in res)
    return svc, keys


def test_migration_moves_range_and_survives_crash(tmp_path):
    svc, keys = _loaded_service(tmp_path / "m")
    before = svc.check_integrity()
    svc.migrate_range(100, 160, 2)
    assert svc.router.ranges == [(100, 160, 2)]
    assert svc.check_integrity() == before       # items are invariant
    assert svc.stats.migrations == 1 and svc.stats.keys_moved > 0
    assert svc.stats.mig_pause_waves and svc.stats.mig_pause_waves[0] >= 1
    for k in range(100, 160, 3):
        assert svc.router.shard_of_key(k) == 2
        assert svc.lookup(k) == keys[k]
    # the swing is durable: route table + record survive a crash
    svc2 = svc.crash()
    assert svc2.router.ranges == [(100, 160, 2)]
    assert svc2.check_integrity() == before


def test_migration_holds_and_releases_inflight_writes(tmp_path):
    """Writes covering the range (and all scans) park until the swing,
    then re-route and land on the destination — the copy can never
    diverge from a racing client write."""
    svc, keys = _loaded_service(tmp_path / "h")
    svc.start_migration(100, 160, 2)
    fut = svc.submit(KVOp(UPDATE, 103, 4242))    # in-range: held
    scan = svc.submit(KVOp(SCAN, 1))             # scans hold too
    out = svc.submit(KVOp(READ, 199))            # out of range: proceeds
    assert svc.pending_count == 3
    for _ in range(200):
        if fut.done and scan.done:
            break
        svc.step()
    assert fut.status == OK and scan.status == OK and out.status == OK
    assert scan.result.value == len(keys)        # no double-counted copy
    assert svc.lookup(103) == 4242
    assert svc.router.shard_of_key(103) == 2
    assert svc.check_integrity()[103] == 4242


def test_migration_crash_mid_copy_is_invisible(tmp_path):
    """A crash while the copy is in flight rolls the migration back:
    no route change, no residue, the MIGRATING record aborted."""
    svc, keys = _loaded_service(tmp_path / "c", chunk=2)
    before = svc.check_integrity()
    svc.start_migration(100, 160, 2)
    svc.step(); svc.step()                       # partial copy
    assert svc._migrations
    svc2 = svc.crash()
    assert svc2.router.ranges == []
    assert svc2.check_integrity() == before
    assert svc2.mig_log.pending() == []
    assert not svc2._migrations


def test_migration_crash_mid_swing_rolls_forward(tmp_path):
    """Once the ROUTED record persists, a crash anywhere in the rest of
    the swing recovers to the COMPLETED migration."""
    svc, keys = _loaded_service(tmp_path / "s")
    before = svc.check_integrity()
    # trap the decision log right after the ROUTED persist (decide is
    # persist 1 relative to now, mark_routed is persist 2)
    svc.mig_pool.crash_after = svc.mig_pool.persist_count + 2
    with pytest.raises(SimulatedCrash):
        svc.migrate_range(100, 160, 2)
    svc2 = svc.crash()
    assert svc2.router.ranges == [(100, 160, 2)]
    assert svc2.check_integrity() == before
    assert svc2.mig_log.pending() == []
    for k in range(100, 160, 3):
        assert svc2.lookup(k) == keys[k]


def test_migration_crash_sweep(tmp_path):
    """Tentpole acceptance: a crash trap on every pool (each shard WAL
    + the decision log) at every persist ordinal leaves the migration
    invisible or completed — never a torn route or a lost key."""
    load = {k: k * 10 for k in range(100, 150, 3)}
    swept = check_migration_crash_sweep(
        load, tmp_path, lo=100, hi=130, dst=2,
        n_shards=3, n_buckets=16, migration_chunk=3)
    assert swept >= 8


def test_remigration_trims_older_route_overrides(tmp_path):
    """A later migration may re-migrate part of an earlier one's range;
    the newest override must win and the older row is trimmed."""
    svc, keys = _loaded_service(tmp_path / "t")
    before = svc.check_integrity()
    svc.migrate_range(100, 160, 2)
    svc.migrate_range(130, 180, 0)
    assert svc.router.ranges == [(100, 130, 2), (130, 180, 0)]
    assert svc.check_integrity() == before
    for k, v in keys.items():
        assert svc.lookup(k) == v
    svc2 = svc.crash()                           # both swings durable
    assert svc2.router.ranges == [(100, 130, 2), (130, 180, 0)]
    assert svc2.check_integrity() == before


def test_migration_guards():
    r = ShardRouter(3, words_per_shard=64)
    r.set_range(10, 20, 1)
    r.set_range(15, 30, 2)                       # trims the first row
    assert r.ranges == [(10, 15, 1), (15, 30, 2)]
    assert r.shard_of_key(12) == 1 and r.shard_of_key(17) == 2
    r.clear_range(12, 18)                        # partial clear trims both
    assert r.ranges == [(10, 12, 1), (18, 30, 2)]
    with pytest.raises(ValueError):
        r.set_range(5, 5, 0)                     # empty range
    with pytest.raises(ValueError):
        r.set_range(0, 5, 9)                     # shard out of range


def test_migration_requires_decision_log_on_durable_shards(tmp_path):
    """Durable shards without a decision log would lose the route table
    on crash while keeping the moved keys — refused loudly."""
    backends = [DurableBackend(tmp_path / f"b{s}") for s in range(2)]
    svc = KVService(2, backend=backends, n_buckets=16)
    assert svc.mig_log is None
    with pytest.raises(ValueError, match="decision log"):
        svc.start_migration(1, 10, 0)


def test_overlapping_inflight_migration_rejected(tmp_path):
    svc, _ = _loaded_service(tmp_path / "o")
    svc.start_migration(100, 160, 2)
    with pytest.raises(RuntimeError, match="overlaps"):
        svc.start_migration(150, 170, 0)
    svc.drain()                                  # finish the first one


# ---------------------------------------------------------------------------
# acceptance: elastic service absorbs 4x its initial capacity
# ---------------------------------------------------------------------------

def test_service_absorbs_4x_initial_capacity(tmp_path):
    """The headline acceptance: a durable sharded service with elastic
    shards absorbs 4x its initial aggregate capacity with ZERO
    EXHAUSTED/FULL — every shard doubles its directory as it fills."""
    n_shards, n_buckets = 2, 8
    svc = KVService(n_shards, backend="durable", n_buckets=n_buckets,
                    max_doublings=4, durable_root=tmp_path / "x")
    n_keys = 4 * n_shards * n_buckets            # 64 keys vs 16 buckets
    res = svc.apply([KVOp(INSERT, k, k + 7)
                     for k in range(1, n_keys + 1)])
    statuses = [r.status for r in res]
    assert statuses.count(FULL) == 0 and statuses.count(EXHAUSTED) == 0
    assert all(s == OK for s in statuses)
    assert svc.check_integrity() == {k: k + 7
                                     for k in range(1, n_keys + 1)}
    assert all(st.gen >= 1 for st in svc.structs), \
        "every shard must have grown"
    # and the grown state is durable
    svc2 = svc.crash()
    assert svc2.check_integrity() == {k: k + 7
                                      for k in range(1, n_keys + 1)}

"""The unified repro.pmwcas API: operation model validation, the fluent
SimSession builder, backend adapters, and the cross-backend differential
check — one MwCASOp batch through sim, kernel and durable backends must
yield identical per-op verdicts and final values."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dependency
    HAVE_HYPOTHESIS = False

from repro.pmwcas import (DurableBackend, KernelBackend, MwCASOp, OpResult,
                          ORIGINAL, OURS, OURS_DF, PCAS, SimBackend,
                          SimConfig, SimSession, Target, UnsupportedBatch,
                          batch_width, increment_batch, ops_from_arrays,
                          ops_to_arrays, resolve, results_from_mask,
                          run_differential)


# ---------------------------------------------------------------------------
# operation model
# ---------------------------------------------------------------------------

def test_mwcas_op_validation():
    with pytest.raises(ValueError):
        MwCASOp([])                                    # no targets
    with pytest.raises(ValueError):
        MwCASOp([(0, 1, 2), (0, 3, 4)])                # duplicate address
    with pytest.raises(ValueError):
        Target(-1, 0, 1)                               # negative address
    op = MwCASOp([(3, 1, 2), (1, 5, 9)])
    assert op.sorted().addrs == (1, 3)
    assert not op.is_increment()                       # 5 -> 9 is a jump
    assert MwCASOp.increment([1, 3], [5, 1]).is_increment()


def test_ops_array_roundtrip():
    ops = [MwCASOp([(0, 1, 2), (4, 5, 6)]), MwCASOp([(2, 3, 4)])]
    addr, exp, des = ops_to_arrays(ops)
    assert addr.shape == (2, 2) and addr[1, 1] == -1   # padded
    back = ops_from_arrays(addr, exp, des)
    assert back == ops


def _check_array_roundtrip(seed: int):
    """Property: ops_to_arrays / ops_from_arrays / results_from_mask are
    mutually consistent for random batches with mixed widths, arbitrary
    (unsorted) addresses, uint32-extreme values and -1 padding."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 12))
    W = 64
    ops = []
    for _ in range(B):
        k = int(rng.integers(1, 5))
        addrs = rng.choice(W, k, replace=False)        # arbitrary order
        exp = rng.integers(0, 1 << 32, k, dtype=np.uint64)
        des = rng.integers(0, 1 << 32, k, dtype=np.uint64)
        ops.append(MwCASOp([(int(a), int(e), int(d))
                            for a, e, d in zip(addrs, exp, des)]))
    K = batch_width(ops)
    assert K == max(op.k for op in ops)
    addr, exp, des = ops_to_arrays(ops)
    assert addr.shape == (B, K) and addr.dtype == np.int32
    assert exp.dtype == np.uint32 and des.dtype == np.uint32
    # padding exactly where an op runs out of targets, values zeroed
    for i, op in enumerate(ops):
        assert (addr[i, op.k:] == -1).all()
        assert (exp[i, op.k:] == 0).all() and (des[i, op.k:] == 0).all()
        # target order is preserved (the descriptor's embedding order)
        assert [int(a) for a in addr[i, :op.k]] == list(op.addrs)
    assert ops_from_arrays(addr, exp, des) == ops      # inverse modulo pad
    # widening the batch only adds padding
    addr2, exp2, des2 = ops_to_arrays(ops, k=K + 2)
    assert (addr2[:, K:] == -1).all()
    assert ops_from_arrays(addr2, exp2, des2) == ops
    # results_from_mask pairs verdicts with ops positionally
    mask = rng.random(B) < 0.5
    res = results_from_mask(ops, mask, "test")
    assert [r.success for r in res] == mask.tolist()
    assert [r.index for r in res] == list(range(B))
    assert all(r.op is ops[i] and r.backend == "test"
               for i, r in enumerate(res))
    # int -> w<addr> durable-slot mapping (one batch, every backend)
    for op in ops:
        for t in op.targets:
            assert t.slot_name == f"w{t.addr}"


# Deterministic fallback sweep: always runs, hypothesis or not.
@pytest.mark.parametrize("seed", range(8))
def test_array_roundtrip_deterministic(seed):
    _check_array_roundtrip(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_array_roundtrip(seed):
        _check_array_roundtrip(seed)
else:
    def test_array_roundtrip():
        pytest.importorskip("hypothesis")  # records skip: optional dep absent


def test_ops_to_arrays_rejects_bad_batches():
    with pytest.raises(ValueError):
        ops_to_arrays([])                              # empty batch
    with pytest.raises(ValueError):                    # op wider than K
        ops_to_arrays([MwCASOp([(0, 1, 2), (1, 1, 2)])], k=1)
    with pytest.raises(TypeError):                     # str addr has no index
        ops_to_arrays([MwCASOp([("slot", 1, 2)])])


def test_int_addr_maps_to_durable_slot(tmp_path):
    """The w<addr> mapping is what lets one int-addressed batch run on
    the durable backend: seeding word 3 and reading slot 'w3' agree."""
    db = DurableBackend(tmp_path)
    db.seed({3: 7})
    assert db.read(3) == 7 and db.read("w3") == 7
    (res,) = db.execute([MwCASOp([(3, 7, 8)])])
    assert res.success and db.read("w3") == 8


def test_algorithm_strategies():
    assert resolve("ours") is OURS
    assert resolve(OURS_DF) is OURS_DF
    with pytest.raises(ValueError):
        resolve("nope")
    assert OURS.cas_per_op(3) == 6 and ORIGINAL.cas_per_op(3) == 12
    assert PCAS.max_k == 1 and not PCAS.supports_k(2)
    assert str(OURS) == "ours"                         # legacy-string bridge
    # flush formula matches the engine (desc_lines=2 at k=3, see
    # SimConfig.desc_lines and quickstart's measured 9/12 flushes per op)
    assert OURS.flush_per_op(3, desc_lines=2) == 9
    assert OURS_DF.flush_per_op(3, desc_lines=2) == 12
    assert ORIGINAL.flush_per_op(3) is None
    assert PCAS.flush_per_op(1) == 1


# ---------------------------------------------------------------------------
# SimSession builder
# ---------------------------------------------------------------------------

def test_session_is_immutable_and_forkable():
    base = SimSession().with_algorithm(OURS).with_words(128).with_k(2)
    a = base.with_threads(2)
    b = base.with_threads(4)
    assert base.cfg.n_threads == SimConfig().n_threads
    assert (a.cfg.n_threads, b.cfg.n_threads) == (2, 4)
    assert a.cfg.n_words == b.cfg.n_words == 128
    assert a.algorithm is OURS


def test_session_runs_and_matches_legacy_path():
    from repro.pmwcas import run_sim
    s = (SimSession().with_algorithm(OURS).with_threads(2).with_words(64)
         .with_k(2).with_steps(1500).with_max_ops(32).with_seed(9))
    r1 = s.run()
    r2 = run_sim(s.cfg)                  # legacy entry point, same config
    assert r1.ops_completed == r2.ops_completed
    assert np.array_equal(r1.counters, r2.counters)


def test_session_with_schedule_crash():
    s = (SimSession().with_algorithm(OURS_DF).with_threads(4).with_words(32)
         .with_k(2).with_steps(600).with_max_ops(16))
    rec, hist = s.crash_at(211)
    assert rec.shape == (32,)
    assert (rec & 0b111 == 0).all()


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def test_kernel_backend_carries_state():
    kb = KernelBackend(values=[5, 5, 5])
    r1 = kb.execute([MwCASOp([(0, 5, 7)])])
    assert r1[0].success and kb.read(0) == 7
    # second batch sees the first batch's writes
    r2 = kb.execute([MwCASOp([(0, 5, 9)])])
    assert not r2[0].success and kb.read(0) == 7


def test_sim_backend_rejects_inexpressible_batches():
    sb = SimBackend(8, algorithm=OURS, values=[1] * 8)
    with pytest.raises(UnsupportedBatch):
        sb.execute([MwCASOp([(0, 9, 10)])])            # stale expected
    with pytest.raises(UnsupportedBatch):
        sb.execute([MwCASOp([(3, 1, 2), (1, 1, 2)])])  # unsorted addrs
    with pytest.raises(UnsupportedBatch):              # PCAS is single-word
        SimBackend(8, algorithm=PCAS, values=[1] * 8).execute(
            [MwCASOp([(0, 1, 2), (1, 1, 2)])])
    with pytest.raises(UnsupportedBatch):              # PCAS machine is v+1
        SimBackend(8, algorithm=PCAS, values=[1] * 8).execute(
            [MwCASOp([(0, 1, 5)])])


def test_sim_backend_native_desired_values():
    """Value jumps, TOMBSTONE-sized payloads, guard words and mixed
    widths all run natively on the micro-op machines (no shadow words) —
    the structure rounds' vocabulary."""
    tomb = (1 << 32) - 1
    sb = SimBackend(8, algorithm=OURS, values=[5, 5, 0, 0, 0, 0, 0, 0])
    (r,) = sb.execute([MwCASOp([(0, 5, 9), (1, 5, tomb)])])   # jump + tomb
    assert r.success and sb.read(0) == 9 and sb.read(1) == tomb
    # guard word (desired == expected) participates but moves nothing
    (g,) = sb.execute([MwCASOp([(0, 9, 9), (2, 0, 3)])])
    assert g.success and sb.read(0) == 9 and sb.read(2) == 3
    # mixed widths in one batch; conflict on a shared address still loses
    res = sb.execute([MwCASOp([(3, 0, 7), (4, 0, 8), (5, 0, 2)]),
                      MwCASOp([(6, 0, 4)]),
                      MwCASOp([(4, 0, 1)])])
    assert [x.success for x in res] == [True, True, False]
    assert sb.values()[3:7].tolist() == [7, 8, 2, 4]


def test_sim_backend_counts_real_work():
    sb = SimBackend(8, algorithm=OURS, values=[0] * 8)
    res = sb.execute([MwCASOp.increment([0, 1], [0, 0]),
                      MwCASOp.increment([2, 3], [0, 0])])
    assert all(r.success for r in res)
    assert sb.counters is not None and sb.counters.sum() > 0
    assert sb.values()[:4].tolist() == [1, 1, 1, 1]


def test_durable_backend_is_actually_durable(tmp_path):
    db = DurableBackend(tmp_path)
    db.seed({0: 3, 1: 4})
    res = db.execute([MwCASOp([(0, 3, 4), (1, 4, 5)])])
    assert res[0].success
    # survive a crash: unpersisted state dropped, committed state recovered
    db2 = db.crash()
    assert db2.read(0) == 4 and db2.read(1) == 5


def test_guard_word_ops_agree_kernel_vs_durable(tmp_path):
    """A desired == expected target is a guard word: it participates in
    the verdict (expected check + address claim) but moves nothing.
    Kernel and durable backends must agree on such ops."""
    op = MwCASOp([(0, 3, 3), (1, 4, 5)])        # word 0 guards, word 1 moves
    kb = KernelBackend(values=[3, 4])
    db = DurableBackend(tmp_path)
    db.seed({0: 3, 1: 4})
    (rk,) = kb.execute([op])
    (rd,) = db.execute([op])
    assert rk.success and rd.success
    assert kb.read(0) == 3 and kb.read(1) == 5
    assert db.read(0) == 3 and db.read(1) == 5
    # guard word with a WRONG expectation fails everywhere
    op2 = MwCASOp([(0, 99, 99), (1, 5, 6)])
    (rk2,) = kb.execute([op2])
    (rd2,) = db.execute([op2])
    assert not rk2.success and not rd2.success
    assert kb.read(1) == 5 and db.read(1) == 5


def test_op_result_truthiness():
    op = MwCASOp([(0, 1, 2)])
    assert OpResult(0, True, "kernel", op)
    assert not OpResult(0, False, "kernel", op)


# ---------------------------------------------------------------------------
# the acceptance differential: sim == kernel == durable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg,k", [(OURS, 3), (OURS_DF, 2), (ORIGINAL, 2),
                                   (PCAS, 1)])
def test_cross_backend_differential(tmp_path, alg, k):
    """One MwCASOp batch through SimBackend, KernelBackend and
    DurableBackend: identical per-op success verdicts and final values."""
    initial, ops = increment_batch(n_words=24, k=k, n_ops=8,
                                   seed=100 + k)
    assert len(ops) >= 4 and any(True for _ in ops)
    rep = run_differential(ops, initial, algorithm=alg,
                           durable_root=tmp_path / alg.name,
                           use_kernel=True)
    assert rep.agree, rep.summary()
    # the batch must actually exercise both outcomes
    v = rep.verdicts["kernel"]
    assert v.any() and (~v).any(), "degenerate batch: craft better conflicts"


def test_differential_uses_all_three_backends(tmp_path):
    initial, ops = increment_batch(n_words=16, k=2, n_ops=6, seed=42)
    rep = run_differential(ops, initial, durable_root=tmp_path)
    assert set(rep.verdicts) == {"sim", "kernel", "durable"}
    assert set(rep.values) == {"sim", "kernel", "durable"}

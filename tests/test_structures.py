"""The structures layer: lock-free persistent data structures built only
on the public ``repro.pmwcas`` surface, exercised on the kernel and
durable backends, shadow-verified on the simulator, and crash-swept on
both persistent substrates."""
import dataclasses

import numpy as np
import pytest

from repro.pmwcas import (DurableBackend, KernelBackend, MwCASOp,
                          ops_from_arrays, zipf_probs)
from repro.structures import (BzTreeIndex, DELETE, EXISTS, FULL,
                              FreeListAllocator, DoubleFree, HashMap,
                              INNER_BIT, INSERT,
                              KVOp, LEAF_DEAD, LeafNode, NODE_FROZEN,
                              NODE_FULL, NODE_OK, NOT_FOUND, OK,
                              OutOfRegions, READ, SCAN,
                              SortedNode, SplitError, TOMBSTONE, TornStructure,
                              UPDATE, WorkloadSpec, YCSB_A, YCSB_B, YCSB_C,
                              YCSB_E, check_durable_crash_sweep,
                              check_sim_crash_sweep, check_tree_crash_sweep,
                              compile_workload, conservative_verdicts,
                              kernel_round_arrays, load_phase, read_pointer,
                              run_struct_differential, run_workload,
                              shadow_batch, swap_pointer,
                              winner_blocking_verdicts)


def oracle_map(n_buckets=16, n_words=None, **kw):
    return HashMap(KernelBackend(n_words=n_words or 2 * n_buckets,
                                 use_kernel=False, **kw), n_buckets)


def oracle_tree(leaf_cap=4, root_cap=4, n_regions=6, **kw):
    n = BzTreeIndex.words_needed(leaf_cap, root_cap, n_regions)
    return BzTreeIndex(KernelBackend(n_words=n, use_kernel=False, **kw),
                       leaf_cap=leaf_cap, root_cap=root_cap,
                       n_regions=n_regions)


# ---------------------------------------------------------------------------
# operation model
# ---------------------------------------------------------------------------

def test_kvop_validation():
    with pytest.raises(ValueError):
        KVOp("bump", 1)                        # unknown kind
    with pytest.raises(ValueError):
        KVOp(INSERT, 0, 1)                     # key 0 is the EMPTY word
    with pytest.raises(ValueError):
        KVOp(INSERT, TOMBSTONE, 1)             # key collides with tombstone
    with pytest.raises(ValueError):
        KVOp(INSERT, 5, 0)                     # value 0 means "no value"
    KVOp(READ, 5)                              # reads need no value


# ---------------------------------------------------------------------------
# hash map: sequential semantics
# ---------------------------------------------------------------------------

def test_hashmap_insert_read_update_delete():
    h = oracle_map()
    assert all(h.apply([KVOp(INSERT, 5, 100), KVOp(INSERT, 7, 200)]))
    (r,) = h.apply([KVOp(READ, 5)])
    assert r.status == OK and r.value == 100
    (r,) = h.apply([KVOp(UPDATE, 5, 111)])
    assert r.status == OK and h.lookup(5) == 111
    (r,) = h.apply([KVOp(DELETE, 7)])
    assert r.status == OK
    (r,) = h.apply([KVOp(READ, 7)])
    assert r.status == NOT_FOUND and r.value is None
    assert h.check_integrity() == {5: 111}


def test_hashmap_miss_paths():
    h = oracle_map()
    assert h.apply([KVOp(UPDATE, 9, 1)])[0].status == NOT_FOUND
    assert h.apply([KVOp(DELETE, 9)])[0].status == NOT_FOUND
    assert all(h.apply([KVOp(INSERT, 9, 1)]))
    assert h.apply([KVOp(INSERT, 9, 2)])[0].status == EXISTS
    assert h.lookup(9) == 1                    # losing insert changed nothing


def test_hashmap_full_and_tombstone_reuse():
    h = oracle_map(n_buckets=4)
    keys = [3, 7, 11, 15]
    assert all(h.apply([KVOp(INSERT, k, k) for k in keys]))
    assert h.apply([KVOp(INSERT, 99, 1)])[0].status == FULL
    # delete one -> its tombstone is reused by the next insert
    assert all(h.apply([KVOp(DELETE, 7)]))
    assert all(h.apply([KVOp(INSERT, 99, 42)]))
    assert h.check_integrity() == {3: 3, 11: 11, 15: 15, 99: 42}
    # probe chains survive the tombstone: every key still findable
    for k in (3, 11, 15):
        assert h.lookup(k) == k


def test_hashmap_one_mwcas_per_mutation():
    """The tentpole claim: insert/update/delete compile to exactly one
    2-word MwCASOp over the bucket's (key word, value word) pair."""
    h = oracle_map()
    snap = h.snapshot()
    op = h.compile_op(KVOp(INSERT, 5, 100), snap)
    assert isinstance(op, MwCASOp) and op.k == 2
    (kw, vw) = op.addrs
    assert vw == kw + 1 and kw % 2 == 0        # adjacent pair, sorted
    h.apply([KVOp(INSERT, 5, 100)])
    snap = h.snapshot()
    upd = h.compile_op(KVOp(UPDATE, 5, 7), snap)
    assert upd.k == 2 and upd.targets[0].expected == upd.targets[0].desired
    dele = h.compile_op(KVOp(DELETE, 5), snap)
    assert dele.k == 2 and dele.targets[0].desired == TOMBSTONE
    assert dele.targets[1].desired == 0


# ---------------------------------------------------------------------------
# hash map: concurrent batches (the one-shot semantics)
# ---------------------------------------------------------------------------

def test_hashmap_concurrent_duplicate_insert():
    h = oracle_map()
    res = h.apply([KVOp(INSERT, 5, 100), KVOp(INSERT, 5, 300)])
    assert [r.status for r in res] == [OK, EXISTS]
    assert h.lookup(5) == 100                  # lower index won


def test_hashmap_concurrent_update_vs_delete():
    """Update guards the key word, delete moves it: the two ops conflict
    on both words, so exactly one commits per round — never a value
    written into a dead bucket."""
    for first, second in [(KVOp(UPDATE, 5, 9), KVOp(DELETE, 5)),
                          (KVOp(DELETE, 5), KVOp(UPDATE, 5, 9))]:
        h = oracle_map()
        h.apply([KVOp(INSERT, 5, 1)])
        res = h.apply([first, second])
        # lower index wins round 1; the loser recompiles: after a delete
        # the update misses, after an update the delete still applies
        assert res[0].status == OK
        assert res[1].status == (NOT_FOUND if first.kind == DELETE else OK)
        h.check_integrity()
        if first.kind == DELETE:
            assert h.lookup(5) is None
        else:
            assert h.lookup(5) is None         # update then delete


def test_hashmap_conflict_rounds_make_progress():
    """Keys forced into one probe neighborhood: every round commits at
    least one op (lowest index passes (a) and wins), so a batch of N
    finishes in <= N rounds."""
    h = oracle_map(n_buckets=4)
    keys = [3, 7, 11, 15]                      # all compete for 4 buckets
    res = h.apply([KVOp(INSERT, k, k) for k in keys])
    assert all(r.status == OK for r in res)
    assert h.rounds_run <= len(keys)
    assert h.check_integrity() == {k: k for k in keys}


def test_hashmap_reads_see_pre_batch_snapshot():
    """Ops inside one apply() are concurrent: a READ linearizes at the
    round snapshot and cannot observe a same-batch INSERT."""
    h = oracle_map()
    res = h.apply([KVOp(INSERT, 5, 100), KVOp(READ, 5)])
    assert res[0].status == OK and res[1].status == NOT_FOUND
    (r,) = h.apply([KVOp(READ, 5)])            # next batch sees it
    assert r.value == 100


def test_hashmap_scan_counts_live_keys():
    h = oracle_map()
    h.apply([KVOp(INSERT, k, k) for k in (2, 4, 6)])
    (r,) = h.apply([KVOp(SCAN, 4)])
    assert r.status == OK and r.value == 2     # keys >= 4: {4, 6}


def test_torn_structure_detected():
    """check_integrity flags a key word without its value word (a state
    no MwCAS history can produce — the detector the crash sweeps rely
    on)."""
    kb = KernelBackend(n_words=8, use_kernel=False)
    h = HashMap(kb, 4)
    (res,) = kb.execute([MwCASOp([(h.key_addr(1), 0, 77)])])   # torn write
    assert res.success
    with pytest.raises(TornStructure):
        h.check_integrity()


def test_hashmap_on_real_pallas_kernel():
    """One batch through the actual Pallas kernel path (interpret mode)."""
    h = HashMap(KernelBackend(n_words=16, use_kernel=True), 8)
    res = h.apply([KVOp(INSERT, 3, 30), KVOp(INSERT, 5, 50)])
    assert all(r.status == OK for r in res)
    assert h.check_integrity() == {3: 30, 5: 50}


# ---------------------------------------------------------------------------
# hash map: durability
# ---------------------------------------------------------------------------

def test_hashmap_durable_crash_recover_attach(tmp_path):
    db = DurableBackend(tmp_path)
    h = HashMap(db, 8)
    assert all(h.apply([KVOp(INSERT, 5, 100), KVOp(INSERT, 7, 200)]))
    assert all(h.apply([KVOp(UPDATE, 5, 111)]))
    h2 = HashMap(db.crash(), 8)                # fresh map over recovery
    assert h2.check_integrity() == {5: 111, 7: 200}


def test_hashmap_durable_crash_at_every_persist(tmp_path):
    """Acceptance: sweep the crash point across every persist of a whole
    insert/update/delete workload — recovery never shows a torn bucket
    pair or loses a committed effect."""
    ops = [KVOp(INSERT, 5, 100), KVOp(INSERT, 7, 200), KVOp(UPDATE, 5, 111),
           KVOp(DELETE, 7), KVOp(INSERT, 9, 300)]
    n = check_durable_crash_sweep(ops, n_buckets=8, root=tmp_path / "perop",
                                  group_commit=False)
    assert n > 20                              # the sweep covered the protocol
    # the coalesced path: one fence per op-round, so the clean run needs
    # far fewer persists — and every one of them is still swept
    g = check_durable_crash_sweep(ops, n_buckets=8, root=tmp_path / "group")
    assert 0 < g < n


# ---------------------------------------------------------------------------
# simulator shadow: crash sweep + verdict semantics
# ---------------------------------------------------------------------------

def test_sim_shadow_crash_sweep():
    """Acceptance: structure rounds shadowed into the cycle-accurate
    simulator survive micro-op-granularity crashes with per-op
    atomicity (driven through SimSession.crash_at)."""
    h = oracle_map(n_buckets=8)
    snap = h.snapshot()
    batch = [h.compile_op(KVOp(INSERT, k, 10 * k), snap)
             for k in (3, 5, 9, 12)]
    assert all(isinstance(op, MwCASOp) for op in batch)
    checked = check_sim_crash_sweep(batch, n_steps=1200)
    assert checked >= 10


def test_verdict_semantics_helpers():
    ops = [MwCASOp([(0, 0, 1), (1, 0, 1)]),    # wins
           MwCASOp([(1, 0, 1), (2, 0, 1)]),    # blocked by winner 0
           MwCASOp([(2, 0, 1), (3, 0, 1)])]    # chained: semantics split
    cons = conservative_verdicts(ops)
    wb = winner_blocking_verdicts(ops)
    assert cons.tolist() == [True, False, False]
    assert wb.tolist() == [True, False, True]


# ---------------------------------------------------------------------------
# the structure differential (acceptance)
# ---------------------------------------------------------------------------

def test_struct_differential_workload(tmp_path):
    """A conflict-bearing logical workload agrees across kernel and
    durable backends, and every shadow-expressible round's verdicts
    match the cycle-accurate simulator."""
    ops = ([KVOp(INSERT, k, 10 * k) for k in (3, 7, 11, 15)]     # same chain
           + [KVOp(INSERT, 3, 5), KVOp(INSERT, 21, 9)])
    rep = run_struct_differential(ops, n_buckets=4,
                                  durable_root=tmp_path)
    assert rep.agree, rep.summary()
    assert rep.sim_rounds_checked >= 1
    assert rep.statuses["kernel"].count(OK) == 4
    assert FULL in rep.statuses["kernel"]      # 5th distinct key can't fit


def test_struct_differential_mixed_mutations(tmp_path):
    ops = [KVOp(INSERT, 5, 100), KVOp(INSERT, 13, 200),
           KVOp(UPDATE, 5, 111), KVOp(DELETE, 13), KVOp(INSERT, 5, 1)]
    rep = run_struct_differential(ops, n_buckets=8,
                                  durable_root=tmp_path)
    assert rep.agree, rep.summary()
    assert rep.items["kernel"] == rep.items["durable"]


def test_struct_differential_native_sim_no_shadow_skips(tmp_path):
    """The sim replays REAL rounds natively — actual desired payloads
    (wide values, TOMBSTONE deletes) and mixed op kinds in one round —
    so no round is skipped for expressibility.  The only legitimate
    skip is a genuine semantic divergence (winner-blocking !=
    conservative verdicts), which this conflict-light workload avoids."""
    ops = ([KVOp(INSERT, k, (k << 8) | 1) for k in (2, 6, 10, 14)]
           + [KVOp(UPDATE, 2, 123456), KVOp(DELETE, 6),
              KVOp(INSERT, 18, 7), KVOp(DELETE, 10), KVOp(INSERT, 6, 999)])
    rep = run_struct_differential(ops, n_buckets=8, durable_root=tmp_path)
    assert rep.agree, rep.summary()
    assert rep.sim_rounds_checked >= 3
    assert rep.sim_rounds_skipped == 0, \
        "native replay must not skip rounds for expressibility"


def test_tree_differential_native_sim_mixed_width_rounds(tmp_path):
    """BzTree rounds mix op widths (meta-word CAS vs slot+meta inserts);
    the native replay pads each op privately and still verifies every
    round (no winner-blocking divergence in this workload)."""
    spec = WorkloadSpec(n_ops=16, n_keys=10, read=0.1, update=0.3,
                        insert=0.4, delete=0.2, seed=5, batch=4)
    ops = load_phase(spec) + compile_workload(spec)
    rep = run_struct_differential(ops, structure="bztree", leaf_cap=2,
                                  root_cap=8, n_regions=10,
                                  durable_root=tmp_path)
    assert rep.agree, rep.summary()
    assert rep.sim_rounds_checked >= 3
    assert rep.sim_rounds_skipped == 0
    assert rep.items["kernel"] == rep.items["durable"]


# ---------------------------------------------------------------------------
# BzTree-style sorted node
# ---------------------------------------------------------------------------

def test_node_insert_and_sorted_view():
    kb = KernelBackend(n_words=32, use_kernel=False)
    node = SortedNode(kb, base=2, capacity=6)
    for k in (42, 7, 19):
        assert node.insert(k) == NODE_OK
    assert node.raw_slots() == [42, 7, 19]     # arrival order on medium
    assert node.keys() == [7, 19, 42]          # sorted on read
    assert node.search(19) and not node.search(20)
    assert node.insert(7) == "exists"


def test_node_concurrent_inserts_serialize():
    """All pending inserts target the same (meta, slot) pair each round:
    exactly one winner per round, everyone lands eventually."""
    kb = KernelBackend(n_words=32, use_kernel=False)
    node = SortedNode(kb, base=0, capacity=6)
    sts = node.insert_batch([5, 9, 3, 7])
    assert sts == [NODE_OK] * 4
    assert node.keys() == [3, 5, 7, 9]
    assert node.count == 4


def test_node_full_freeze_split():
    kb = KernelBackend(n_words=64, use_kernel=False)
    node = SortedNode(kb, base=0, capacity=4)
    assert node.insert_batch([10, 30, 20, 40]) == [NODE_OK] * 4
    assert node.insert(50) == NODE_FULL
    left, right, sep = node.split(10, 20)      # fresh zeroed regions
    assert node.frozen and node.insert(60) == NODE_FROZEN
    assert left.keys() == [10, 20] and right.keys() == [30, 40]
    assert sep == 30
    assert not left.frozen and left.insert(15) == NODE_OK
    # atomic pointer install: readers swing from old to new in one CAS
    ptr = 50
    assert swap_pointer(kb, ptr, 0, left.base)
    assert read_pointer(kb, ptr) == left.base
    assert not swap_pointer(kb, ptr, 0, right.base)   # stale expected


def test_node_split_needs_zeroed_region():
    kb = KernelBackend(n_words=64, use_kernel=False)
    node = SortedNode(kb, base=0, capacity=4)
    node.insert_batch([1, 2, 3, 4])
    kb.execute([MwCASOp([(21, 0, 99)])])       # dirty word in right region
    with pytest.raises(SplitError):
        node.split(10, 20)


def test_node_on_durable_backend(tmp_path):
    db = DurableBackend(tmp_path)
    node = SortedNode(db, base=0, capacity=4)
    assert node.insert_batch([42, 7, 19, 23]) == [NODE_OK] * 4
    left, right, sep = node.split(10, 20)
    assert (left.keys(), right.keys(), sep) == ([7, 19], [23, 42], 23)
    # the split (one wide MwCAS) survives a crash as a unit
    db2 = db.crash()
    l2 = SortedNode(db2, 10, 4)
    r2 = SortedNode(db2, 20, 4)
    assert l2.keys() == [7, 19] and r2.keys() == [23, 42]
    assert SortedNode(db2, 0, 4).frozen        # original stays frozen


# ---------------------------------------------------------------------------
# free-list allocator
# ---------------------------------------------------------------------------

def test_freelist_alloc_free_roundtrip():
    fl = FreeListAllocator(16, region_base=100, region_words=8)
    grants = fl.alloc([2, 3, 0])
    assert grants[2] == [] and len(grants[0]) == 2 and len(grants[1]) == 3
    assert fl.n_free == 11
    assert fl.region(grants[0][0]) == 100 + grants[0][0] * 8
    fl.free(grants[1])
    assert fl.n_free == 14
    with pytest.raises(DoubleFree):
        fl.free(grants[1])                     # already back on the list


def test_freelist_exhaustion_is_typed():
    fl = FreeListAllocator(4)
    with pytest.raises(OutOfRegions) as exc:   # supply for one, not both
        fl.alloc([3, 3])
    # the exception names the starved request and keeps the grants the
    # same call already claimed (the caller owns them)
    assert exc.value.requests == (1,)
    served = [g for g in exc.value.grants if g is not None]
    assert len(served) == 1 and len(served[0]) == 3 and fl.n_free == 1
    # legacy mode: a None grant instead of the typed error
    fl2 = FreeListAllocator(4)
    grants = fl2.alloc([3, 3], on_exhausted="none")
    assert grants[0] is not None and grants[1] is None
    # raw contended reservations: lower batch index wins atomically
    fl3 = FreeListAllocator(8)
    ok = fl3.reserve([[0, 1], [1, 2], [3, 4]])
    assert ok == [True, False, True]
    assert fl3.n_free == 4                     # loser claimed nothing


# ---------------------------------------------------------------------------
# workload compiler
# ---------------------------------------------------------------------------

def test_workload_compile_deterministic_and_mixed():
    spec = WorkloadSpec(n_ops=200, n_keys=32, read=0.4, update=0.3,
                        insert=0.2, delete=0.1, seed=7)
    ops1, ops2 = compile_workload(spec), compile_workload(spec)
    assert ops1 == ops2                        # seeded determinism
    kinds = {op.kind for op in ops1}
    assert kinds == {READ, UPDATE, INSERT, DELETE}
    assert all(1 <= op.key <= spec.n_keys for op in ops1)


def test_workload_zipf_skew_concentrates_keys():
    uniform = compile_workload(WorkloadSpec(n_ops=400, n_keys=64, seed=1))
    skewed = compile_workload(WorkloadSpec(n_ops=400, n_keys=64, seed=1,
                                           alpha=1.2))
    def top_share(ops):
        _, counts = np.unique([op.key for op in ops], return_counts=True)
        return np.sort(counts)[-4:].sum() / len(ops)
    assert top_share(skewed) > top_share(uniform) + 0.1


def test_workload_invalid_mix_rejected():
    with pytest.raises(ValueError):
        WorkloadSpec(read=0.9, update=0.9, insert=0, delete=0, scan=0)


def test_workload_end_to_end_with_stats():
    spec = WorkloadSpec(n_ops=48, n_keys=16, seed=3, batch=8, alpha=0.9)
    h = oracle_map(n_buckets=32)
    h.apply(load_phase(spec))
    stats = run_workload(h, spec)
    assert stats.n_ops == 48
    assert sum(stats.by_status.values()) == 48
    assert stats.by_status.get(OK, 0) > 0
    assert stats.mwcas_won <= stats.mwcas_submitted
    h.check_integrity()


def test_kernel_round_arrays_wire_form():
    """The structure layer hands the Pallas kernel its native
    int32[B,K]-with-(-1)-padding wire format."""
    h = oracle_map(n_buckets=8)
    ops = [KVOp(INSERT, 3, 30), KVOp(INSERT, 5, 50), KVOp(READ, 3)]
    addr, exp, des, mwcas = kernel_round_arrays(h, ops)
    assert addr.shape == (2, 2)                # the READ compiles to no CAS
    assert addr.dtype == np.int32 and (addr >= 0).all()
    assert (des[:, 1] == [30, 50]).all()       # value words carried


# ---------------------------------------------------------------------------
# workload compiler: edge cases
# ---------------------------------------------------------------------------

def test_zipf_alpha_zero_is_uniform():
    p = zipf_probs(16, 0.0)
    assert p.shape == (16,)
    assert np.allclose(p, 1 / 16) and np.isclose(p.sum(), 1.0)


def test_zipf_single_key_universe():
    assert np.allclose(zipf_probs(1, 0.0), [1.0])
    assert np.allclose(zipf_probs(1, 1.2), [1.0])


def test_workload_single_key_universe_runs():
    """n_keys=1 degenerates every rank to the same key; the compiler and
    the retry loop must both survive it (alpha irrelevant)."""
    spec = WorkloadSpec(n_ops=12, n_keys=1, read=0.25, update=0.25,
                        insert=0.25, delete=0.25, seed=3, batch=4)
    ops = compile_workload(spec)
    assert {op.key for op in ops} == {1}
    h = oracle_map(n_buckets=2)
    stats = run_workload(h, spec, ops=ops)
    assert sum(stats.by_status.values()) == 12
    h.check_integrity()


def test_scan_heavy_mix_round_trips_kernel_arrays():
    """A YCSB-E (scan-heavy) round still produces a faithful kernel wire
    form: scans compile to no CAS, the inserts round-trip exactly
    through ops_to_arrays/ops_from_arrays."""
    spec = dataclasses.replace(YCSB_E, n_ops=32, n_keys=8, seed=5)
    ops = compile_workload(spec)
    kinds = {op.kind for op in ops}
    assert SCAN in kinds and INSERT in kinds
    tree = oracle_tree()
    addr, exp, des, mwcas = kernel_round_arrays(tree, ops)
    assert addr.shape[0] == len(mwcas) < len(ops)   # scans dropped
    assert all(op.k == 3 for op in mwcas)           # tree inserts: 3-word
    assert [op.targets for op in ops_from_arrays(addr, exp, des)] == \
        [op.targets for op in mwcas]


def test_shadow_batch_pads_mixed_widths():
    """Tree rounds mix 2- and 3-word ops; the shadow pads every op to
    one uniform width with private fresh words, leaving the conflict
    graph (and hence the verdicts) unchanged."""
    ops = [MwCASOp([(10, 0, 1), (11, 0, 2), (12, 0, 3)]),   # 3-word
           MwCASOp([(10, 0, 0), (13, 5, 6)]),               # shares 10
           MwCASOp([(14, 1, 2)])]                           # independent
    n, shadow = shadow_batch(ops)
    assert {op.k for op in shadow} == {3}                   # uniform now
    assert all(op.is_increment() for op in shadow)
    assert all(list(op.addrs) == sorted(op.addrs) for op in shadow)
    assert n == 5 + 3                                       # 5 real + 3 pad
    cons = conservative_verdicts(shadow)
    assert cons.tolist() == conservative_verdicts(ops).tolist()
    assert winner_blocking_verdicts(shadow).tolist() == \
        winner_blocking_verdicts(ops).tolist()


# ---------------------------------------------------------------------------
# multi-node BzTree index (the tentpole)
# ---------------------------------------------------------------------------

def test_tree_insert_read_update_delete():
    t = oracle_tree()
    assert all(t.apply([KVOp(INSERT, 5, 100), KVOp(INSERT, 7, 200)]))
    (r,) = t.apply([KVOp(READ, 5)])
    assert r.status == OK and r.value == 100
    assert t.apply([KVOp(INSERT, 5, 1)])[0].status == EXISTS
    (r,) = t.apply([KVOp(UPDATE, 5, 111)])
    assert r.status == OK and t.lookup(5) == 111
    (r,) = t.apply([KVOp(DELETE, 7)])
    assert r.status == OK
    assert t.apply([KVOp(READ, 7)])[0].status == NOT_FOUND
    assert t.apply([KVOp(UPDATE, 7, 1)])[0].status == NOT_FOUND
    assert t.check_integrity() == {5: 111}


def test_tree_insert_is_three_words_update_two():
    """The leaf op shapes: insert = (meta bump, key slot, value slot) in
    ONE 3-word MwCAS; update/delete = (meta guard, value word)."""
    t = oracle_tree()
    snap = t.snapshot()
    ins = t.compile_op(KVOp(INSERT, 5, 100), snap)
    assert isinstance(ins, MwCASOp) and ins.k == 3
    assert ins.targets[0].desired == ins.targets[0].expected + 1
    t.apply([KVOp(INSERT, 5, 100)])
    snap = t.snapshot()
    upd = t.compile_op(KVOp(UPDATE, 5, 7), snap)
    dele = t.compile_op(KVOp(DELETE, 5), snap)
    assert upd.k == 2 and upd.targets[0].expected == upd.targets[0].desired
    assert dele.k == 2 and dele.targets[1].desired == LEAF_DEAD


def test_tree_split_preserves_items_and_routing():
    t = oracle_tree(leaf_cap=4, root_cap=4, n_regions=6)
    keys = (50, 20, 80, 10, 60, 30, 70, 40, 90)
    res = t.apply([KVOp(INSERT, k, k) for k in keys])
    assert all(r.status == OK for r in res)
    assert t.splits >= 1 and t.root_count() >= 1
    assert t.check_integrity() == {k: k for k in keys}
    assert len(t.leaf_bases()) == t.root_count() + 1
    # every key routes to the leaf that holds it, and reads agree
    for k in keys:
        assert t.lookup(k) == k
    (r,) = t.apply([KVOp(SCAN, 50)])
    assert r.value == len([k for k in keys if k >= 50])


def test_tree_split_is_exactly_two_mwcas_rounds():
    """Split propagation = the wide materialize op, then the 2-word
    swing — with only the 1-word freeze in front (DESIGN §7/§12).  The
    FIRST split is a ROOT split: the wide op carries both half images,
    the new 1-entry root image and the pending word."""
    t = oracle_tree(leaf_cap=2, root_cap=4, n_regions=4)
    t.apply([KVOp(INSERT, 5, 50), KVOp(INSERT, 3, 30)])
    executed = []
    real_execute = t.backend.execute
    t.backend.execute = lambda ops: (executed.append(list(ops)),
                                     real_execute(ops))[1]
    (r,) = t.apply([KVOp(INSERT, 9, 90)])      # forces the root split
    assert r.status == OK and t.splits == 1 and t.root_splits == 1
    widths = [[op.k for op in batch] for batch in executed]
    # freeze (1-word), round 1 (ONE wide op: both 1-key half images of
    # meta+key+value, the 4-word new root image, the pending word),
    # round 2 (the 2-word super/pending swing), then the retried
    # insert (3-word)
    assert widths == [[1], [2 * 3 + 4 + 1], [2], [3]]


def test_tree_nonroot_split_is_exactly_two_mwcas_rounds():
    """Once an inner root exists, a leaf split is the original DESIGN §7
    protocol: wide materialize + invisible parent pre-entry, then the
    2-word count-bump/pointer-swing install."""
    t = oracle_tree(leaf_cap=2, root_cap=4, n_regions=4)
    t.apply([KVOp(INSERT, k, 10 * k) for k in (5, 3, 9)])   # root split
    assert t.root_count() == 1
    executed = []
    real_execute = t.backend.execute
    t.backend.execute = lambda ops: (executed.append(list(ops)),
                                     real_execute(ops))[1]
    (r,) = t.apply([KVOp(INSERT, 8, 80)])      # splits the right leaf
    assert r.status == OK and t.splits == 2 and t.root_splits == 1
    widths = [[op.k for op in batch] for batch in executed]
    # freeze, wide op (two 1-key half images + 2-word pre-entry),
    # 2-word install, retried insert
    assert widths == [[1], [2 * 3 + 2], [2], [3]]


def test_tree_pre_entry_invisible_until_install():
    """Round 1 pre-publishes the parent entry beyond the count: readers
    (and the integrity checker) still see the pre-split tree; the 2-word
    install is the linearization point."""
    t = oracle_tree(leaf_cap=2, root_cap=4, n_regions=6)
    t.apply([KVOp(INSERT, k, 10 * k) for k in (5, 3, 9)])   # inner root
    root = t.root_base()
    n = t.root_count()
    assert n == 1
    before = t.check_integrity()
    leaf = LeafNode(t.backend, t.leaf_bases()[1], 2)   # the full [5, 9] leaf
    (grant,) = t.allocator.alloc([1])
    pair = t.allocator.region(grant[0])
    sep = leaf.keys()[1]
    leaf.split(pair, pair + t.leaf_words,
               extra_targets=[(t.sep_addr(n), 0, sep),
                              (t.child_addr(n), 0, pair + t.leaf_words)])
    assert t.root_count() == n                 # entry not visible
    assert t.check_integrity() == before       # pre-split tree intact
    assert t._install(root, n, sep, pair + t.leaf_words)
    assert t.root_count() == n + 1             # now fully linked
    assert t.check_integrity() == before
    assert t.leaf_bases()[1:] == [pair, pair + t.leaf_words]


def test_tree_completes_pending_split_after_crash(tmp_path):
    """Crash between round 1 and the install leaves a frozen leaf and an
    invisible pre-entry; the next mutation completes the split from
    persisted state alone (left half derived from the pair region)."""
    db = DurableBackend(tmp_path)
    kw = dict(leaf_cap=2, root_cap=4, n_regions=6)
    t = BzTreeIndex(db, **kw)
    t.apply([KVOp(INSERT, k, 10 * k) for k in (5, 3, 9)])   # inner root
    n = t.root_count()
    leaf = LeafNode(db, t.leaf_bases()[1], 2)  # the full [5, 9] leaf
    (grant,) = t.allocator.alloc([1])
    pair = t.allocator.region(grant[0])
    sep = leaf.keys()[1]
    leaf.split(pair, pair + t.leaf_words,
               extra_targets=[(t.sep_addr(n), 0, sep),
                              (t.child_addr(n), 0, pair + t.leaf_words)])
    before = t.check_integrity()
    t2 = BzTreeIndex(db.crash(), **kw)         # attach over recovery
    assert t2.check_integrity() == before
    (r,) = t2.apply([KVOp(INSERT, 7, 70)])     # lands on the frozen leaf
    assert r.status == OK
    assert t2.root_count() == n + 1
    assert t2.check_integrity() == {**before, 7: 70}


def test_tree_completes_pending_root_split_after_crash(tmp_path):
    """Crash between root-split round 1 and the super swing leaves the
    pending word pointing at a fully materialized new root while super
    still routes to the frozen old root; the next mutation completes
    the swing from the pending word alone."""
    db = DurableBackend(tmp_path)
    kw = dict(leaf_cap=2, root_cap=4, n_regions=4)
    t = BzTreeIndex(db, **kw)
    t.apply([KVOp(INSERT, 5, 50), KVOp(INSERT, 3, 30)])
    # perform ROOT-SPLIT ROUND 1 by hand: both halves + new root image
    # + pending word in one wide MwCAS, then "crash" before the swing
    leaf = LeafNode(db, t.root_base(), 2)
    (grant,) = t.allocator.alloc([1])
    region = t.allocator.region(grant[0])
    left, right = region, region + t.leaf_words
    sep = leaf.keys()[1]
    new_root = region + 2 * t.leaf_words
    leaf.split(left, right, extra_targets=[
        (new_root, 0, 1 | INNER_BIT), (new_root + 1, 0, left),
        (new_root + 2, 0, sep), (new_root + 3, 0, right),
        (t.pending_addr, 0, new_root)])
    t2 = BzTreeIndex(db.crash(), **kw)         # attach over recovery
    assert t2.root_base() == t.root_base()     # swing not yet visible
    assert int(t2.backend.read(t2.pending_addr)) == new_root
    assert t2.check_integrity() == {3: 30, 5: 50}
    (r,) = t2.apply([KVOp(INSERT, 9, 90)])     # completes the swing
    assert r.status == OK
    assert t2.root_base() == new_root and t2.root_count() == 1
    assert int(t2.backend.read(t2.pending_addr)) == 0
    assert t2.check_integrity() == {3: 30, 5: 50, 9: 90}


def test_tree_delete_revive_and_consolidation():
    t = oracle_tree(leaf_cap=2, root_cap=2, n_regions=5)
    t.apply([KVOp(INSERT, 5, 50), KVOp(INSERT, 3, 30)])
    t.apply([KVOp(DELETE, 5)])
    # re-insert of a dead key revives the slot in place (no count bump)
    (r,) = t.apply([KVOp(INSERT, 5, 55)])
    assert r.status == OK and t.check_integrity() == {3: 30, 5: 55}
    assert len(t.leaf_bases()) == 1            # no split happened
    # a full leaf with < 2 live keys consolidates instead of splitting
    t.apply([KVOp(DELETE, 5), KVOp(DELETE, 3)])
    (r,) = t.apply([KVOp(INSERT, 7, 70)])
    assert r.status == OK
    assert t.consolidations == 1 and t.splits == 0
    assert t.check_integrity() == {7: 70}


def test_tree_region_exhaustion_does_not_wedge_leaf():
    """Regression: a failed split for lack of regions must not leave the
    leaf frozen — updates/deletes of its live keys keep working."""
    t = oracle_tree(leaf_cap=2, root_cap=4, n_regions=1)   # bootstrap
    t.apply([KVOp(INSERT, 5, 50), KVOp(INSERT, 3, 30)])    # eats region 0
    (r,) = t.apply([KVOp(INSERT, 9, 90)])
    assert r.status == FULL                    # nowhere to split into
    (r,) = t.apply([KVOp(UPDATE, 5, 55)])      # live keys stay mutable
    assert r.status == OK and t.lookup(5) == 55
    (r,) = t.apply([KVOp(DELETE, 3)])
    assert r.status == OK
    assert t.check_integrity() == {5: 55}


def test_tree_region_gc_reclaims_frozen_originals():
    """Split originals keep their regions claimed forever without GC;
    ``gc_regions`` frees every region no routing state references and
    the tree can grow again.  ``ensure_room`` now runs a GC pass
    itself before reporting OutOfRegions, so growth rides through
    region exhaustion without caller intervention."""
    t = oracle_tree(leaf_cap=2, root_cap=8, n_regions=3)
    # region 0: bootstrap leaf; the root split eats region 1, freezing
    # the original in region 0; the next leaf split eats region 2 —
    # after that every further split must reclaim residue via auto-GC
    res = t.apply([KVOp(INSERT, k, k) for k in (10, 20, 30, 40)])
    assert all(r.status == OK for r in res) and t.splits >= 1
    before = t.check_integrity()
    assert t.allocator.n_free == 0
    # no region left: the next split succeeds anyway because
    # ensure_room GCs the frozen originals first
    (r,) = t.apply([KVOp(INSERT, 50, 50)])
    assert r.status == OK
    assert t.check_integrity() == {**before, 50: 50}
    freed = t.gc_regions()
    assert freed >= 0                          # idempotent / re-runnable
    assert t.check_integrity() == {**before, 50: 50}


def test_tree_region_gc_protects_pending_split(tmp_path):
    """A crash between split rounds leaves a half-materialized pair
    referenced only by the INVISIBLE pre-entry; GC must keep it (the
    next mutation completes the split from exactly that state)."""
    kw = dict(leaf_cap=2, root_cap=4, n_regions=4)
    from repro import PMemPool, SimulatedCrash
    # find a crash point that lands between root-split round 1 and the
    # super swing: pending word set, super still on the frozen old
    # root.  The per-op protocol keeps the persist granularity this
    # hunt was calibrated for (group commit collapses it to one fence
    # per round)
    for crash_at in range(6, 200):
        pool = PMemPool(tmp_path / f"c{crash_at}",
                        crash_after_persists=crash_at)
        t = BzTreeIndex(DurableBackend(pool=pool, group_commit=False),
                        **kw)
        try:
            t.apply([KVOp(INSERT, 5, 50), KVOp(INSERT, 3, 30),
                     KVOp(INSERT, 9, 90)])
        except SimulatedCrash:
            t2 = BzTreeIndex(DurableBackend(pool=pool.crash(),
                                            group_commit=False), **kw)
            if t2.root_count() == 0 and \
                    int(t2.backend.read(t2.pending_addr)):
                break
    else:
        pytest.skip("no crash point hit the inter-round window")
    pending = t2.backend.read(t2.pending_addr)
    t2.gc_regions()
    # the pending new root (and its halves, sharing the region)
    # survived GC and the split still completes
    assert t2.backend.read(t2.pending_addr) == pending
    res = t2.apply([KVOp(INSERT, 7, 70)])
    assert res[0].status == OK
    items = t2.check_integrity()
    assert items[7] == 70 and t2.root_count() >= 1


def test_tree_gc_on_durable_crash_recover(tmp_path):
    kw = dict(leaf_cap=2, root_cap=4, n_regions=4)
    db = DurableBackend(tmp_path)
    t = BzTreeIndex(db, **kw)
    t.apply([KVOp(INSERT, k, k) for k in (5, 3, 9, 7)])
    assert t.splits >= 1
    before = t.check_integrity()
    db2 = db.crash()
    t2 = BzTreeIndex(db2, **kw)                # attach reclaims residue
    freed = t2.gc_regions()
    assert freed >= 1
    assert t2.check_integrity() == before
    # GC is durable: another crash/recover sees the same tree and the
    # same free regions
    t3 = BzTreeIndex(db2.crash(), **kw)
    assert t3.check_integrity() == before
    assert t3.allocator.n_free >= freed


def test_tree_root_split_unbounds_growth():
    """root_cap no longer caps the tree: a full inner root splits and
    the tree grows a level (the elastic scale-out tentpole).  FULL now
    only means region exhaustion."""
    t = oracle_tree(leaf_cap=2, root_cap=2, n_regions=32)
    keys = list(range(10, 130, 10))
    res = t.apply([KVOp(INSERT, k, k) for k in keys])
    # 2x the old hard ceiling (root_cap+1 leaves * leaf_cap = 6 keys)
    assert all(r.status == OK for r in res)
    assert t.root_splits >= 2 and t.height() >= 3
    assert t.check_integrity() == {k: k for k in keys}
    for k in keys:
        assert t.lookup(k) == k


def test_tree_on_real_pallas_kernel():
    """One splitting workload through the actual Pallas kernel path."""
    n = BzTreeIndex.words_needed(2, 4, 4)
    t = BzTreeIndex(KernelBackend(n_words=n, use_kernel=True),
                    leaf_cap=2, root_cap=4, n_regions=4)
    res = t.apply([KVOp(INSERT, k, 10 * k) for k in (5, 3, 9)])
    assert all(r.status == OK for r in res) and t.splits == 1
    assert t.check_integrity() == {5: 50, 3: 30, 9: 90}


def test_tree_durable_crash_recover_attach(tmp_path):
    kw = dict(leaf_cap=2, root_cap=4, n_regions=4)
    db = DurableBackend(tmp_path)
    t = BzTreeIndex(db, **kw)
    assert all(t.apply([KVOp(INSERT, k, k) for k in (5, 3, 9, 7)]))
    assert t.splits >= 1
    before = t.check_integrity()
    t2 = BzTreeIndex(db.crash(), **kw)
    assert t2.check_integrity() == before == {3: 3, 5: 5, 7: 7, 9: 9}


def test_tree_crash_sweep_through_split(tmp_path):
    """Acceptance: crash at EVERY persist point of a workload that
    drives a leaf split — recovery always shows the pre-split or the
    fully-linked post-split tree, never a torn one."""
    ops = [KVOp(INSERT, 5, 50), KVOp(INSERT, 3, 30), KVOp(INSERT, 9, 90),
           KVOp(UPDATE, 5, 55), KVOp(DELETE, 3)]
    n = check_tree_crash_sweep(ops, tmp_path / "perop", leaf_cap=2,
                               root_cap=4, n_regions=4, group_commit=False)
    assert n > 40                              # the sweep crossed the split
    g = check_tree_crash_sweep(ops, tmp_path / "group", leaf_cap=2,
                               root_cap=4, n_regions=4)
    assert 0 < g < n                           # coalesced path: fewer fences


def test_tree_sim_shadow_crash_sweep():
    """A compiled tree round (mixed widths) shadows into the
    cycle-accurate simulator crash sweep via the padded shadow batch."""
    t = oracle_tree(leaf_cap=4, root_cap=4, n_regions=6)
    t.apply([KVOp(INSERT, k, k) for k in (10, 20, 30)])
    snap = t.snapshot()
    batch = [t.compile_op(op, snap)
             for op in [KVOp(INSERT, 40, 4), KVOp(UPDATE, 10, 1),
                        KVOp(DELETE, 20)]]
    assert {op.k for op in batch} == {2, 3}    # genuinely mixed widths
    _, shadow = shadow_batch(batch)
    checked = check_sim_crash_sweep(shadow, n_steps=1500)
    assert checked >= 10


@pytest.mark.parametrize("mix", [YCSB_A, YCSB_B, YCSB_C, YCSB_E])
def test_tree_ycsb_differential(tmp_path, mix):
    """Acceptance: YCSB A/B/C plus the scan mix run against BzTreeIndex
    on kernel AND durable backends in lockstep, every client round
    shadow-verified on the simulator."""
    spec = dataclasses.replace(mix, n_ops=20, n_keys=10, seed=13, batch=4)
    ops = load_phase(spec) + compile_workload(spec)
    rep = run_struct_differential(ops, structure="bztree", leaf_cap=2,
                                  root_cap=8, n_regions=10,
                                  durable_root=tmp_path)
    assert rep.agree, rep.summary()
    assert rep.sim_rounds_checked >= 1
    assert rep.items["kernel"] == rep.items["durable"]


def test_tree_ycsb_workload_stats():
    """The generalized run_workload drives the tree end to end and the
    split counters surface in the stats vocabulary."""
    spec = WorkloadSpec(n_ops=48, n_keys=24, read=0.3, update=0.3,
                        insert=0.3, delete=0.05, scan=0.05, seed=7,
                        batch=8, alpha=0.9)
    t = oracle_tree(leaf_cap=4, root_cap=8, n_regions=10)
    t.apply(load_phase(spec))
    stats = run_workload(t, spec)
    assert stats.n_ops == 48 == sum(stats.by_status.values())
    assert stats.by_status.get(OK, 0) > 0
    assert t.splits >= 1
    t.check_integrity()

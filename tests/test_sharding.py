"""Sharding rules: every (arch x mesh) assignment must be divisible and
well-formed — no compile needed, so this covers all 10 archs cheaply."""

import numpy as np
import pytest

# build tiny fake meshes out of the single CPU device via AbstractMesh
import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.launch.steps import cell_model_config
from repro.models import build_model
from repro.parallel.sharding import ShardingRules


def _mesh(multi_pod=False):
    # AbstractMesh takes a tuple of (axis_name, size) pairs
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return AbstractMesh(tuple(zip(axes, shape)))


def _check_spec_divides(shape, spec, mesh):
    for dim, axes in enumerate(spec):
        if axes is None:
            continue
        axes = axes if isinstance(axes, tuple) else (axes,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert shape[dim] % size == 0, \
            f"dim {dim} of {shape} not divisible by {axes}={size}"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divide(arch, multi_pod):
    cfg = get_config(arch)
    mesh = _mesh(multi_pod)
    rules = ShardingRules(mesh=mesh, cfg=cfg)
    aparams = build_model(cfg).abstract_params()
    pspecs = rules.params_pspecs(aparams)

    leaves_and_specs = zip(
        jax.tree_util.tree_leaves(aparams),
        jax.tree_util.tree_leaves(pspecs,
                                  is_leaf=lambda x: isinstance(x, P)))
    n_sharded = 0
    for leaf, spec in leaves_and_specs:
        _check_spec_divides(leaf.shape, spec, mesh)
        if any(s is not None for s in spec):
            n_sharded += 1
    assert n_sharded > 0, "nothing sharded at all"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    mesh = _mesh()
    for shape in shapes_for(cfg):
        if not shape.is_decode:
            continue
        mcfg = cell_model_config(cfg, shape)
        rules = ShardingRules(mesh=mesh, cfg=mcfg)
        model = build_model(mcfg)
        acache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cspecs = rules.cache_pspecs(acache)
        for leaf, spec in zip(
                jax.tree_util.tree_leaves(acache),
                jax.tree_util.tree_leaves(
                    cspecs, is_leaf=lambda x: isinstance(x, P))):
            _check_spec_divides(leaf.shape, spec, mesh)


def test_batch_spec_falls_back():
    cfg = get_config("llama3_8b")
    rules = ShardingRules(mesh=_mesh(), cfg=cfg)
    assert rules.batch_spec(256) == ("data",)
    assert rules.batch_spec(1) is None          # long_500k: unshardable
    assert rules.batch_spec(17) is None


def test_attention_fallback_when_heads_dont_divide():
    """qwen1.5 (40 heads) and paligemma (8 heads) cannot TP 16 ways:
    attention weights must fall back to FSDP-only."""
    mesh = _mesh()
    for arch, heads_ok in [("qwen15_32b", False), ("paligemma_3b", False),
                           ("llama3_8b", True)]:
        cfg = get_config(arch)
        rules = ShardingRules(mesh=mesh, cfg=cfg)
        spec = rules.param_spec("units/layer0/attn/wq", (1, 4096, 4096))
        if heads_ok:
            assert "model" in str(spec)
        else:
            assert "model" not in str(spec)

"""scripts/perf_trend.py — trend comparison hygiene.

Synthetic summary rows (``us_per_call == 0.0``: ``service_scaling``,
``service_tree_gc``, ``durable_group_speedup``, ...) are derived
ratios, not measurements; they must never be compared as throughput
rows even when they carry an ``ops_per_s``-shaped field.
"""
import importlib.util
import json
import pathlib

_SPEC = importlib.util.spec_from_file_location(
    "perf_trend",
    pathlib.Path(__file__).resolve().parents[1] / "scripts/perf_trend.py")
perf_trend = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(perf_trend)


def _write(directory: pathlib.Path, rows):
    directory.mkdir(parents=True, exist_ok=True)
    (directory / "BENCH_service.json").write_text(
        json.dumps({"section": "service", "rows": rows}))


def test_synthetic_rows_are_skipped(tmp_path):
    base = [
        {"name": "real", "us_per_call": 12.5, "ops_per_s": 1000.0},
        {"name": "service_scaling", "us_per_call": 0.0,
         "ops_per_s": 900.0},              # synthetic: must be ignored
    ]
    cur = [
        {"name": "real", "us_per_call": 12.5, "ops_per_s": 990.0},
        {"name": "service_scaling", "us_per_call": 0.0,
         "ops_per_s": 1.0},                # would be a -99.9% "drop"
    ]
    _write(tmp_path / "base", base)
    _write(tmp_path / "cur", cur)
    regressions = perf_trend.compare(tmp_path / "cur", tmp_path / "base",
                                     threshold=0.20)
    assert regressions == []


def test_real_regressions_still_flagged(tmp_path):
    _write(tmp_path / "base",
           [{"name": "real", "us_per_call": 10.0, "ops_per_s": 1000.0}])
    _write(tmp_path / "cur",
           [{"name": "real", "us_per_call": 40.0, "ops_per_s": 250.0}])
    regs = perf_trend.compare(tmp_path / "cur", tmp_path / "base", 0.20)
    assert len(regs) == 1 and regs[0][1] == "real"

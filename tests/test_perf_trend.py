"""scripts/perf_trend.py — trend comparison hygiene.

Synthetic summary rows (``us_per_call == 0.0``: ``service_scaling``,
``service_tree_gc``, ``durable_group_speedup``, ...) are derived
ratios, not measurements; they must never be compared as throughput
rows even when they carry an ``ops_per_s``-shaped field.
"""
import importlib.util
import json
import pathlib

_SPEC = importlib.util.spec_from_file_location(
    "perf_trend",
    pathlib.Path(__file__).resolve().parents[1] / "scripts/perf_trend.py")
perf_trend = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(perf_trend)


def _write(directory: pathlib.Path, rows):
    directory.mkdir(parents=True, exist_ok=True)
    (directory / "BENCH_service.json").write_text(
        json.dumps({"section": "service", "rows": rows}))


def test_synthetic_rows_are_skipped(tmp_path):
    base = [
        {"name": "real", "us_per_call": 12.5, "ops_per_s": 1000.0},
        {"name": "service_scaling", "us_per_call": 0.0,
         "ops_per_s": 900.0},              # synthetic: must be ignored
    ]
    cur = [
        {"name": "real", "us_per_call": 12.5, "ops_per_s": 990.0},
        {"name": "service_scaling", "us_per_call": 0.0,
         "ops_per_s": 1.0},                # would be a -99.9% "drop"
    ]
    _write(tmp_path / "base", base)
    _write(tmp_path / "cur", cur)
    regressions = perf_trend.compare(tmp_path / "cur", tmp_path / "base",
                                     threshold=0.20)
    assert regressions == []


def test_real_regressions_still_flagged(tmp_path):
    _write(tmp_path / "base",
           [{"name": "real", "us_per_call": 10.0, "ops_per_s": 1000.0}])
    _write(tmp_path / "cur",
           [{"name": "real", "us_per_call": 40.0, "ops_per_s": 250.0}])
    regs = perf_trend.compare(tmp_path / "cur", tmp_path / "base", 0.20)
    assert len(regs) == 1 and regs[0][1] == "real"
    assert regs[0][6] == "drop"


def test_lower_is_better_metrics_flag_rises(tmp_path):
    """flushes_per_commit / recover_us regress by RISING: a drop is an
    improvement and must stay silent; a rise past the threshold flags."""
    _write(tmp_path / "base",
           [{"name": "durable_kv_S2_group", "us_per_call": 50.0,
             "ops_per_s": 500.0, "flushes_per_commit": 1.0},
            {"name": "durable_group_recover", "us_per_call": 800.0,
             "recover_us": 400.0}])
    _write(tmp_path / "cur",
           [{"name": "durable_kv_S2_group", "us_per_call": 50.0,
             "ops_per_s": 500.0, "flushes_per_commit": 2.5},  # +150%: flag
            {"name": "durable_group_recover", "us_per_call": 800.0,
             "recover_us": 100.0}])                           # -75%: fine
    regs = perf_trend.compare(tmp_path / "cur", tmp_path / "base", 0.20)
    assert len(regs) == 1
    section, name, key, old, new, change, direction = regs[0]
    assert (name, key, direction) == ("durable_kv_S2_group",
                                      "flushes_per_commit", "rise")
    assert old == 1.0 and new == 2.5


def test_cost_improvements_and_missing_keys_stay_silent(tmp_path):
    """A row missing the cost key on either side never flags (sections
    predating the obs fields must keep comparing cleanly)."""
    _write(tmp_path / "base",
           [{"name": "row", "us_per_call": 5.0, "ops_per_s": 100.0}])
    _write(tmp_path / "cur",
           [{"name": "row", "us_per_call": 5.0, "ops_per_s": 100.0,
             "flushes_per_commit": 9.0}])    # no baseline value: silent
    assert perf_trend.compare(tmp_path / "cur", tmp_path / "base",
                              0.20) == []

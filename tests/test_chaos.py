"""repro.chaos: statechart machines, the scenario driver, determinism,
and the linearizability checker (including its rejection power — a
checker that never fails proves nothing)."""
import dataclasses

import pytest

from repro.chaos import (ChaosReport, ClientMachine, ClientSpec, Event,
                         FaultMachine, FaultSpec, LinearizabilityError,
                         Machine, ScenarioDriver, Transition,
                         CRASH_AT_PERSIST, SHARD_STORM, check_history,
                         crash_mid_migration, crash_mid_scan,
                         default_scenarios, drifting_skew, hot_key_storm,
                         sim_native, straggler)


# ---------------------------------------------------------------------------
# statechart substrate
# ---------------------------------------------------------------------------

def _toggle(seed=0):
    return Machine("t", "off", [
        Transition("off", "flip", "on"),
        Transition("on", "flip", "off"),
        Transition("*", "reset", "off"),
    ], seed)


def test_statechart_transitions_and_trace():
    m = _toggle()
    m.post("flip")
    m.post("flip")
    m.post("noise")          # no transition consumes it -> dropped
    m.post("reset")
    fired = m.process()
    assert fired == 3
    assert m.state == "off"
    assert m.trace_lines() == [
        "t:off--flip-->on", "t:on--flip-->off",
        "t:off--noise-->.", "t:off--reset-->off"]


def test_statechart_declaration_order_and_guards():
    hits = []
    m = Machine("g", "s", [
        Transition("s", "go", "a", guard=lambda m, e: e.get("n", 0) > 3,
                   action=lambda m, e: hits.append("first")),
        Transition("s", "go", "b",
                   action=lambda m, e: hits.append("second")),
    ], 0)
    m.post("go", n=1)
    m.process()
    assert m.state == "b" and hits == ["second"]
    m2 = Machine("g", "s", m.transitions, 0)
    m2.post("go", n=5)
    m2.process()
    assert m2.state == "a" and hits[-1] == "first"


def test_event_payload_access():
    ev = Event("e", {"k": 7})
    assert ev["k"] == 7 and ev.get("missing", 9) == 9


def test_client_machine_issue_await_cycle():
    spec = ClientSpec(think_lo=0, think_hi=0)
    c = ClientMachine("c0", spec, seed=1)
    c.post("tick", wave=1)
    c.process()
    assert c.state == "await" and c.outbox is not None
    op = c.outbox
    assert 1 <= op.key <= spec.n_keys
    c.post("tick", wave=2)      # still awaiting: no second issue
    c.process()
    assert c.issued == 1
    c.post("done", status="ok")
    c.process()
    assert c.state == "think"


def test_fault_machine_crash_schedule_fires_after_first_wave():
    fm = FaultMachine(FaultSpec(kind=CRASH_AT_PERSIST, n_shards=2,
                                first_wave=3), seed=4)
    fm.post("tick", wave=1)
    fm.process()
    assert fm.state == "idle" and not fm.directives
    fm.post("tick", wave=3)
    fm.process()
    assert fm.state == "armed"
    (kind, shard, ahead), = fm.drain_directives()
    assert kind == "arm_crash" and shard in (0, 1) and ahead >= 0
    fm.post("crash", wave=5)
    fm.process()
    assert fm.state == "idle" and fm.fired == 1 and fm.next_wave > 5


def test_fault_machine_storm_start_and_end():
    fm = FaultMachine(FaultSpec(kind=SHARD_STORM, n_shards=2, first_wave=2,
                                storm_len=3), seed=0)
    fm.post("tick", wave=2)
    fm.process()
    assert fm.state == "storming"
    (kind, shard), = fm.drain_directives()
    assert kind == "storm"
    fm.post("tick", wave=fm.until)
    fm.process()
    assert fm.state == "calm"
    assert fm.drain_directives() == [("calm",)]


# ---------------------------------------------------------------------------
# linearizability checker on synthetic histories
# ---------------------------------------------------------------------------

def _history(*events):
    return [("base", [[1, 10], [2, 20]])] + list(events)


def test_checker_accepts_consistent_history():
    stats = check_history(_history(
        ("invoke", 1, "c0", 1, "read", 1, 0),
        ("invoke", 1, "c1", 2, "update", 2, 99),
        ("complete", 1, 1, "ok", 10),
        ("complete", 1, 2, "ok", None),
        ("invoke", 2, "c0", 3, "scan", 1, 0),
        ("complete", 2, 3, "ok", 2),
        ("final", [[1, 10], [2, 99]]),
    ))
    assert stats.ok and stats.immediates == 2 and stats.mutations == 1


@pytest.mark.parametrize("tamper, match", [
    (("complete", 1, 1, "ok", 11), "read"),           # wrong read value
    (("complete", 1, 1, "not_found", None), "missed"),  # read misses live key
    (("complete", 1, 2, "not_found", None), "missed"),  # update NF on live key
], ids=["wrong-read-value", "read-misses-live", "update-misses-live"])
def test_checker_rejects_corrupted_completion(tamper, match):
    events = _history(
        ("invoke", 1, "c0", 1, "read", 1, 0),
        ("invoke", 1, "c1", 2, "update", 2, 99),
        tamper,
        ("final", [[1, 10], [2, 20]]),
    )
    with pytest.raises(LinearizabilityError, match=match):
        check_history(events)


def test_checker_rejects_double_mutation_per_wave():
    events = _history(
        ("invoke", 1, "c0", 1, "update", 1, 5),
        ("invoke", 1, "c1", 2, "update", 1, 6),
        ("complete", 1, 1, "ok", None),
        ("complete", 1, 2, "ok", None),
    )
    with pytest.raises(LinearizabilityError, match="conflict-defer"):
        check_history(events)


def test_checker_rejects_final_state_mismatch():
    with pytest.raises(LinearizabilityError, match="final"):
        check_history(_history(("final", [[1, 10]])))


def test_checker_crash_adopt_reachability():
    # in-flight insert(3) at the crash: recovered state may or may not
    # contain it — both adoptions must pass, any other value must not
    prefix = _history(("invoke", 2, "c0", 1, "insert", 3, 30), ("crash", 2))
    for adopted in ([[1, 10], [2, 20]], [[1, 10], [2, 20], [3, 30]]):
        stats = check_history(prefix + [("adopt", 2, adopted),
                                        ("final", adopted)])
        assert stats.ok and stats.crashes == 1 and stats.indeterminate == 1
    with pytest.raises(LinearizabilityError, match="unreachable"):
        check_history(prefix + [("adopt", 2, [[1, 10], [2, 20], [3, 31]])])


# ---------------------------------------------------------------------------
# scenario driver end-to-end (durable shards, real crash/recover)
# ---------------------------------------------------------------------------

def _run(scenario, tmp_path, sub=""):
    root = None if scenario.backend != "durable" else tmp_path / ("r" + sub)
    return ScenarioDriver(scenario, durable_root=root).run()


def test_chaos_sweep_four_durable_families_linearizable(tmp_path):
    """The acceptance sweep: every durable family runs with injected
    crash/recover cycles and every completed history checks out."""
    crashes = 0
    for i, make in enumerate((hot_key_storm, crash_mid_scan, straggler,
                              drifting_skew)):
        rep = _run(make(seed=0, waves=50), tmp_path, sub=str(i))
        assert rep.check is not None and rep.check.ok, rep.summary()
        assert rep.ops_completed > 30, rep.summary()
        crashes += rep.crashes
        assert rep.scenario.family in rep.summary()
    assert crashes >= 3, "the sweep must actually inject crashes"


def test_chaos_crash_marks_inflight_indeterminate(tmp_path):
    rep = _run(drifting_skew(seed=0, waves=50), tmp_path)
    assert rep.crashes >= 1
    assert rep.check.crashes == rep.crashes
    assert rep.ops_invoked >= rep.ops_completed
    # completed + indeterminate-at-crash accounts for every invocation
    assert rep.check.indeterminate == rep.ops_invoked - rep.ops_completed


def test_chaos_wal_prune_runs_between_waves(tmp_path):
    rep = _run(drifting_skew(seed=0, waves=50), tmp_path)
    assert rep.wal_pruned > 0, "prune cadence never fired"
    # pruning keeps the on-disk WAL bounded well below one record/round
    total_rounds = rep.ops_completed
    assert rep.wal_records < total_rounds


def test_chaos_determinism_byte_identical_across_runs(tmp_path):
    """Same seed -> byte-identical event traces and final state, even
    across crash/recover cycles (the drifting_skew run crashes)."""
    sc = drifting_skew(seed=3, waves=40)
    a = _run(sc, tmp_path, sub="a")
    b = _run(sc, tmp_path, sub="b")
    assert a.crashes >= 1, "determinism test must cover crash/recover"
    assert a.trace_lines == b.trace_lines
    assert a.final_items == b.final_items
    assert (a.ops_invoked, a.ops_completed, a.crashes) == \
        (b.ops_invoked, b.ops_completed, b.crashes)
    c = _run(dataclasses.replace(sc, seed=4, name="drifting_skew/s4"),
             tmp_path, sub="c")
    assert c.trace_lines != a.trace_lines, "seed must matter"


def test_chaos_driver_rejects_corrupted_real_history(tmp_path):
    """Tamper with one completed verdict from a REAL run: the checker
    must notice (regression for the checker's rejection power)."""
    sc = hot_key_storm(seed=0, waves=30)
    driver = ScenarioDriver(sc, durable_root=tmp_path / "t")
    rep = driver.run()
    assert rep.check.ok
    events = list(driver.recorder.events)
    idx = next(i for i, ev in enumerate(events)
               if ev[0] == "complete" and ev[3] == "ok"
               and ev[4] is not None)
    wave, seq, status, val = events[idx][1:]
    events[idx] = ("complete", wave, seq, status, (val or 0) + 1)
    with pytest.raises(LinearizabilityError):
        check_history(events)


def test_chaos_sim_native_scenario(tmp_path):
    """SIM-backed shards run the full KV workload natively (desired
    values on the micro-op machines — no crash faults by design)."""
    rep = _run(sim_native(seed=0, waves=12), tmp_path)
    assert rep.check is not None and rep.check.ok, rep.summary()
    assert rep.crashes == 0 and rep.check.mutations > 0
    assert rep.ops_completed == rep.ops_invoked


def test_default_scenarios_cover_families():
    scs = default_scenarios(seed=1, waves=30)
    assert {s.family for s in scs} == {
        "hot_key_storm", "crash_mid_scan", "straggler", "drifting_skew",
        "crash_mid_migration", "epoch_boundary", "sim_native"}
    assert all(s.seed == 1 for s in scs)


def test_chaos_crash_mid_migration_family(tmp_path):
    """Key-range migrations under live traffic with crashes scheduled
    into the copy and the swing: every history linearizable, every
    recovered state routable (a failed routing check would raise out of
    check_integrity during the run)."""
    crashes = migrations = 0
    for seed in (0, 1, 2):
        rep = _run(crash_mid_migration(seed=seed, waves=50), tmp_path,
                   sub=f"m{seed}")
        assert rep.check is not None and rep.check.ok, rep.summary()
        assert rep.migrations >= 1, "no migration ever started"
        crashes += rep.crashes
        migrations += rep.migrations
    assert crashes >= 2, "the family must actually inject crashes"
    assert migrations >= 4


def test_chaos_crash_mid_migration_determinism(tmp_path):
    """Same seed -> byte-identical traces across runs, with crashes
    landing inside migrations (the migration machinery — decide, copy
    chunks, swing, rollback — must be fully seeded-deterministic)."""
    sc = crash_mid_migration(seed=1, waves=40)
    a = _run(sc, tmp_path, sub="ma")
    b = _run(sc, tmp_path, sub="mb")
    assert a.crashes >= 1 and a.migrations >= 1
    assert a.trace_lines == b.trace_lines
    assert a.final_items == b.final_items
    assert (a.migrations, a.crashes) == (b.migrations, b.crashes)


def test_chaos_report_summary_fields(tmp_path):
    rep = _run(straggler(seed=0, waves=30), tmp_path)
    assert isinstance(rep, ChaosReport)
    assert "LINEARIZABLE" in rep.summary()
    assert rep.ops_per_s > 0
    assert rep.waves_run >= 30
    assert rep.faults_fired >= 1, "straggler fault never fired"
